"""`repro.net` acceptance suite: codecs, link model, engine bridge.

* Codecs: exact encode/decode round trips for the sparse codecs, bounded
  error for the quantized variant, measured payload length == closed-form
  `nbytes`, and `sparse_bitpack` strictly under `dense_f32` at the paper's
  sparsity ratios.
* Batched accounting: the Pallas `nnz_fleet` pass, the jnp fallback and
  per-row real encoding all agree.
* Comm-accounting dedup: `fleet.stages.bytes_per_node` and
  `core.accumulator.upload_bytes` pinned to the shared analytic helper
  (and to their pre-refactor values).
* NetworkSpec: compile_plan validation, JSON round trips (v2 stamped, v1
  accepted), RunReport.net + RoundRecord.bytes_source round trips.
* Engine bridge: with `NetworkSpec` at defaults every schedule reproduces
  the analytic trajectories exactly; with a heterogeneous lossy network
  the async arrival order demonstrably shifts and the report's byte
  totals equal the NetTrace's encoded bytes.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, net
from repro.core import accumulator as accum
from repro.core import detection
from repro.fleet import stages as fleet_stages
from repro.net.codecs import analytic_upload_bytes

PAPER_RATIOS = (0.05, 0.1, 0.25, 0.5)


def _sparse_update(n_params: int, nnz: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = np.zeros(n_params, np.float32)
    if nnz:
        idx = rng.choice(n_params, nnz, replace=False)
        u[idx] = rng.normal(size=nnz).astype(np.float32)
    return u


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["dense_f32", "sparse_coo",
                                  "sparse_bitpack"])
def test_codec_exact_round_trip_and_measured_bytes(name):
    u = _sparse_update(4097, 300)
    codec = net.get_codec(name)
    msg = codec.encode(u)
    assert np.array_equal(codec.decode(msg), u)          # exact
    nnz = int((u != 0).sum())
    assert msg.nbytes == int(np.asarray(codec.nbytes(nnz, u.size)))


@pytest.mark.parametrize("value_bits", [8, 16])
def test_quantized_bitpack_round_trip_bounded(value_bits):
    u = _sparse_update(2048, 150, seed=3)
    codec = net.get_codec("sparse_bitpack", value_bits=value_bits)
    msg = codec.encode(u)
    dec = codec.decode(msg)
    scale = msg.meta["scale"]
    assert float(np.abs(dec - u).max()) <= scale / 2 + 1e-6
    # the sparsity pattern survives quantization exactly
    assert set(np.flatnonzero(dec)) <= set(np.flatnonzero(u))
    assert msg.nbytes == int(np.asarray(codec.nbytes(150, u.size)))


def test_empty_and_dense_edge_cases():
    zeros = np.zeros(1000, np.float32)
    for name in ("sparse_coo", "sparse_bitpack"):
        codec = net.get_codec(name)
        msg = codec.encode(zeros)
        assert np.array_equal(codec.decode(msg), zeros)
        assert msg.nbytes == int(np.asarray(codec.nbytes(0, 1000)))
    dense = np.arange(1.0, 9.0, dtype=np.float32)
    codec = net.get_codec("dense_f32")
    assert np.array_equal(codec.decode(codec.encode(dense)), dense)


@pytest.mark.parametrize("ratio", PAPER_RATIOS)
def test_bitpack_strictly_beats_dense_at_paper_ratios(ratio):
    """The acceptance bar: sparse_bitpack < dense_f32 bytes at every
    sparsity ratio the paper operates at, measured on real payloads."""
    n = 50_000
    u = _sparse_update(n, int(n * ratio))
    dense = net.get_codec("dense_f32").encode(u).nbytes
    bitpack = net.get_codec("sparse_bitpack").encode(u).nbytes
    assert bitpack < dense
    # quantized variants compress further still
    q8 = net.get_codec("sparse_bitpack", value_bits=8).encode(u).nbytes
    assert q8 < bitpack


def test_get_codec_rejects_unknown_and_bad_value_bits():
    with pytest.raises(ValueError, match="unknown codec"):
        net.get_codec("zstd")
    with pytest.raises(ValueError, match="sparse_bitpack"):
        net.get_codec("dense_f32", value_bits=8)
    with pytest.raises(ValueError, match="value_bits"):
        net.get_codec("sparse_bitpack", value_bits=12)


def test_batched_encoded_bytes_pallas_matches_reference_and_encode():
    """The node-batched accounting path: fused Pallas nnz pass == jnp
    fallback == per-row real encoding, across mixed sparsity rows."""
    rows = [_sparse_update(3000, k, seed=k) for k in (0, 1, 50, 1500, 3000)]
    flat = jnp.asarray(np.stack(rows))
    codec = net.get_codec("sparse_bitpack")
    ref = net.batched_encoded_bytes(flat, codec, backend="reference")
    pal = net.batched_encoded_bytes(flat, codec, backend="pallas")
    per_row = [codec.encode(r).nbytes for r in rows]
    assert list(ref) == per_row
    assert list(pal) == per_row


# ---------------------------------------------------------------------------
# comm-accounting dedup (satellite): one analytic helper, two call sites
# ---------------------------------------------------------------------------

def test_analytic_helper_pins_both_legacy_call_sites():
    """`stages.bytes_per_node` and `accumulator.upload_bytes` must produce
    exactly their pre-refactor values, and agree with each other, for a
    grid of (n_params, ratio) — both are now the one shared helper."""
    tree = {"a": jnp.zeros((100, 10)), "b": jnp.zeros((237,))}
    n_params = 1237
    for ratio in (0.01, 0.1, 0.33, 0.5, 0.99, 1.0):
        # the pre-refactor formulas, inlined as the regression oracle
        old_stages = (n_params * 4 if ratio >= 1.0
                      else int(n_params * ratio) * 8)
        old_accum = (n_params * 4 if ratio >= 1.0
                     else int(n_params * min(ratio, 1.0)) * 8)
        assert fleet_stages.bytes_per_node(n_params, ratio) == old_stages
        assert accum.upload_bytes(tree, ratio) == old_accum
        assert analytic_upload_bytes(n_params, ratio) == old_stages
    assert accum.upload_bytes(tree, 1.0, bytes_per_value=2) == n_params * 2


# ---------------------------------------------------------------------------
# NetworkSpec: validation + serialization
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=4, samples_per_node=20, n_test=32,
                            n_cloud_test=16),
        schedule=api.SchedulePolicy(kind="async"),
        train=api.TrainSpec(local_steps=1, batch_size=4, lr=0.1),
        rounds=1)
    base.update(kw)
    return api.ExperimentSpec(**base)


@pytest.mark.parametrize("bad,match", [
    (api.NetworkSpec(codec="gzip"), "network.codec"),
    (api.NetworkSpec(codec="sparse_bitpack", value_bits=12), "value_bits"),
    (api.NetworkSpec(codec="sparse_coo", value_bits=8), "quantized-value"),
    (api.NetworkSpec(codec="dense_f32", loss_prob=1.0), "loss_prob"),
    (api.NetworkSpec(codec="dense_f32", latency_s=-1.0), "latency"),
    (api.NetworkSpec(codec="dense_f32", mtu_bytes=0), "mtu"),
    (api.NetworkSpec(loss_prob=0.5), "link simulation needs a wire codec"),
    (api.NetworkSpec(jitter_s=0.1), "link simulation needs a wire codec"),
])
def test_compile_plan_rejects_bad_network(bad, match):
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(network=bad))


def test_compile_plan_rejects_network_on_sequential_topology():
    with pytest.raises(api.SpecError, match="no network simulation"):
        api.compile_plan(_spec(
            network=api.NetworkSpec(codec="dense_f32"),
            topology=api.Topology(kind="sequential")))


def test_compile_plan_lowers_network_stages():
    plan = api.compile_plan(_spec(
        network=api.NetworkSpec(codec="sparse_bitpack", loss_prob=0.1),
        compression=api.CompressionSpec(sparsify_ratio=0.5)))
    assert plan.net_codec == "sparse_bitpack"
    assert "wire_encode[sparse_bitpack]" in plan.stages
    assert "link_sim" in plan.stages
    plan0 = api.compile_plan(_spec())
    assert plan0.net_codec is None
    assert not any(s.startswith("wire") for s in plan0.stages)


def test_network_spec_json_round_trip_and_v1_acceptance():
    spec = _spec(network=api.NetworkSpec(
        codec="sparse_bitpack", value_bits=8, bandwidth_sigma=0.5,
        latency_s=0.01, jitter_s=0.1, loss_prob=0.05,
        shared_uplink_bps=1e8))
    d = spec.to_dict()
    assert d["schema_version"] == api.SCHEMA_VERSION >= 5
    assert api.ExperimentSpec.from_dict(d) == spec
    # v1 payloads (no network section) still load, with analytic defaults
    v1 = _spec().to_dict()
    v1.pop("network")
    v1["schema_version"] = 1
    loaded = api.ExperimentSpec.from_dict(v1)
    assert loaded.network == api.NetworkSpec()
    v0 = dict(v1, schema_version=0)
    with pytest.raises(ValueError, match="schema_version"):
        api.ExperimentSpec.from_dict(v0)


def test_report_round_trip_with_net_and_bytes_source():
    from repro.api import RoundRecord
    rep = api.RunReport(
        mode="async", engine="fleet",
        records=[RoundRecord(1.0, 0, 0.5, 1e4, 2.0, 0.1, 0,
                             bytes_source="encoded")],
        kappa=0.1, net={"codec": "sparse_coo", "n_uploads": 4,
                        "encoded_bytes": 1e4, "wire_bytes": 1.2e4,
                        "transfer_s": 0.4, "retransmits": 2})
    d = rep.to_dict()
    assert d["schema_version"] == api.SCHEMA_VERSION
    assert d["records"][0]["bytes_source"] == "encoded"
    rep2 = api.RunReport.from_json(rep.to_json())
    assert rep2 == dataclasses.replace(rep, final_params=None)
    # v1 report records (no bytes_source) load as analytic
    v1 = json.loads(rep.to_json())
    v1["schema_version"] = 1
    del v1["net"]
    for r in v1["records"]:
        del r["bytes_source"]
    loaded = api.RunReport.from_dict(v1)
    assert loaded.records[0].bytes_source == "analytic"
    assert loaded.net is None


# ---------------------------------------------------------------------------
# engine bridge: defaults == analytic trajectories; lossy shifts arrivals
# ---------------------------------------------------------------------------

N, ROUNDS = 5, 2


@pytest.fixture(scope="module")
def small_spec():
    return api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=N, samples_per_node=24, n_test=64,
                            n_cloud_test=32,
                            attack=api.AttackMix(malicious_frac=0.2),
                            profile=api.NodeHeterogeneity(heterogeneity=0.8)),
        privacy=api.PrivacySpec(sigma=0.05),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=ROUNDS, seed=0)


def _with(spec, **kw):
    return dataclasses.replace(spec, **kw)


@pytest.mark.parametrize("kind", ["sync", "async", "buffered"])
def test_default_network_reproduces_analytic_trajectories(kind, small_spec):
    """NetworkSpec() (analytic) must be observationally identical to the
    pre-net engines for every schedule — the explicit-default spec and the
    field-omitted spec run the same engines with net=None."""
    base = _with(small_spec, schedule=api.SchedulePolicy(kind=kind))
    explicit = _with(base, network=api.NetworkSpec())
    rep_a = api.run(api.compile_plan(base))
    rep_b = api.run(api.compile_plan(explicit))
    assert rep_a.net is None and rep_b.net is None
    assert [dataclasses.replace(r) for r in rep_a.records] == rep_b.records
    assert all(r.bytes_source == "analytic" for r in rep_a.records)
    assert rep_a.kappa == rep_b.kappa


def _arrival_sequence(spec, windows=10):
    """The per-window processed-node-id sequences of an async engine."""
    plan = api.compile_plan(spec)
    eng = api.make_engine(plan, api.materialize(spec))
    seqs = []
    for _ in range(windows):
        order, proc = eng.select_window()
        seqs.append(tuple(order[proc]))
        eng.run_window(evaluate=False)
    return eng, seqs


def test_lossy_heterogeneous_network_shifts_async_composition(small_spec):
    """The acceptance bar: a heterogeneous lossy link demonstrably changes
    which arrivals land in which window (the network drives the clocks),
    and the run's byte totals equal the NetTrace's encoded bytes."""
    base = _with(small_spec,
                 schedule=api.SchedulePolicy(kind="async"), rounds=4)
    lossy = _with(base, network=api.NetworkSpec(
        codec="sparse_bitpack", bandwidth_sigma=2.0, latency_s=0.05,
        jitter_s=2.0, loss_prob=0.3))
    _, seq_analytic = _arrival_sequence(base)
    eng, seq_lossy = _arrival_sequence(lossy)
    assert seq_analytic != seq_lossy, \
        "arrival/window composition must respond to the network"
    # byte accounting: every window's comm_bytes is the codec's measured
    # pricing, and the engine history sums to the trace total
    hist_bytes = sum(r.comm_bytes for r in eng.history)
    assert hist_bytes == eng.net.trace.total_encoded_bytes
    assert eng.net.trace.n_uploads == \
        sum(r.n_processed for r in eng.history)


def test_async_net_report_bytes_equal_trace(small_spec):
    spec = _with(small_spec,
                 schedule=api.SchedulePolicy(kind="async"),
                 network=api.NetworkSpec(codec="sparse_coo",
                                         bandwidth_sigma=1.0,
                                         loss_prob=0.2, jitter_s=0.1))
    rep = api.run(api.compile_plan(spec))
    assert rep.net is not None and rep.net["codec"] == "sparse_coo"
    assert all(r.bytes_source == "encoded" for r in rep.records)
    assert sum(r.comm_bytes for r in rep.records) == \
        rep.net["encoded_bytes"]
    # kappa derives from the link-model comm times, not the analytic ones
    comm = sum(r.comm_time for r in rep.records)
    assert comm == pytest.approx(rep.net["transfer_s"])


def test_sync_net_report_bytes_equal_trace(small_spec):
    spec = _with(small_spec,
                 schedule=api.SchedulePolicy(kind="sync"),
                 network=api.NetworkSpec(codec="sparse_bitpack",
                                         value_bits=8, latency_s=0.01,
                                         loss_prob=0.1))
    rep = api.run(api.compile_plan(spec))
    assert rep.net is not None
    assert sum(r.comm_bytes for r in rep.records) == \
        rep.net["encoded_bytes"]
    assert rep.net["n_uploads"] == N * ROUNDS
    assert all(r.bytes_source == "encoded" for r in rep.records)


def test_encoded_bytes_track_measured_sparsity(small_spec):
    """Measured pricing: at ratio 0.5 the sparse payloads must land close
    to the analytic nominal count but be derived from the actual per-leaf
    DGC splits (total nnz within a few % of nominal, not equal to the
    dense count)."""
    spec = _with(small_spec,
                 schedule=api.SchedulePolicy(kind="sync"),
                 network=api.NetworkSpec(codec="sparse_coo"))
    plan = api.compile_plan(spec)
    eng = api.make_engine(plan, api.materialize(spec))
    eng.run_round()
    nnz = np.asarray(eng.net.trace.nnz)
    nominal = eng.net.nominal_nnz
    assert nnz.shape == (N,)
    assert (np.abs(nnz - nominal) < 0.05 * nominal).all()
    assert (nnz < eng.n_params).all()


def test_mesh_topology_runs_net_and_bytes_equal_trace():
    """The mesh path carries the network subsystem too: on a forced
    2-device host, sync and async runs with a lossy codec-enabled network
    produce encoded byte totals equal to their NetTrace (subprocess
    pattern from test_fleet_shard.py)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import json
        import jax
        from repro import api

        out = {"n_devices": len(jax.devices())}
        for kind in ("sync", "async"):
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(n_nodes=5, samples_per_node=20,
                                    n_test=32, n_cloud_test=16,
                                    profile=api.NodeHeterogeneity(
                                        heterogeneity=0.8)),
                schedule=api.SchedulePolicy(kind=kind),
                compression=api.CompressionSpec(sparsify_ratio=0.5),
                network=api.NetworkSpec(codec="sparse_bitpack",
                                        bandwidth_sigma=1.0,
                                        loss_prob=0.2, jitter_s=0.1),
                topology=api.Topology(kind="mesh", devices=2),
                train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
                rounds=2, seed=0)
            rep = api.run(api.compile_plan(spec))
            out[kind] = {
                "engine": rep.engine,
                "sum_bytes": sum(r.comm_bytes for r in rep.records),
                "trace_bytes": rep.net["encoded_bytes"],
                "n_uploads": rep.net["n_uploads"],
                "sources": sorted({r.bytes_source for r in rep.records}),
            }
        print(json.dumps(out))
    """)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 2
    for kind in ("sync", "async"):
        got = rec[kind]
        assert got["engine"] == "fleet-mesh"
        assert got["sum_bytes"] == got["trace_bytes"] > 0
        assert got["n_uploads"] == 10          # 5 nodes x 2 rounds
        assert got["sources"] == ["encoded"]


# ---------------------------------------------------------------------------
# buffered staleness weights (satellite)
# ---------------------------------------------------------------------------

def test_masked_weighted_mean_uniform_equals_masked_mean():
    """The parity contract: uniform weights reproduce the FedBuff masked
    mean bit-for-bit."""
    rng = np.random.default_rng(0)
    trees = {"w": jnp.asarray(rng.normal(size=(6, 4, 3)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))}
    for mask in (np.array([1, 0, 1, 1, 0, 1], bool),
                 np.zeros(6, bool), np.ones(6, bool)):
        m = jnp.asarray(mask)
        uniform = detection.masked_weighted_mean(trees, m, jnp.ones(6))
        plain = detection.masked_mean(trees, m)
        for a, b in zip(jax.tree.leaves(uniform), jax.tree.leaves(plain)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_staleness_weights_discount_stale_updates():
    taus = jnp.asarray([0, 1, 3, 10])
    w = np.asarray(detection.staleness_weights(taus, 0.5))
    assert w[0] == 1.0
    assert (np.diff(w) < 0).all()
    np.testing.assert_allclose(w, (1.0 + np.asarray(taus)) ** -0.5,
                               rtol=1e-6)


def test_buffered_staleness_adaptive_runs_and_differs(small_spec):
    """The SchedulePolicy knob: staleness-weighted FedBuff runs end to end;
    with the load-aware fat windows (real staleness spread) it produces a
    different trajectory than the uniform mean, while uniform stays the
    pre-PR buffered path."""
    base = _with(small_spec, schedule=api.SchedulePolicy(
        kind="buffered", window=api.TargetArrivalsWindow(target_arrivals=N)),
        rounds=4)
    adaptive = _with(base, schedule=dataclasses.replace(
        base.schedule, staleness_adaptive=True, staleness_a=0.9))
    rep_u = api.run(api.compile_plan(base))
    rep_s = api.run(api.compile_plan(adaptive))
    assert len(rep_u.records) == len(rep_s.records)
    # same arrival schedule (weights don't touch clocks) ...
    assert [r.t for r in rep_u.records] == [r.t for r in rep_s.records]
    # ... different aggregation
    pu = jax.tree.leaves(rep_u.final_params)
    ps = jax.tree.leaves(rep_s.final_params)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(pu, ps))
