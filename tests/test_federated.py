"""Integration tests: the four schemes, attacks, detection, fed_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FedConfig, FederatedTrainer, FedStepConfig
from repro.core.attacks import (attack_success_rate, dlg_attack, flip_labels,
                                reconstruction_mse)
from repro.core.fed_step import fed_train_step
from repro.data import make_federated_image_data
from repro.models import loss_fn as model_loss_fn
from repro.models import init_params
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


def small_fed_setup(mode, n_malicious=0, detect=False, rounds=4, seed=0,
                    sparsify=1.0, sigma=0.05):
    """sigma=0.05 keeps a workable SNR at this tiny scale; the paper's own
    calibration (ε=8, δ=1e-3 ⇒ σ≈0.47) collapses accuracy — a finding we
    assert explicitly in test_paper_calibrated_sigma_hurts (EXPERIMENTS.md)."""
    node_data, test, cloud, _ = make_federated_image_data(
        seed, n_nodes=5, n_malicious=n_malicious, n_train=800, n_test=300,
        n_cloud_test=200, hw=(14, 14))
    cfg = FedConfig(mode=mode, n_nodes=5, rounds=rounds, local_steps=15,
                    batch_size=32, lr=0.1, detect=detect, sigma=sigma,
                    sparsify_ratio=sparsify, seed=seed)
    params = init_cnn(jax.random.PRNGKey(seed), in_hw=(14, 14))
    return FederatedTrainer(params, cnn_loss, cnn_accuracy, node_data, test,
                            cloud, cfg)


def test_sfl_learns():
    tr = small_fed_setup("sfl", rounds=5)
    hist = tr.run()
    assert hist[-1].accuracy > 0.5, hist[-1].accuracy


def test_afl_learns_and_is_faster_than_sfl():
    tr_a = small_fed_setup("afl", rounds=4)
    ha = tr_a.run()
    tr_s = small_fed_setup("sfl", rounds=4)
    hs = tr_s.run()
    assert ha[-1].accuracy > 0.4
    # async: no barrier on the slowest node => lower simulated wall clock
    assert ha[-1].t < hs[-1].t


def test_aldpfl_close_to_afl():
    """Paper Fig. 7a: LDP costs only a little accuracy."""
    acc_afl = small_fed_setup("afl", rounds=4).run()[-1].accuracy
    acc_aldp = small_fed_setup("aldpfl", rounds=4).run()[-1].accuracy
    assert acc_aldp > acc_afl - 0.25


def test_detection_mitigates_label_flipping():
    """Paper Fig. 8(b) special task: 2/5 nodes flip labels 1->7; the attack
    craters class-1 accuracy, and detection rejects poisoned updates. (The
    general task moves much less — exactly the paper's observation.)"""
    from repro.models.cnn import per_class_accuracy
    t_attack = small_fed_setup("aldpfl", n_malicious=2, detect=False,
                               rounds=5)
    t_attack.run()
    cls1_attacked = float(per_class_accuracy(t_attack.params,
                                             *t_attack.test_data, 1))
    t_def = small_fed_setup("aldpfl", n_malicious=2, detect=True, rounds=5)
    t_def.run()
    cls1_defended = float(per_class_accuracy(t_def.params,
                                             *t_def.test_data, 1))
    rejected = sum(r.n_rejected for r in t_def.history)
    assert rejected > 0
    assert cls1_defended >= cls1_attacked - 0.05


def test_staleness_adaptive_async_runs():
    """FedAsync polynomial staleness weighting path (beyond-paper option)."""
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=4, n_malicious=0, n_train=400, n_test=150,
        n_cloud_test=100, hw=(14, 14))
    cfg = FedConfig(mode="aldpfl", n_nodes=4, rounds=2, local_steps=8,
                    batch_size=32, lr=0.1, detect=False, sigma=0.05,
                    staleness_adaptive=True, heterogeneity=1.0)
    tr = FederatedTrainer(init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
                          cnn_loss, cnn_accuracy, node_data, test, cloud, cfg)
    hist = tr.run()
    assert hist[-1].accuracy > 0.1


def test_noniid_dirichlet_trains():
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=5, n_malicious=0, n_train=800, n_test=200,
        n_cloud_test=100, hw=(14, 14), iid=False, dirichlet_alpha=0.3)
    cfg = FedConfig(mode="afl", n_nodes=5, rounds=4, local_steps=12,
                    batch_size=32, lr=0.1, detect=False)
    tr = FederatedTrainer(init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
                          cnn_loss, cnn_accuracy, node_data, test, cloud, cfg)
    hist = tr.run()
    assert hist[-1].accuracy > 0.3


def test_privacy_accountant_tracks():
    tr = small_fed_setup("aldpfl", rounds=2)
    tr.run()
    assert tr.epsilon_spent() > 0


def test_paper_calibrated_sigma_hurts():
    """Honest finding: at the paper's ε=8/δ=1e-3 calibration (σ≈0.47 on the
    whole-delta L2 ball), per-coordinate SNR is far below 1 and accuracy
    degrades vs the low-noise run — the paper's 'negligible accuracy loss'
    claim does not survive honest Eq.-8 calibration at this scale."""
    noisy = small_fed_setup("aldpfl", rounds=3, sigma=None)  # ε=8 calibrated
    acc_paper = noisy.run()[-1].accuracy
    mild = small_fed_setup("aldpfl", rounds=3, sigma=0.02)
    acc_mild = mild.run()[-1].accuracy
    assert noisy.sigma > 0.4
    assert acc_mild > acc_paper - 0.05   # low-noise at least as good


def test_sparsified_uploads_smaller():
    tr = small_fed_setup("aldpfl", rounds=2, sparsify=0.1)
    hist = tr.run()
    tr_full = small_fed_setup("aldpfl", rounds=2, sparsify=1.0)
    hist_full = tr_full.run()
    assert hist[-1].comm_bytes < hist_full[-1].comm_bytes


# ---------------------------------------------------------------------------
# attacks
# ---------------------------------------------------------------------------

def test_flip_labels():
    y = jnp.array([0, 1, 2, 1, 7])
    out = flip_labels(y, 1, 7)
    np.testing.assert_array_equal(np.asarray(out), [0, 7, 2, 7, 7])


def test_dlg_attack_and_ldp_defence():
    """DLG reconstructs data from clean gradients; LDP noise breaks it."""
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (16, 4)) * 0.3

    def loss(params, x, y_soft):
        logits = x @ params
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * y_soft, -1))

    x_true = jax.random.normal(jax.random.PRNGKey(1), (1, 16)) * 0.5
    y_true = jax.nn.one_hot(jnp.array([2]), 4)
    g_clean = jax.grad(loss)(W, x_true, y_true)

    x_rec, hist = dlg_attack(lambda p, x, y: loss(p, x, y), W, g_clean,
                             (1, 16), 4, jax.random.PRNGKey(2), steps=300,
                             lr=0.1)
    assert float(hist[-1]) < float(hist[0]) * 0.1
    mse_clean = float(reconstruction_mse(x_true, x_rec))

    from repro.core.aldp import add_gaussian_noise
    g_noisy = add_gaussian_noise(g_clean, jax.random.PRNGKey(3), 0.5, 1.0)
    x_rec_n, _ = dlg_attack(lambda p, x, y: loss(p, x, y), W, g_noisy,
                            (1, 16), 4, jax.random.PRNGKey(2), steps=300,
                            lr=0.1)
    mse_noisy = float(reconstruction_mse(x_true, x_rec_n))
    assert mse_noisy > mse_clean


def test_asr_metric():
    x = jnp.zeros((4, 8))
    rec = x.at[0].set(1.0)
    asr = attack_success_rate(x, rec, mse_threshold=0.5)
    assert float(asr) == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# datacenter fed_train_step
# ---------------------------------------------------------------------------

def test_fed_step_learns_lm():
    cfg = get_smoke_config("qwen1.5-0.5b").replace(
        n_layers=2, d_model=64, d_ff=128, vocab=64, attn_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fcfg = FedStepConfig(n_nodes=4, local_steps=2, lr=0.1, sigma=1e-4,
                         detect=True)
    lfn = lambda p, b: model_loss_fn(p, cfg, b)
    afn = lambda p, b: model_loss_fn(p, cfg, b)[1]["accuracy"]

    from repro.data.synthetic import make_token_dataset
    data = make_token_dataset(0, 128, 16, cfg.vocab)
    rng = np.random.default_rng(0)

    def batch(lead):
        n = int(np.prod(lead))
        idx = rng.integers(0, data.shape[0], n)
        return {"tokens": jnp.asarray(data[idx, :16].reshape(lead + (16,))),
                "targets": jnp.asarray(data[idx, 1:17].reshape(lead + (16,)))}

    step = jax.jit(lambda p, nb, eb, k: fed_train_step(
        p, nb, eb, k, loss_fn=lfn, acc_fn=afn, fcfg=fcfg))
    key = jax.random.PRNGKey(1)
    losses = []
    for r in range(6):
        key, k = jax.random.split(key)
        params, m = step(params, batch((4, 2, 4)), batch((2,)), k)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(m["n_normal"]) >= 1


def test_fed_step_alpha_zero_keeps_global():
    cfg = get_smoke_config("olmo-1b").replace(n_layers=1, d_model=32,
                                              d_ff=64, vocab=32, attn_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fcfg = FedStepConfig(n_nodes=2, local_steps=1, lr=0.1, sigma=0.0,
                         alpha=1.0, detect=False)
    lfn = lambda p, b: model_loss_fn(p, cfg, b)
    toks = jnp.zeros((2, 1, 2, 8), jnp.int32)
    nb = {"tokens": toks, "targets": toks}
    new, _ = fed_train_step(params, nb, None, jax.random.PRNGKey(1),
                            loss_fn=lfn, acc_fn=None, fcfg=fcfg)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
