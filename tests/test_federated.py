"""Integration tests: the four schemes, attacks, detection, fed_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_smoke_config
from repro.core import FedStepConfig
from repro.core.attacks import (attack_success_rate, dlg_attack, flip_labels,
                                reconstruction_mse)
from repro.core.fed_step import fed_train_step
from repro.data import make_federated_image_data
from repro.fleet import NodeProfile
from repro.models import loss_fn as model_loss_fn
from repro.models import init_params
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

_KIND = {"sfl": "sync", "afl": "async", "sldpfl": "sync", "aldpfl": "async"}


def small_fed_run(mode, n_malicious=0, detect=False, rounds=4, seed=0,
                  sparsify=1.0, sigma=0.05):
    """(report, plan, population) for one small CNN run of a paper scheme.

    sigma=0.05 keeps a workable SNR at this tiny scale; the paper's own
    calibration (ε=8, δ=1e-3 ⇒ σ≈0.47) collapses accuracy — a finding we
    assert explicitly in test_paper_calibrated_sigma_hurts (EXPERIMENTS.md)."""
    node_data, test, cloud, _ = make_federated_image_data(
        seed, n_nodes=5, n_malicious=n_malicious, n_train=800, n_test=300,
        n_cloud_test=200, hw=(14, 14))
    if mode in ("sfl", "afl"):
        sigma = 0.0                  # noiseless schemes, whatever sigma says
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=5),
        schedule=api.SchedulePolicy(kind=_KIND[mode]),
        privacy=api.PrivacySpec(sigma=sigma),
        compression=api.CompressionSpec(sparsify_ratio=sparsify),
        defense=api.DefenseSpec(detect=detect),
        train=api.TrainSpec(local_steps=15, batch_size=32, lr=0.1),
        rounds=rounds, seed=seed)
    plan = api.compile_plan(spec)
    pop = api.Population(
        params=init_cnn(jax.random.PRNGKey(seed), in_hw=(14, 14)),
        loss_fn=cnn_loss, acc_fn=cnn_accuracy, node_data=node_data,
        test_data=test, cloud_test=cloud,
        profile=NodeProfile.lognormal(5, 1.0, 0.5, 12.5e6, seed=seed))
    return api.run(plan, pop), plan, pop


def test_sfl_learns():
    rep, _, _ = small_fed_run("sfl", rounds=5)
    assert rep.final_accuracy > 0.5, rep.final_accuracy


def test_afl_learns_and_is_faster_than_sfl():
    rep_a, _, _ = small_fed_run("afl", rounds=4)
    rep_s, _, _ = small_fed_run("sfl", rounds=4)
    assert rep_a.final_accuracy > 0.4
    # async: no barrier on the slowest node => lower simulated wall clock
    assert rep_a.records[-1].t < rep_s.records[-1].t


def test_aldpfl_close_to_afl():
    """Paper Fig. 7a: LDP costs only a little accuracy."""
    acc_afl = small_fed_run("afl", rounds=4)[0].final_accuracy
    acc_aldp = small_fed_run("aldpfl", rounds=4)[0].final_accuracy
    assert acc_aldp > acc_afl - 0.25


def test_detection_mitigates_label_flipping():
    """Paper Fig. 8(b) special task: 2/5 nodes flip labels 1->7; the attack
    craters class-1 accuracy, and detection rejects poisoned updates. (The
    general task moves much less — exactly the paper's observation.)"""
    from repro.models.cnn import per_class_accuracy
    rep_attack, _, pop_a = small_fed_run("aldpfl", n_malicious=2,
                                         detect=False, rounds=5)
    cls1_attacked = float(per_class_accuracy(rep_attack.final_params,
                                             *pop_a.test_data, 1))
    rep_def, _, pop_d = small_fed_run("aldpfl", n_malicious=2, detect=True,
                                      rounds=5)
    cls1_defended = float(per_class_accuracy(rep_def.final_params,
                                             *pop_d.test_data, 1))
    rejected = sum(r.n_rejected for r in rep_def.records)
    assert rejected > 0
    assert cls1_defended >= cls1_attacked - 0.05


def test_staleness_adaptive_async_runs():
    """FedAsync polynomial staleness weighting path (beyond-paper option)."""
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=4, n_malicious=0, n_train=400, n_test=150,
        n_cloud_test=100, hw=(14, 14))
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=4),
        schedule=api.SchedulePolicy(kind="async", staleness_adaptive=True),
        privacy=api.PrivacySpec(sigma=0.05),
        defense=api.DefenseSpec(detect=False),
        train=api.TrainSpec(local_steps=8, batch_size=32, lr=0.1),
        rounds=2, seed=0)
    pop = api.Population(
        params=init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
        loss_fn=cnn_loss, acc_fn=cnn_accuracy, node_data=node_data,
        test_data=test, cloud_test=cloud,
        profile=NodeProfile.lognormal(4, 1.0, 1.0, 12.5e6, seed=0))
    rep = api.run(api.compile_plan(spec), pop)
    assert rep.final_accuracy > 0.1


def test_noniid_dirichlet_trains():
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=5, n_malicious=0, n_train=800, n_test=200,
        n_cloud_test=100, hw=(14, 14), iid=False, dirichlet_alpha=0.3)
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=5),
        schedule=api.SchedulePolicy(kind="async"),
        defense=api.DefenseSpec(detect=False),
        train=api.TrainSpec(local_steps=12, batch_size=32, lr=0.1),
        rounds=4, seed=0)
    pop = api.Population(
        params=init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
        loss_fn=cnn_loss, acc_fn=cnn_accuracy, node_data=node_data,
        test_data=test, cloud_test=cloud,
        profile=NodeProfile.lognormal(5, 1.0, 0.5, 12.5e6, seed=0))
    rep = api.run(api.compile_plan(spec), pop)
    assert rep.final_accuracy > 0.3


def test_privacy_accountant_tracks():
    rep, _, _ = small_fed_run("aldpfl", rounds=2)
    assert rep.epsilon_spent > 0


def test_paper_calibrated_sigma_hurts():
    """Honest finding: at the paper's ε=8/δ=1e-3 calibration (σ≈0.47 on the
    whole-delta L2 ball), per-coordinate SNR is far below 1 and accuracy
    degrades vs the low-noise run — the paper's 'negligible accuracy loss'
    claim does not survive honest Eq.-8 calibration at this scale."""
    rep_paper, plan, _ = small_fed_run("aldpfl", rounds=3, sigma=None)
    acc_paper = rep_paper.final_accuracy
    acc_mild = small_fed_run("aldpfl", rounds=3, sigma=0.02)[0].final_accuracy
    assert plan.sigma > 0.4
    assert acc_mild > acc_paper - 0.05   # low-noise at least as good


def test_sparsified_uploads_smaller():
    rep, _, _ = small_fed_run("aldpfl", rounds=2, sparsify=0.1)
    rep_full, _, _ = small_fed_run("aldpfl", rounds=2, sparsify=1.0)
    assert rep.records[-1].comm_bytes < rep_full.records[-1].comm_bytes


# ---------------------------------------------------------------------------
# attacks
# ---------------------------------------------------------------------------

def test_flip_labels():
    y = jnp.array([0, 1, 2, 1, 7])
    out = flip_labels(y, 1, 7)
    np.testing.assert_array_equal(np.asarray(out), [0, 7, 2, 7, 7])


def test_dlg_attack_and_ldp_defence():
    """DLG reconstructs data from clean gradients; LDP noise breaks it."""
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (16, 4)) * 0.3

    def loss(params, x, y_soft):
        logits = x @ params
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * y_soft, -1))

    x_true = jax.random.normal(jax.random.PRNGKey(1), (1, 16)) * 0.5
    y_true = jax.nn.one_hot(jnp.array([2]), 4)
    g_clean = jax.grad(loss)(W, x_true, y_true)

    x_rec, hist = dlg_attack(lambda p, x, y: loss(p, x, y), W, g_clean,
                             (1, 16), 4, jax.random.PRNGKey(2), steps=300,
                             lr=0.1)
    assert float(hist[-1]) < float(hist[0]) * 0.1
    mse_clean = float(reconstruction_mse(x_true, x_rec))

    from repro.core.aldp import add_gaussian_noise
    g_noisy = add_gaussian_noise(g_clean, jax.random.PRNGKey(3), 0.5, 1.0)
    x_rec_n, _ = dlg_attack(lambda p, x, y: loss(p, x, y), W, g_noisy,
                            (1, 16), 4, jax.random.PRNGKey(2), steps=300,
                            lr=0.1)
    mse_noisy = float(reconstruction_mse(x_true, x_rec_n))
    assert mse_noisy > mse_clean


def test_asr_metric():
    x = jnp.zeros((4, 8))
    rec = x.at[0].set(1.0)
    asr = attack_success_rate(x, rec, mse_threshold=0.5)
    assert float(asr) == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# datacenter fed_train_step
# ---------------------------------------------------------------------------

def test_fed_step_learns_lm():
    cfg = get_smoke_config("qwen1.5-0.5b").replace(
        n_layers=2, d_model=64, d_ff=128, vocab=64, attn_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fcfg = FedStepConfig(n_nodes=4, local_steps=2, lr=0.1, sigma=1e-4,
                         detect=True)
    lfn = lambda p, b: model_loss_fn(p, cfg, b)
    afn = lambda p, b: model_loss_fn(p, cfg, b)[1]["accuracy"]

    from repro.data.synthetic import make_token_dataset
    data = make_token_dataset(0, 128, 16, cfg.vocab)
    rng = np.random.default_rng(0)

    def batch(lead):
        n = int(np.prod(lead))
        idx = rng.integers(0, data.shape[0], n)
        return {"tokens": jnp.asarray(data[idx, :16].reshape(lead + (16,))),
                "targets": jnp.asarray(data[idx, 1:17].reshape(lead + (16,)))}

    step = jax.jit(lambda p, nb, eb, k: fed_train_step(
        p, nb, eb, k, loss_fn=lfn, acc_fn=afn, fcfg=fcfg))
    key = jax.random.PRNGKey(1)
    losses = []
    for r in range(6):
        key, k = jax.random.split(key)
        params, m = step(params, batch((4, 2, 4)), batch((2,)), k)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(m["n_normal"]) >= 1


def test_fed_step_alpha_zero_keeps_global():
    cfg = get_smoke_config("olmo-1b").replace(n_layers=1, d_model=32,
                                              d_ff=64, vocab=32, attn_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fcfg = FedStepConfig(n_nodes=2, local_steps=1, lr=0.1, sigma=0.0,
                         alpha=1.0, detect=False)
    lfn = lambda p, b: model_loss_fn(p, cfg, b)
    toks = jnp.zeros((2, 1, 2, 8), jnp.int32)
    nb = {"tokens": toks, "targets": toks}
    new, _ = fed_train_step(params, nb, None, jax.random.PRNGKey(1),
                            loss_fn=lfn, acc_fn=None, fcfg=fcfg)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
