"""Async fleet engine tests: event-loop parity, streaming detection,
staleness-aware mixing, window accounting, async scenarios."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (detection_threshold, mix_stale, mix_stale_sequence,
                        ring_detect, ring_init, ring_push, ring_threshold)
from repro.data import make_federated_image_data
from repro.fleet import (build_async_engine, chain_node_keys,
                         chain_node_keys_masked, get_scenario)
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss


# ---------------------------------------------------------------------------
# streaming detection ring ≡ the event loop's Python acc_window list
# ---------------------------------------------------------------------------

def test_ring_matches_list_window():
    rng = np.random.default_rng(0)
    accs = rng.uniform(0.1, 0.9, 23).astype(np.float32)
    window, warmup, s = 7, 4, 80.0
    ring, count = ring_init(window)
    acc_list = []
    for a in accs:
        ring, count = ring_push(ring, count, jnp.float32(a))
        acc_list.append(float(a))
        acc_list = acc_list[-window:]
        thr_ref = float(detection_threshold(jnp.asarray(acc_list), s))
        assert float(ring_threshold(ring, count, s)) == \
            pytest.approx(thr_ref, abs=1e-6)
        rej_ref = len(acc_list) >= warmup and float(a) <= thr_ref
        rej = bool(ring_detect(ring, count, jnp.float32(a), s, warmup))
        assert rej == rej_ref


def test_ring_warmup_blocks_detection():
    ring, count = ring_init(8)
    ring, count = ring_push(ring, count, jnp.float32(0.0))
    # one observation: even a terrible accuracy is not rejected yet
    assert not bool(ring_detect(ring, count, jnp.float32(0.0), 80.0, 4))


def test_ring_warmup_larger_than_window_never_detects():
    """The event loop caps its acc_window at the window length before the
    warmup check, so warmup > window disables detection; the ring must
    gate on occupancy (min(count, window)), not total pushes."""
    ring, count = ring_init(8)
    for v in np.linspace(0.1, 0.9, 30):
        ring, count = ring_push(ring, count, jnp.float32(v))
        assert not bool(ring_detect(ring, count, jnp.float32(v), 80.0, 20))


# ---------------------------------------------------------------------------
# masked PRNG chain
# ---------------------------------------------------------------------------

def test_chain_node_keys_masked_skips_masked_slots():
    key = jax.random.PRNGKey(3)
    mask = jnp.array([True, False, True, True, False])
    kend, k1s, k2s = chain_node_keys_masked(key, mask)
    # reference: plain chain over only the True slots
    kref, k1r, k2r = chain_node_keys(key, 3)
    np.testing.assert_array_equal(np.asarray(kend), np.asarray(kref))
    on = [0, 2, 3]
    for j, i in enumerate(on):
        np.testing.assert_array_equal(np.asarray(k1s[i]), np.asarray(k1r[j]))
        np.testing.assert_array_equal(np.asarray(k2s[i]), np.asarray(k2r[j]))


# ---------------------------------------------------------------------------
# staleness-aware sequential mixing
# ---------------------------------------------------------------------------

def test_mix_stale_sequence_matches_sequential_application():
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (4, 3)), "b": jnp.ones((3,))}
    stack = {"w": jax.random.normal(key, (6, 4, 3)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (6, 3))}
    taus = jnp.array([0, 3, 1, 7, 2, 0])
    final, snaps = mix_stale_sequence(tree, stack, taus, alpha=0.5)
    ref = tree
    for i in range(6):
        ref = mix_stale(ref, jax.tree.map(lambda x: x[i], stack), 0.5,
                        int(taus[i]))
        for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[i], snaps)),
                        jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_mix_stale_sequence_gate_skips_arrivals():
    tree = {"w": jnp.zeros((2,))}
    stack = {"w": jnp.ones((3, 2))}
    gate = jnp.array([True, False, True])
    final, _ = mix_stale_sequence(tree, stack, jnp.zeros(3, jnp.int32), 0.5,
                                  gate=gate)
    ref, _ = mix_stale_sequence(tree, {"w": jnp.ones((2, 2))},
                                jnp.zeros(2, jnp.int32), 0.5)
    np.testing.assert_allclose(np.asarray(final["w"]), np.asarray(ref["w"]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# engine ≡ sequential event loop (the acceptance bar)
# ---------------------------------------------------------------------------

def _paired_async_runs(sigma, sparsify, staleness_adaptive=False):
    """((fleet report, fleet state), (reference report, reference state))
    for one async scheme — the seed per-arrival event loop
    (`Topology('sequential')`) is the parity oracle."""
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=8, n_malicious=2, n_train=640, n_test=256,
        n_cloud_test=128, hw=(8, 8))

    def run(topology):
        from repro.fleet import NodeProfile
        spec = api.ExperimentSpec(
            fleet=api.FleetSpec(n_nodes=8),
            schedule=api.SchedulePolicy(
                kind="async", staleness_adaptive=staleness_adaptive),
            privacy=api.PrivacySpec(sigma=sigma),
            compression=api.CompressionSpec(sparsify_ratio=sparsify),
            defense=api.DefenseSpec(detect=True),
            topology=api.Topology(kind=topology),
            train=api.TrainSpec(local_steps=8, batch_size=16, lr=0.1),
            rounds=4, seed=0)
        plan = api.compile_plan(spec)
        pop = api.Population(
            params=init_mlp(jax.random.PRNGKey(0), 64), loss_fn=mlp_loss,
            acc_fn=mlp_accuracy, node_data=node_data, test_data=test,
            cloud_test=cloud,
            profile=NodeProfile.lognormal(8, 1.0, 0.5, 12.5e6, seed=0))
        state = api.init_state(plan, pop)
        api.execute(plan, pop, state)
        comm = sum(r.comm_time for r in state.history)
        comp = sum(r.comp_time for r in state.history)
        eps = (state.accountant.epsilon(spec.privacy.delta)
               if state.accountant is not None else 0.0)
        from repro.core.async_update import communication_efficiency
        rep = api.RunReport(
            mode=plan.mode, engine=plan.engine, records=state.history,
            kappa=communication_efficiency(comm, comp), epsilon_spent=eps,
            final_accuracy=state.history[-1].accuracy,
            final_params=state.params)
        return rep, state

    return run("single"), run("sequential")


@pytest.mark.parametrize("sigma,sparsify,stale", [
    (0.0, 1.0, False),      # plain async + detection (afl)
    (0.05, 1.0, False),     # + LDP noise, shared PRNG chain (aldpfl)
    (0.05, 0.25, False),    # + DGC sparsified uploads
    (0.0, 1.0, True),       # staleness-adaptive mixing
])
def test_async_fleet_matches_event_loop(sigma, sparsify, stale):
    (fleet_rep, _), (seq_rep, _) = _paired_async_runs(sigma, sparsify, stale)
    hf, hs = fleet_rep.records, seq_rep.records
    for a, b in zip(jax.tree.leaves(fleet_rep.final_params),
                    jax.tree.leaves(seq_rep.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # same record cadence (one per n_nodes arrivals) and same trajectory
    assert len(hf) == len(hs)
    np.testing.assert_allclose([r.accuracy for r in hf],
                               [r.accuracy for r in hs], atol=2e-3)
    np.testing.assert_allclose([r.t for r in hf], [r.t for r in hs],
                               rtol=1e-5)
    assert [r.comm_bytes for r in hf] == [r.comm_bytes for r in hs]
    assert [r.n_rejected for r in hf] == [r.n_rejected for r in hs]
    assert fleet_rep.epsilon_spent == pytest.approx(seq_rep.epsilon_spent)


def test_async_fleet_key_chain_hand_back():
    """After a fleet-async run the handed-back PRNG key equals the event
    loop's, so follow-on work stays faithful."""
    (_, fleet_state), (_, seq_state) = _paired_async_runs(0.0, 1.0)
    np.testing.assert_array_equal(np.asarray(fleet_state.key),
                                  np.asarray(seq_state.key))


# ---------------------------------------------------------------------------
# async metrics accounting (the comm_bytes/kappa fix)
# ---------------------------------------------------------------------------

def _total_bytes(kind, topology):
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=6, n_malicious=0, n_train=360, n_test=128,
        n_cloud_test=64, hw=(8, 8))
    from repro.fleet import NodeProfile
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=6),
        schedule=api.SchedulePolicy(kind=kind),
        defense=api.DefenseSpec(detect=False),
        topology=api.Topology(kind=topology),
        train=api.TrainSpec(local_steps=4, batch_size=16, lr=0.1),
        rounds=3, seed=0)
    pop = api.Population(
        params=init_mlp(jax.random.PRNGKey(0), 64), loss_fn=mlp_loss,
        acc_fn=mlp_accuracy, node_data=node_data, test_data=test,
        cloud_test=cloud,
        profile=NodeProfile.lognormal(6, 1.0, 0.5, 12.5e6, seed=0))
    rep = api.run(api.compile_plan(spec), pop)
    return sum(r.comm_bytes for r in rep.records), rep


@pytest.mark.parametrize("topology", ["single", "sequential"])
def test_async_total_bytes_match_sync(topology):
    """rounds×n_nodes arrivals at sparsify=1 move exactly as many bytes as
    rounds synchronous cohorts — the old per-record accounting understated
    async traffic by ~n_nodes×."""
    async_bytes, async_rep = _total_bytes("async", topology)
    sync_bytes, _ = _total_bytes("sync", topology)
    assert async_bytes == sync_bytes
    # kappa now reflects per-arrival comp/comm totals, not the last arrival
    assert 0.0 < async_rep.kappa < 1.0


def test_plan_detection_window_defaults():
    def window(n_nodes, **defense_kw):
        spec = api.ExperimentSpec(
            fleet=api.FleetSpec(n_nodes=n_nodes),
            schedule=api.SchedulePolicy(kind="async"),
            defense=api.DefenseSpec(detect=True, **defense_kw))
        return api.compile_plan(spec).detect_window

    assert window(10) == 10
    assert window(2) == 4
    assert window(10, detect_window=6) == 6
    assert api.DefenseSpec().detect_warmup == 4


# ---------------------------------------------------------------------------
# staleness under stragglers at fleet scale
# ---------------------------------------------------------------------------

def test_straggler_profile_grows_staleness():
    """A straggler's dispatched model ages while fast nodes keep mixing:
    max τ under a straggler tail must exceed the homogeneous fleet's."""
    sc = get_scenario("async_stragglers").with_nodes(12)
    slow = build_async_engine(sc, seed=0)
    fast = build_async_engine(
        dataclasses.replace(sc, straggler_frac=0.0, heterogeneity=0.0),
        seed=0)
    slow.run_arrivals(48)
    fast.run_arrivals(48)
    tau_slow = max(r.max_staleness for r in slow.history)
    tau_fast = max(r.max_staleness for r in fast.history)
    assert tau_slow > tau_fast, (tau_slow, tau_fast)
    assert tau_slow >= 12          # the straggler misses >= one full fleet pass


def test_staleness_adaptive_discounts_stale_arrivals():
    """mix_stale with growing τ shrinks the new-model weight (FedAsync)."""
    from repro.core.async_update import staleness_alpha
    w0 = float(staleness_alpha(0.5, 0))
    w9 = float(staleness_alpha(0.5, 9))
    assert w0 == pytest.approx(0.5) and w9 < w0 / 3


# ---------------------------------------------------------------------------
# window semantics
# ---------------------------------------------------------------------------

def test_auto_window_preserves_arrival_order():
    """With window=None no processed node can re-arrive inside the window:
    every window's arrivals all precede the next window's."""
    eng = build_async_engine(get_scenario("honest").with_nodes(10), seed=0)
    ends = []
    for _ in range(6):
        na_before = np.asarray(eng.state.next_arrival, np.float64)
        order, proc = eng.select_window()
        ts = na_before[order[proc]]
        eng.run_window()
        ends.append((ts.min(), ts.max()))
    for (lo1, hi1), (lo2, hi2) in zip(ends, ends[1:]):
        assert hi1 <= lo2 + 1e-6, (hi1, lo2)


def test_run_arrivals_truncates_final_window():
    eng = build_async_engine(get_scenario("honest").with_nodes(8), seed=0)
    eng.run_arrivals(11)
    assert sum(r.n_processed for r in eng.history) == 11


def test_buffered_mixing_runs_and_learns():
    sc = dataclasses.replace(get_scenario("async_buffered"), local_steps=10,
                             lr=0.2)
    eng = build_async_engine(sc, seed=0)
    eng.run_arrivals(60)
    assert eng.history[-1].accuracy > eng.history[0].accuracy + 0.1, \
        [r.accuracy for r in eng.history]
    # buffered mode bumps the version once per non-empty window
    assert eng.state is not None
    assert int(eng.state.version) <= len(eng.history)


def test_async_scenarios_build_and_run():
    for name in ("async_stragglers", "async_churn", "async_label_flip",
                 "async_buffered"):
        eng = build_async_engine(get_scenario(name).with_nodes(8), seed=0)
        recs = eng.run(2)
        assert len(recs) == 2
        assert all(0.0 <= r.accuracy <= 1.0 for r in recs)


def test_async_churn_drops_arrivals():
    """Unavailable nodes' uploads are lost: fewer mixes than arrivals."""
    eng = build_async_engine(get_scenario("async_churn").with_nodes(10),
                             seed=0)
    eng.run_arrivals(30)
    processed = sum(r.n_processed for r in eng.history)
    assert processed == 30
    # version counts accepted mixes only; churn must have dropped some
    assert int(eng.state.version) < processed


def test_async_cohort_sampler_gates_arrivals():
    """Any ClientSampler works: a UniformSampler cohort maps to per-node
    availability, dropping arrivals from unsampled nodes that window."""
    from repro.fleet import UniformSampler
    sc = get_scenario("sampled_cohort").with_nodes(12)   # cohort_frac=0.2
    eng = build_async_engine(sc, seed=0)
    assert isinstance(eng.sampler, UniformSampler)
    eng.run_arrivals(24)
    processed = sum(r.n_processed for r in eng.history)
    assert processed == 24
    assert int(eng.state.version) < processed   # unsampled arrivals dropped


def test_async_detection_rejects_malicious_nodes():
    eng = build_async_engine(get_scenario("async_label_flip").with_nodes(10),
                             seed=0)
    eng.run_arrivals(40)
    assert sum(r.n_rejected for r in eng.history) > 0


def test_async_engine_rejects_bad_window():
    sc = get_scenario("honest").with_nodes(4)
    with pytest.raises(ValueError, match="window"):
        build_async_engine(dataclasses.replace(sc, async_window=-1.0), seed=0)
