"""Mesh-sharded fleet tests: padding/placement helpers, single-host mesh
parity, and the multi-device parity suite run in a subprocess with 8 forced
host devices (sharded sync rounds and async windows must float-close the
single-device engines at n=64, including uneven n % n_devices != 0)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import FleetData, FleetMesh, pad_keys, pad_node_axis

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# mesh + padding helpers (single device is a valid 1-mesh)
# ---------------------------------------------------------------------------

def test_fleet_mesh_create_and_padding():
    mesh = FleetMesh.create()
    assert mesh.n_devices == len(jax.devices())
    d = mesh.n_devices
    assert mesh.padded(d) == d
    assert mesh.padded(d + 1) == 2 * d
    assert mesh.padded(1) == d


def test_fleet_mesh_too_many_devices_raises():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        FleetMesh.create(len(jax.devices()) + 1)


def test_fleet_data_pad_to_adds_dummy_nodes():
    fd = FleetData.from_node_data(
        [(np.ones((3, 2), np.float32), np.ones(3, np.int32))] * 2)
    padded = fd.pad_to(5)
    assert padded.x.shape == (5, 3, 2)
    np.testing.assert_array_equal(np.asarray(padded.sizes), [3, 3, 1, 1, 1])
    assert float(padded.x[2:].sum()) == 0.0
    with pytest.raises(ValueError, match="already has"):
        fd.pad_to(1)


def test_pad_node_axis_and_keys():
    tree = {"w": jnp.ones((3, 4)), "b": jnp.ones((3,))}
    p = pad_node_axis(tree, 8)
    assert p["w"].shape == (8, 4) and p["b"].shape == (8,)
    assert float(p["w"][3:].sum()) == 0.0
    with pytest.raises(ValueError):
        pad_node_axis(tree, 2)

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    kp = pad_keys(ks, 6)
    assert kp.shape[0] == 6
    np.testing.assert_array_equal(np.asarray(kp[3]), np.asarray(ks[2]))


# ---------------------------------------------------------------------------
# sharded engines on the host's own mesh (1 device in plain tier-1; the CI
# matrix job re-runs this file with 8 forced host devices)
# ---------------------------------------------------------------------------

def _diff_params(a, b):
    return max(float(jnp.abs(x - y).max()) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _population(n):
    from repro.data import make_federated_image_data
    from repro.models.mlp import init_mlp
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=n, n_malicious=2, n_train=40 * n, n_test=128,
        n_cloud_test=64, hw=(8, 8))
    return init_mlp(jax.random.PRNGKey(0), 64), node_data, test, cloud


def test_sharded_sync_engine_matches_unsharded_on_host_mesh():
    """n=10 is uneven against any multi-device host mesh. key_mode
    "sequential" makes parity exact regardless of padding (the chain is
    consumed per real node)."""
    from repro.fleet import FleetConfig, FleetEngine
    from repro.models.mlp import mlp_accuracy, mlp_loss
    params, node_data, test, cloud = _population(10)
    cfg = FleetConfig(local_steps=3, batch_size=16, lr=0.1, detect=True,
                      key_mode="sequential", seed=0)
    args = (params, mlp_loss, mlp_accuracy, node_data, test, cloud, cfg)
    ref = FleetEngine(*args)
    sh = FleetEngine(*args, mesh=FleetMesh.create())
    hr = ref.run(2)
    hs = sh.run(2)
    np.testing.assert_allclose([r.accuracy for r in hr],
                               [r.accuracy for r in hs], atol=2e-3)
    assert [r.n_rejected for r in hr] == [r.n_rejected for r in hs]
    assert _diff_params(ref.params, sh.params) < 1e-5


def test_sharded_async_engine_matches_unsharded_on_host_mesh():
    from repro.fleet import AsyncFleetConfig, AsyncFleetEngine
    from repro.models.mlp import mlp_accuracy, mlp_loss
    params, node_data, test, cloud = _population(10)
    cfg = AsyncFleetConfig(local_steps=3, batch_size=16, lr=0.1, detect=True,
                           key_mode="sequential", seed=0, detect_window=10)
    args = (params, mlp_loss, mlp_accuracy, node_data, test, cloud, cfg)
    ref = AsyncFleetEngine(*args)
    sh = AsyncFleetEngine(*args, mesh=FleetMesh.create())
    ref.run_arrivals(20)
    sh.run_arrivals(20)
    assert int(ref.state.version) == int(sh.state.version)
    assert _diff_params(ref.params, sh.params) < 1e-5


# ---------------------------------------------------------------------------
# the 8-device parity suite (subprocess, forced host platform device count —
# pattern from test_system.py)
# ---------------------------------------------------------------------------

def test_sharded_parity_on_8_devices_in_subprocess():
    """Sharded sync round + async window float-close the single-device
    engines at n=64 on an 8-device host mesh, including the uneven padded
    case (n=61, 61 % 8 != 0)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np
        from repro.fleet import (AsyncFleetConfig, AsyncFleetEngine,
                                 FleetConfig, FleetEngine, FleetMesh,
                                 FullParticipation, NodeProfile)
        from repro.data import make_federated_image_data
        from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

        def diff(a, b):
            return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
                       for x, y in zip(jax.tree.leaves(a),
                                       jax.tree.leaves(b)))

        def population(n):
            node_data, test, cloud, _ = make_federated_image_data(
                0, n_nodes=n, n_malicious=n // 5, n_train=40 * n,
                n_test=256, n_cloud_test=128, hw=(8, 8))
            profile = NodeProfile.lognormal(n, 1.0, 0.5, 12.5e6, seed=0)
            params = init_mlp(jax.random.PRNGKey(0), 64)
            return params, node_data, test, cloud, profile

        out = {"n_devices": len(jax.devices())}
        mesh = FleetMesh.create(8)
        for n in (64, 61):                     # even and uneven padding
            params, node_data, test, cloud, profile = population(n)
            cfg = FleetConfig(local_steps=4, batch_size=16, lr=0.1,
                              detect=True, sigma=0.05, sparsify_ratio=0.5,
                              key_mode="sequential", seed=0)
            args = (params, mlp_loss, mlp_accuracy, node_data, test, cloud,
                    cfg)
            ref = FleetEngine(*args, profile=profile,
                              sampler=FullParticipation())
            sh = FleetEngine(*args, profile=profile,
                             sampler=FullParticipation(), mesh=mesh)
            hr, hs = ref.run(3), sh.run(3)
            out[f"sync{n}_acc"] = max(abs(a.accuracy - b.accuracy)
                                      for a, b in zip(hr, hs))
            out[f"sync{n}_rej"] = int(sum(a.n_rejected != b.n_rejected
                                          for a, b in zip(hr, hs)))
            out[f"sync{n}_params"] = diff(ref.params, sh.params)

            acfg = AsyncFleetConfig(local_steps=4, batch_size=16, lr=0.1,
                                    detect=True, sigma=0.05,
                                    sparsify_ratio=0.5,
                                    key_mode="sequential", seed=0,
                                    detect_window=max(n, 4))
            aargs = (params, mlp_loss, mlp_accuracy, node_data, test, cloud,
                     acfg)
            aref = AsyncFleetEngine(*aargs, profile=profile)
            ash = AsyncFleetEngine(*aargs, profile=profile, mesh=mesh)
            aref.run_arrivals(2 * n)
            ash.run_arrivals(2 * n)
            out[f"async{n}_version"] = abs(int(aref.state.version)
                                           - int(ash.state.version))
            out[f"async{n}_params"] = diff(aref.params, ash.params)
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)          # the child forces its own devices
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    for n in (64, 61):
        assert rec[f"sync{n}_acc"] < 2e-3, rec
        assert rec[f"sync{n}_rej"] == 0, rec
        assert rec[f"sync{n}_params"] < 1e-5, rec
        assert rec[f"async{n}_version"] == 0, rec
        assert rec[f"async{n}_params"] < 1e-4, rec
