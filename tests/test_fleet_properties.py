"""Hypothesis property tests for the fleet stacking/indexing layer
(`repro.fleet.state`) and the streaming detection ring — skipped cleanly
when hypothesis is absent (see tests/_optional.py)."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # hypothesis, optional

from repro.core import detection
from repro.fleet import (chain_node_keys, chain_node_keys_masked,
                         gather_nodes, scatter_nodes)


# ---------------------------------------------------------------------------
# gather/scatter round-trip — including duplicate (padded) cohort indices
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(st.integers(2, 12),
       st.lists(st.integers(0, 11), min_size=1, max_size=20),
       st.integers(0, 10_000))
def test_gather_scatter_roundtrip_with_duplicates(n, raw_idx, seed):
    """Scattering back exactly what was gathered is the identity, even when
    the cohort repeats node indices (padded cohorts): duplicated slots are
    identical copies by construction, so last-write-wins is harmless."""
    idx = jnp.asarray([i % n for i in raw_idx], jnp.int32)
    key = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(key, (n, 3)),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(seed + 1),
                                         (n, 2, 2))}}
    cohort = gather_nodes(tree, idx)
    back = scatter_nodes(tree, idx, cohort, debug=True)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 12),
       st.lists(st.integers(0, 11), min_size=2, max_size=20),
       st.integers(0, 10_000))
def test_scatter_overwrites_exactly_the_indexed_rows(n, raw_idx, seed):
    """Rows named by idx end up holding the (shared) new value; every other
    row is untouched."""
    idx_h = np.asarray([i % n for i in raw_idx], np.int32)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(seed), (n, 4))}
    new_rows = jnp.zeros((len(idx_h), 4)) + 7.0
    out = scatter_nodes(tree, jnp.asarray(idx_h), {"w": new_rows},
                        debug=True)
    out_h = np.asarray(out["w"])
    ref = np.asarray(tree["w"]).copy()
    ref[idx_h] = 7.0
    np.testing.assert_array_equal(out_h, ref)


# ---------------------------------------------------------------------------
# masked PRNG chain ≡ plain chain on an all-True mask
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.integers(1, 24), st.integers(0, 10_000))
def test_chain_masked_all_true_equals_plain_chain(n, seed):
    key = jax.random.PRNGKey(seed)
    ke, k1, k2 = chain_node_keys(key, n)
    km, m1, m2 = chain_node_keys_masked(key, jnp.ones(n, bool))
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(km))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(m2))


@settings(deadline=None, max_examples=20)
@given(st.lists(st.booleans(), min_size=1, max_size=24),
       st.integers(0, 10_000))
def test_chain_masked_advances_only_on_true_slots(mask, seed):
    """The end key after a masked chain equals a plain chain over just the
    True slots — masked-out slots must leave the chain untouched."""
    key = jax.random.PRNGKey(seed)
    ke, _, _ = chain_node_keys_masked(key, jnp.asarray(mask))
    k = key
    for _ in range(sum(mask)):
        k, _, _ = jax.random.split(k, 3)
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(k))


# ---------------------------------------------------------------------------
# streaming detection ring ≡ a Python deque reference
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(st.integers(1, 9),
       st.lists(st.floats(0.0, 1.0, width=32), min_size=1, max_size=30),
       st.integers(1, 12))
def test_ring_matches_deque_reference(window, values, warmup):
    """`ring_push`/`ring_threshold`/`ring_detect` must track a plain
    bounded deque across arbitrary push sequences."""
    s = 80.0
    ring, count = detection.ring_init(window)
    dq = collections.deque(maxlen=window)
    for v in values:
        ring, count = detection.ring_push(ring, count, jnp.float32(v))
        dq.append(np.float32(v))
        thr_ref = float(detection.detection_threshold(
            jnp.asarray(list(dq)), s))
        assert float(detection.ring_threshold(ring, count, s)) == \
            pytest.approx(thr_ref, abs=1e-6)
        rej_ref = len(dq) >= warmup and np.float32(v) <= np.float32(thr_ref)
        rej = bool(detection.ring_detect(ring, count, jnp.float32(v), s,
                                         warmup))
        assert rej == bool(rej_ref), (v, list(dq), thr_ref)
