"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ldp_noise import ldp_perturb_flat
from repro.kernels.ops import (aldp_perturb_pallas, attention_pallas,
                               sparsify_pallas)
from repro.kernels.ref import (flash_attention_ref, ldp_perturb_flat_ref,
                               selective_scan_ref, sparsify_flat_ref,
                               ssd_scan_ref)
from repro.kernels.selective_scan import selective_scan
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.sparsify import sparsify_flat
from repro.core.aldp import aldp_perturb, clip_by_global_norm
from repro.core.accumulator import accumulate_and_sparsify, init_residual

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,KV,Sq,Sk,D", [
    (1, 4, 2, 64, 64, 32),
    (2, 2, 2, 33, 47, 16),      # ragged, needs padding
    (1, 4, 1, 128, 128, 64),    # MQA
    (1, 8, 8, 96, 96, 32),      # MHA
    (2, 6, 2, 40, 72, 8),       # small head dim
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(B, H, KV, Sq, Sk, D, causal):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, Sk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, Sk, D), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    o_ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 96, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 96, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 96, 32), jnp.float32)
    o = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=32)
    o_ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(dtype)
    o = flash_attention(q, k, v, bq=32, bk=32)
    o_ref = flash_attention_ref(q, k, v)
    assert o.dtype == dtype
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_attention_pallas_model_layout():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 16))
    k = jax.random.normal(ks[1], (2, 48, 2, 16))
    v = jax.random.normal(ks[2], (2, 48, 2, 16))
    o = attention_pallas(q, k, v, causal=True)
    from repro.models.attention import attention as jnp_attention
    o_ref = jnp_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ldp_noise kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [100, 1024, 4097, 300000])
def test_ldp_kernel_deterministic_path(n):
    g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    out = ldp_perturb_flat(g, jnp.int32(3), jnp.float32(0.25), 0.0, 1.0)
    ref = ldp_perturb_flat_ref(g, jnp.float32(0.25), None, 0.0, 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_ldp_kernel_noise_statistics():
    out = ldp_perturb_flat(jnp.zeros(500000), jnp.int32(11), jnp.float32(1.0),
                           0.3, 2.0)
    x = np.asarray(out)
    assert abs(x.mean()) < 5e-3
    assert abs(x.std() - 0.6) < 5e-3
    kurt = ((x - x.mean()) ** 4).mean() / x.std() ** 4
    assert abs(kurt - 3.0) < 0.1          # gaussianity
    out2 = ldp_perturb_flat(jnp.zeros(500000), jnp.int32(12), jnp.float32(1.0),
                            0.3, 2.0)
    assert abs(float(np.corrcoef(x, np.asarray(out2))[0, 1])) < 0.01


def test_ldp_ops_matches_core_clipping():
    key = jax.random.PRNGKey(4)
    tree = {"a": jax.random.normal(key, (64, 32)) * 5,
            "b": jax.random.normal(key, (100,))}
    pk, nrm_k = aldp_perturb_pallas(tree, jnp.int32(0), sigma=0.0, clip_s=0.7)
    pc, nrm_c = clip_by_global_norm(tree, 0.7)
    assert float(abs(nrm_k - nrm_c)) < 1e-3
    for a, b in zip(jax.tree.leaves(pk), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


# ---------------------------------------------------------------------------
# sparsify kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 1025, 50000])
@pytest.mark.parametrize("thr", [0.0, 0.5, 2.0])
def test_sparsify_kernel_exact(n, thr):
    key = jax.random.PRNGKey(5)
    g = jax.random.normal(key, (n,), jnp.float32)
    r = jax.random.normal(jax.random.PRNGKey(6), (n,), jnp.float32)
    up, nr = sparsify_flat(g, r, jnp.float32(thr))
    upr, nrr = sparsify_flat_ref(g, r, jnp.float32(thr))
    np.testing.assert_allclose(np.asarray(up), np.asarray(upr), atol=1e-7)
    np.testing.assert_allclose(np.asarray(nr), np.asarray(nrr), atol=1e-7)


# ---------------------------------------------------------------------------
# selective_scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,D,N,bl,bd", [
    (2, 32, 16, 4, 8, 8),
    (1, 50, 24, 8, 16, 16),     # ragged L/D, needs padding
    (2, 64, 64, 16, 32, 32),
    (1, 33, 8, 16, 64, 64),     # blocks larger than dims
])
def test_selective_scan_vs_ref(B, L, D, N, bl, bd):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D))) * 0.1
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(key, (D, N)) * 0.2)
    y, h = selective_scan(x, dt, Bm, Cm, A, block_l=bl, block_d=bd)
    yr, hr = selective_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_matches_model_ssm():
    """Kernel math == the model's chunked mamba1 recurrence (pre-gating)."""
    from repro.models.ssm import _m1_scan_chunk
    key = jax.random.PRNGKey(1)
    B, L, D, N = 1, 16, 8, 4
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, D))) * 0.1
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(key, (D, N)) * 0.2)
    y, h = selective_scan(x, dt, Bm, Cm, A, block_l=8, block_d=8)
    la = dt[..., None] * A
    bx = (dt * x)[..., None] * Bm[:, :, None, :]
    h_all, h_last = _m1_scan_chunk(jnp.zeros((B, D, N)), la, bx)
    y_model = jnp.einsum("bldn,bln->bld", h_all, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan kernel (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,P,N,c,bh", [
    (1, 32, 4, 8, 16, 8, 2),
    (2, 48, 8, 16, 8, 16, 4),
    (1, 50, 6, 8, 32, 64, 8),    # ragged L/H, blocks > dims
])
def test_ssd_scan_vs_ref(B, L, H, P, N, c, bh):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.2
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(key, (H,)) * 0.3)
    y, h = ssd_scan(x, dt, Bm, Cm, A, chunk=c, block_h=bh)
    yr, hr = ssd_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=5e-4, atol=5e-4)


def test_ssd_scan_matches_model_mamba2():
    """Kernel == the model's one-token mamba2 recurrence iterated."""
    from repro.models.ssm import mamba2_fwd
    # compare against the model's chunked path by building equivalent inputs
    key = jax.random.PRNGKey(2)
    B, L, H, P, N = 1, 16, 4, 8, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.2
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    A = -jnp.exp(jax.random.normal(key, (H,)) * 0.3)
    y_k, h_k = ssd_scan(x, dt, Bm, Cm, A, chunk=4, block_h=4)
    y_r, h_r = ssd_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)


def test_sparsify_ops_matches_accumulator():
    key = jax.random.PRNGKey(7)
    g = {"w": jax.random.normal(key, (400,)), "b": jax.random.normal(key, (30,))}
    r = init_residual(g)
    up_k, r_k = sparsify_pallas(g, r, ratio=0.2)
    up_j, r_j, frac = accumulate_and_sparsify(r, g, 0.2)
    # same keep-fraction and conservation; thresholds computed identically
    kept_k = sum(float((jnp.asarray(u) != 0).sum()) for u in jax.tree.leaves(up_k))
    kept_j = sum(float((jnp.asarray(u) != 0).sum()) for u in jax.tree.leaves(up_j))
    assert abs(kept_k - kept_j) <= 2
    tot_k = jax.tree.map(lambda a, b: a + b, up_k, r_k)
    tot_in = jax.tree.map(lambda a, b: a + b, g, r)
    for x, y in zip(jax.tree.leaves(tot_k), jax.tree.leaves(tot_in)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
