"""Additional hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # hypothesis, optional

from repro.configs import get_smoke_config
from repro.core.detection import detect, masked_mean
from repro.models import forward, init_params
from repro.models.layers import apply_rope, rope_angles


# ---------------------------------------------------------------------------
# RoPE: relative-position property — dot(q_m, k_n) depends only on m − n
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=15)
@given(st.integers(0, 40), st.integers(0, 40), st.integers(1, 30))
def test_rope_relative_position_invariance(m, n, shift):
    D = 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def dot_at(pm, pn):
        am = rope_angles(jnp.array([[pm]]), D, 1e4)
        an = rope_angles(jnp.array([[pn]]), D, 1e4)
        return float((apply_rope(q, am) * apply_rope(k, an)).sum())

    d1 = dot_at(m, n)
    d2 = dot_at(m + shift, n + shift)
    assert abs(d1 - d2) < 1e-3 * max(1.0, abs(d1))


# ---------------------------------------------------------------------------
# Detection: permutation equivariance and mask size monotonicity in s
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000))
def test_detection_permutation_equivariant(seed):
    key = jax.random.PRNGKey(seed)
    accs = jax.random.uniform(key, (12,))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 12)
    m1, _ = detect(accs, 70.0)
    m2, _ = detect(accs[perm], 70.0)
    np.testing.assert_array_equal(np.asarray(m1)[np.asarray(perm)],
                                  np.asarray(m2))


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10_000))
def test_detection_stricter_s_fewer_nodes(seed):
    key = jax.random.PRNGKey(seed)
    accs = jax.random.uniform(key, (16,))
    sizes = [int(detect(accs, s)[0].sum()) for s in (10, 50, 90)]
    assert sizes[0] >= sizes[1] >= sizes[2] >= 1


# ---------------------------------------------------------------------------
# masked_mean: convexity — result stays inside the per-node value range
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10_000))
def test_masked_mean_within_hull(seed):
    key = jax.random.PRNGKey(seed)
    vals = jax.random.normal(key, (6, 5))
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.6, (6,))
    mask = mask.at[0].set(True)
    out = masked_mean({"w": vals}, mask)["w"]
    sel = np.asarray(vals)[np.asarray(mask)]
    assert (np.asarray(out) <= sel.max(0) + 1e-6).all()
    assert (np.asarray(out) >= sel.min(0) - 1e-6).all()


# ---------------------------------------------------------------------------
# Flash attention wired into the model path (use_flash)
# ---------------------------------------------------------------------------

def test_model_use_flash_matches_jnp_path():
    cfg = get_smoke_config("qwen1.5-0.5b").replace(attn_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    l_jnp, _ = forward(params, cfg, batch)
    l_flash, _ = forward(params, cfg.replace(use_flash=True), batch)
    np.testing.assert_allclose(np.asarray(l_flash), np.asarray(l_jnp),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# α-mix is a contraction toward the new model (Theorem 6 structure)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.floats(0.05, 0.95), st.integers(0, 1000))
def test_mix_contraction(alpha, seed):
    from repro.core.async_update import mix
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (8,))}
    n = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (8,))}
    out = mix(g, n, alpha)
    d_before = float(jnp.linalg.norm(g["w"] - n["w"]))
    d_after = float(jnp.linalg.norm(out["w"] - n["w"]))
    assert d_after <= alpha * d_before + 1e-5
