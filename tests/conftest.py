import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # for tests/_optional.py

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
