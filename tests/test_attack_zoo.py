"""Adversary zoo + trust-scored detection acceptance suite.

Covers the PR's attack/defense surface end to end:

* `compile_plan` validation of the grown `AttackMix`/`DefenseSpec`
  (flip-label ranges, kind/placement enums, ddos's network requirement,
  trust_weighted's detect requirement) and the new plan stage names;
* the attack-path bugfixes: seeded-random malicious placement (with the
  legacy first-k default preserved for direct data callers), the
  `net.link` bandwidth positivity guard, and the `detect` all-equal
  fallback (pinned + surfaced as the ``detect.fallback`` obs counter);
* per-attack unit semantics (trigger stamping, sybil boost, adaptive
  throttling, per-kind data poisoning) and the ASR metrics;
* sybil cohort collusion inside one async arrival window;
* forced-8-device mesh parity for the attack + trust-weighted path.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro import obs as obs_lib
from repro.core import detection
from repro.core.attacks import (backdoor_success_rate, flip_success_rate,
                                stamp_trigger)
from repro.data import make_federated_image_data
from repro.data.federated import select_malicious
from repro.fleet import get_scenario, stages
from repro.fleet.scenarios import build_engine
from repro.net.link import (LinkProfile, draw_transfer_batch,
                            materialize_bandwidth)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _spec(**kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=6, samples_per_node=20, n_test=32,
                            n_cloud_test=16,
                            attack=api.AttackMix(malicious_frac=0.34)),
        defense=api.DefenseSpec(detect=True),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=2, seed=0)
    base.update(kw)
    return api.ExperimentSpec(**base)


def _attack(**kw):
    return api.FleetSpec(n_nodes=6, samples_per_node=20, n_test=32,
                         n_cloud_test=16,
                         attack=api.AttackMix(malicious_frac=0.34, **kw))


# ---------------------------------------------------------------------------
# compile_plan validation (satellite 1 + tentpole spec surface)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw, match", [
    (dict(flip_src=3, flip_dst=3), "flip_src"),
    (dict(flip_src=10), "n_classes"),
    (dict(flip_dst=-1), "flip_dst"),
    (dict(kind="gradient_ascent"), "attack.kind"),
    (dict(placement="last"), "placement"),
    (dict(kind="sybil", sybil_boost=0.0), "sybil_boost"),
    (dict(kind="adaptive", adapt_poison_scale=1.5), "adapt_poison_scale"),
    (dict(kind="backdoor", trigger_frac=0.0), "trigger_frac"),
    (dict(kind="backdoor", trigger_label=11), "trigger_label"),
    (dict(kind="backdoor", trigger_size=9), "trigger_size"),
    (dict(kind="ddos", ddos_uploads=0), "ddos_uploads"),
])
def test_compile_plan_rejects_bad_attack(kw, match):
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(fleet=_attack(**kw)))


def test_flip_labels_unconstrained_when_not_attacking():
    """flip_src == flip_dst is only a contradiction when label flipping
    actually runs — an honest fleet carries the fields inert."""
    fleet = api.FleetSpec(n_nodes=6, attack=api.AttackMix(flip_src=3,
                                                          flip_dst=3))
    api.compile_plan(_spec(fleet=fleet))
    # ... and a backdoor fleet never flips labels either
    api.compile_plan(_spec(fleet=_attack(kind="backdoor", flip_src=3,
                                         flip_dst=3)))


def test_compile_plan_ddos_requires_shared_uplink():
    with pytest.raises(api.SpecError, match="shared_uplink"):
        api.compile_plan(_spec(fleet=_attack(kind="ddos")))
    api.compile_plan(_spec(
        fleet=_attack(kind="ddos"),
        network=api.NetworkSpec(codec="dense_f32", shared_uplink_bps=1e6)))


def test_compile_plan_trust_weighted_requires_detect():
    with pytest.raises(api.SpecError, match="detect"):
        api.compile_plan(_spec(
            defense=api.DefenseSpec(detect=False, kind="trust_weighted")))
    with pytest.raises(api.SpecError, match="defense.kind"):
        api.compile_plan(_spec(defense=api.DefenseSpec(kind="tofu")))


def test_compile_plan_zoo_forbids_sequential_topology():
    with pytest.raises(api.SpecError, match="sequential"):
        api.compile_plan(_spec(fleet=_attack(kind="sybil"),
                               topology=api.Topology(kind="sequential")))
    # data-level attacks still run on the reference loop
    api.compile_plan(_spec(fleet=_attack(kind="label_flip"),
                           topology=api.Topology(kind="sequential")))


def test_plan_stages_name_the_adversary_and_defense():
    plan = api.compile_plan(_spec(
        fleet=_attack(kind="sybil"),
        defense=api.DefenseSpec(detect=True, kind="trust_weighted")))
    assert "attack[sybil]" in plan.stages
    assert "trust_weighted_agg" in plan.stages
    # defaults stay stage-identical to the pre-zoo pipeline (opt-in)
    plan0 = api.compile_plan(api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=4),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=1, seed=0))
    assert not any(s.startswith("attack[") for s in plan0.stages)
    assert "trust_weighted_agg" not in plan0.stages


def test_spec_roundtrip_and_v3_payload_accepted():
    """New fields serialize; a pre-zoo (schema v3) payload without them
    still loads with the legacy semantics."""
    spec = _spec(fleet=_attack(kind="backdoor", trigger_size=3),
                 defense=api.DefenseSpec(detect=True,
                                         kind="trust_weighted"))
    again = api.ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    d = api.ExperimentSpec().to_dict()
    d["schema_version"] = 3
    for key in ("kind", "sybil_boost", "adapt_poison_scale", "trigger_frac",
                "trigger_label", "trigger_size", "trigger_value",
                "ddos_uploads", "placement"):
        d["fleet"]["attack"].pop(key, None)
    for key in ("kind", "trust_eta", "trust_floor", "uncertainty_scale"):
        d["defense"].pop(key, None)
    old = api.ExperimentSpec.from_dict(d)
    assert old.fleet.attack.kind == "label_flip"
    assert old.defense.kind == "percentile"


# ---------------------------------------------------------------------------
# malicious placement (satellite 2)
# ---------------------------------------------------------------------------

def test_select_malicious_first_is_legacy_prefix():
    assert select_malicious(7, 10, 3, placement="first") == [0, 1, 2]
    with pytest.raises(ValueError, match="placement"):
        select_malicious(0, 10, 3, placement="last")


def test_select_malicious_random_is_seeded_and_varied():
    a = select_malicious(0, 20, 5, placement="random")
    assert a == select_malicious(0, 20, 5, placement="random")
    assert a == sorted(a) and len(set(a)) == 5
    assert all(0 <= i < 20 for i in a)
    others = {tuple(select_malicious(s, 20, 5, placement="random"))
              for s in range(8)}
    assert len(others) > 1, "placement never leaves the same cohort"
    # the set is over nodes, not a prefix — some seed avoids node 0
    assert any(sel[0] != 0 for sel in others)


def test_direct_data_callers_keep_first_k_placement():
    """`make_federated_image_data`'s own default stays the legacy first-k
    prefix — the byte-compat contract for every pre-zoo caller."""
    _, _, _, malicious = make_federated_image_data(
        0, n_nodes=5, n_malicious=2, n_train=100, n_test=32,
        n_cloud_test=16, hw=(8, 8))
    assert malicious == [0, 1]


def test_spec_routes_seeded_random_placement():
    spec = _spec(fleet=_attack())
    pop = api.materialize(spec)
    k = int(round(0.34 * 6))
    assert list(pop.malicious_ids) == select_malicious(
        spec.seed, 6, k, placement="random")
    legacy = dataclasses.replace(
        spec, fleet=_attack(placement="first"))
    assert list(api.materialize(legacy).malicious_ids) == list(range(k))


# ---------------------------------------------------------------------------
# link bandwidth guard (satellite 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [0.0, -5.0, float("nan"), float("inf")])
def test_materialize_bandwidth_rejects_nonpositive(bad):
    bw = np.array([1e6, bad, 2e6])
    with pytest.raises(ValueError, match="bandwidth"):
        materialize_bandwidth(bw, 0.0, seed=0)


def test_draw_transfer_batch_rejects_bad_bandwidth():
    link = LinkProfile(jitter_s=0.1, loss_prob=0.1)
    nodes = np.array([0, 1])
    seqs = np.zeros(2, np.int64)
    with pytest.raises(ValueError, match="bandwidth"):
        draw_transfer_batch(link, 1000, np.array([1e6, 0.0]), 0, nodes,
                            seqs, concurrency=2)
    t, _, _ = draw_transfer_batch(link, 1000, np.array([1e6, 1e6]), 0,
                                  nodes, seqs, concurrency=2)
    assert np.isfinite(t).all() and (t > 0).all()


# ---------------------------------------------------------------------------
# detect() all-equal fallback (satellite 4)
# ---------------------------------------------------------------------------

def test_detect_all_equal_fallback_pinned():
    """Regression pin: the strict A > Thr comparison rejects everyone on
    an all-equal accuracy set, so detect() falls back to >= and accepts
    everyone — exactly the state a detection-aware attacker forces."""
    accs = jnp.full((5,), 0.37)
    mask, thr = detection.detect(accs, 80.0)
    assert bool(mask.all())
    assert detection.detect_fell_back(np.asarray(accs), float(thr))
    spread = jnp.asarray([0.1, 0.2, 0.9, 0.8, 0.5])
    mask2, thr2 = detection.detect(spread, 80.0)
    assert not bool(mask2.all())
    assert not detection.detect_fell_back(np.asarray(spread), float(thr2))


def test_detect_fallback_obs_counter():
    """The fallback state is audited: one `detect.fallback` counter tick
    per all-equal round, none otherwise."""
    eng = build_engine(get_scenario("label_flip_20").with_nodes(5), seed=0)
    tracer = obs_lib.Tracer(sinks=[obs_lib.MemorySink()], enabled=True)
    eng.obs = tracer
    rec = type(eng.history)().__class__  # noqa: F841 (engine unused below)
    from repro.fleet.engine import FleetRoundRecord
    rr = FleetRoundRecord(t=1.0, round=0, accuracy=0.5, comm_bytes=0.0,
                          comp_time=0.0, comm_time=0.0, n_participating=5,
                          n_rejected=0)
    idx = np.arange(5)
    valid = np.ones(5, bool)
    equal = {"thr": np.float32(0.4), "accs": np.full(5, 0.4, np.float32),
             "mask": np.ones(5, bool)}
    eng._emit_round_events(rr, idx, valid, equal, None)
    assert tracer.metrics.snapshot()["detect.fallback"]["value"] == 1.0
    varied = {"thr": np.float32(0.4),
              "accs": np.linspace(0.1, 0.9, 5).astype(np.float32),
              "mask": np.ones(5, bool)}
    eng._emit_round_events(rr, idx, valid, varied, None)
    assert tracer.metrics.snapshot()["detect.fallback"]["value"] == 1.0


# ---------------------------------------------------------------------------
# per-attack unit semantics
# ---------------------------------------------------------------------------

def test_stamp_trigger_and_success_metrics():
    x = np.zeros((4, 6, 6, 1), np.float32)
    stamped = stamp_trigger(x, size=2, value=0.9)
    assert float(x.max()) == 0.0, "stamp must copy, not mutate"
    assert np.all(np.asarray(stamped)[:, :2, :2, :] == 0.9)
    assert float(jnp.asarray(stamped)[:, 2:, :, :].max()) == 0.0

    # a rigged forward that always predicts class 7
    def always7(params, xx):
        logits = jnp.zeros((xx.shape[0], 10))
        return logits.at[:, 7].set(1.0)

    y = np.array([1, 1, 2, 7])
    asr = flip_success_rate(always7, {}, x, y, src=1, dst=7)
    assert asr == pytest.approx(1.0)
    bsr = backdoor_success_rate(always7, {}, x, y, trigger_label=7)
    assert bsr == pytest.approx(1.0)   # non-7 samples all flip to 7

    def always2(params, xx):
        logits = jnp.zeros((xx.shape[0], 10))
        return logits.at[:, 2].set(1.0)

    assert flip_success_rate(always2, {}, x, y, 1, 7) == pytest.approx(0.0)
    assert backdoor_success_rate(always2, {}, x, y, 7) == pytest.approx(0.0)


def test_sybil_delta_stage_boosts_malicious_rows():
    plan = stages.AttackPlan.from_spec(
        api.AttackMix(malicious_frac=0.5, kind="sybil", sybil_boost=3.0),
        4, (1, 3))
    stage = stages.make_delta_attack(plan)
    deltas = {"w": jnp.ones((4, 2))}
    out = stage(deltas, plan.mask(), None)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [[1, 1], [3, 3], [1, 1], [3, 3]])


def test_adaptive_stage_and_throttle_update():
    plan = stages.AttackPlan.from_spec(
        api.AttackMix(malicious_frac=0.5, kind="adaptive",
                      adapt_poison_scale=0.5), 4, (0, 2))
    assert plan.needs_throttle
    stage = stages.make_delta_attack(plan)
    throttle = jnp.asarray([1.0, 1.0, 0.25, 1.0])
    out = stage({"w": jnp.ones((4, 1))}, plan.mask(), throttle)
    np.testing.assert_allclose(np.asarray(out["w"]).ravel(),
                               [1.0, 1.0, 0.25, 1.0])
    # rejected -> halve, accepted -> recover 1.1x (capped at 1), unseen
    # -> unchanged
    rej = jnp.asarray([True, False, False, False])
    seen = jnp.asarray([True, True, False, True])
    t2 = stages.adaptive_throttle_update(throttle, rej, seen, 0.5)
    np.testing.assert_allclose(np.asarray(t2), [0.5, 1.0, 0.25, 1.0])
    t3 = stages.adaptive_throttle_update(
        jnp.asarray([0.5, 0.9, 0.99, 1.0]), jnp.zeros(4, bool),
        jnp.ones(4, bool), 0.5)
    np.testing.assert_allclose(np.asarray(t3), [0.55, 0.99, 1.0, 1.0])


def test_ddos_plan_floods_but_keeps_data_clean():
    plan = stages.AttackPlan.from_spec(
        api.AttackMix(malicious_frac=0.5, kind="ddos", ddos_uploads=4),
        4, (0, 2))
    assert stages.make_delta_attack(plan) is None
    assert plan.flood_uploads == 8
    clean = make_federated_image_data(
        0, n_nodes=4, n_malicious=0, n_train=80, n_test=32, n_cloud_test=16,
        hw=(8, 8))[0]
    flooded = make_federated_image_data(
        0, n_nodes=4, n_malicious=2, n_train=80, n_test=32, n_cloud_test=16,
        hw=(8, 8), attack_kind="ddos")[0]
    for (xa, ya), (xb, yb) in zip(clean, flooded):
        np.testing.assert_array_equal(ya, yb)
        np.testing.assert_array_equal(xa, xb)


def test_backdoor_data_poisoning():
    node_data, _, _, malicious = make_federated_image_data(
        3, n_nodes=4, n_malicious=2, n_train=160, n_test=32, n_cloud_test=16,
        hw=(8, 8), attack_kind="backdoor", trigger_frac=0.5,
        trigger_label=0, trigger_size=2, trigger_value=1.0)
    for node, (x, y) in enumerate(node_data):
        stamped = np.all(x[:, :2, :2, :] == 1.0, axis=(1, 2, 3))
        if node in malicious:
            assert stamped.any()
            assert np.all(y[stamped] == 0)
        else:
            assert not stamped.any() or x[stamped].size == 0


def test_sybil_cohort_shares_one_shard():
    node_data, _, _, malicious = make_federated_image_data(
        0, n_nodes=5, n_malicious=3, n_train=100, n_test=32, n_cloud_test=16,
        hw=(8, 8), attack_kind="sybil")
    first = malicious[0]
    for m in malicious[1:]:
        np.testing.assert_array_equal(node_data[m][0], node_data[first][0])
        np.testing.assert_array_equal(node_data[m][1], node_data[first][1])
    honest = next(i for i in range(5) if i not in malicious)
    assert not np.array_equal(node_data[honest][1], node_data[first][1])


# ---------------------------------------------------------------------------
# sybil collusion lands in one async window
# ---------------------------------------------------------------------------

def test_sybil_cohort_colludes_in_one_async_window():
    spec = _spec(fleet=_attack(kind="sybil"),
                 schedule=api.SchedulePolicy(kind="async"),
                 defense=api.DefenseSpec(detect=True,
                                         kind="trust_weighted"))
    plan = api.compile_plan(spec)
    pop = api.materialize(spec)
    mal = set(pop.malicious_ids)
    assert len(mal) == 2
    # materialize pins the sybil clones to identical compute
    comp = pop.profile.compute_s
    assert len({float(comp[i]) for i in mal}) == 1
    eng = api.make_engine(plan, pop)
    first_window = {}
    for w in range(12):
        order, proc = eng.select_window()
        sel = set(int(i) for i in order[proc])
        for node in sel & mal:
            first_window.setdefault(node, w)
        eng.run_window()
        if mal <= set(first_window):
            break
    assert mal <= set(first_window), "sybils never arrived"
    assert len(set(first_window.values())) == 1, (
        f"sybil cohort split across windows: {first_window}")
    # the trust ring updated for the arrived nodes
    assert eng.state.trust is not None
    assert float(np.asarray(eng.state.trust).min()) < 1.0


# ---------------------------------------------------------------------------
# attack + trust defense: forced-8-device mesh parity
# ---------------------------------------------------------------------------

def test_attack_trust_mesh_matches_single_device_on_8_devices():
    """The sybil delta stage, trust-weighted fold and throttle scatter are
    shard-oblivious: the forced-8-device mesh float-closes the
    single-device trajectory for both schedules."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax
        from repro import api

        out = {"n_devices": len(jax.devices())}
        for label, kind, attack in (("sybil_sync", "sync", "sybil"),
                                    ("adaptive_async", "async",
                                     "adaptive")):
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(
                    n_nodes=8, samples_per_node=20, n_test=32,
                    n_cloud_test=16,
                    attack=api.AttackMix(malicious_frac=0.25, kind=attack),
                    profile=api.NodeHeterogeneity(heterogeneity=0.5)),
                schedule=api.SchedulePolicy(kind=kind),
                defense=api.DefenseSpec(detect=True,
                                        kind="trust_weighted"),
                topology=api.Topology(kind="single"),
                train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
                rounds=2, seed=0)
            ref = api.run(api.compile_plan(spec))
            mesh_spec = dataclasses.replace(
                spec, topology=api.Topology(kind="mesh", devices=8))
            rep = api.run(api.compile_plan(mesh_spec))
            assert rep.engine == "fleet-mesh", rep.engine
            out[label + "_len"] = len(ref.records) - len(rep.records)
            out[label + "_acc"] = max(
                abs(a.accuracy - b.accuracy)
                for a, b in zip(ref.records, rep.records))
            out[label + "_rej"] = int(sum(
                a.n_rejected != b.n_rejected
                for a, b in zip(ref.records, rep.records)))
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    for label in ("sybil_sync", "adaptive_async"):
        assert rec[f"{label}_len"] == 0, rec
        assert rec[f"{label}_acc"] < 2e-3, rec
        assert rec[f"{label}_rej"] == 0, rec


# ---------------------------------------------------------------------------
# opt-in guarantee: defaults keep the legacy detection/aggregation path
# ---------------------------------------------------------------------------

def test_defaults_allocate_no_adversary_state():
    spec = _spec()     # attacking, but percentile defense
    eng = api.make_engine(api.compile_plan(spec), api.materialize(spec))
    assert eng.state.trust is None and eng.state.throttle is None
    honest = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=4),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=1, seed=0)
    eng0 = api.make_engine(api.compile_plan(honest), api.materialize(honest))
    assert eng0.attack is None
    assert eng0.state.trust is None and eng0.state.throttle is None


def test_trust_weighted_defense_updates_trust_scores():
    spec = _spec(defense=api.DefenseSpec(detect=True,
                                         kind="trust_weighted"))
    eng = api.make_engine(api.compile_plan(spec), api.materialize(spec))
    assert eng.state.trust is not None
    before = np.asarray(eng.state.trust).copy()
    eng.run_round()
    after = np.asarray(eng.state.trust)
    assert after.shape == before.shape
    assert not np.array_equal(after, before)
    assert (after >= 0.0).all() and (after <= 1.0).all()
