"""Optional-dependency shims for the test suite.

`hypothesis` is a dev-only extra (see requirements-dev.txt); a clean runtime
checkout must still collect and pass tier-1. Importing `given/settings/st`
from here instead of `hypothesis` keeps the example-based tests running and
turns every property test into a clean per-test skip when hypothesis is
absent (the spirit of ``pytest.importorskip``, without skipping the whole
module's example-based tests alongside).
"""
from __future__ import annotations

import functools

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for `hypothesis.strategies`: decoration-time no-ops."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            @functools.wraps(fn)
            def skipped(*args, **kwargs):  # signature hides fn's params
                pytest.skip("hypothesis not installed (property test)")

            # hide the wrapped signature so pytest doesn't treat the
            # strategy parameters as fixtures
            del skipped.__wrapped__
            return skipped

        return deco
