"""Per-architecture smoke tests: reduced variant of each assigned family runs
one forward/train step on CPU with correct shapes and no NaNs, and the
prefill+decode path agrees with teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

B, S = 2, 16


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (B, seq), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(ks[1], (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch).replace(attn_chunk=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch).replace(attn_chunk=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    new = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2, _ = loss_fn(new, cfg, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch).replace(attn_chunk=8, remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    logits, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, B, 32, dtype=jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    pre.pop("targets")
    lgp, cache = prefill(params, cfg, pre, cache)
    lgs, cache = decode_step(params, cfg, batch["tokens"][:, -1:], cache)
    np.testing.assert_allclose(np.asarray(lgp[:, 0]), np.asarray(logits[:, -2]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lgs[:, 0]), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_cache():
    cfg = get_smoke_config("qwen1.5-0.5b").replace(
        sliding_window=8, attn_chunk=4, n_layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab)
    logits, _ = forward(params, cfg, {"tokens": toks, "targets": toks})
    cache = init_cache(cfg, 1, 8, dtype=jnp.float32)
    lgp, cache = prefill(params, cfg, {"tokens": toks[:, :-1]}, cache)
    lgs, _ = decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(lgs[:, 0]), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_param_count_analytic_close():
    for arch in ("smollm-360m", "olmo-1b"):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.n_params()
        assert abs(actual - est) / actual < 0.2, (arch, actual, est)
