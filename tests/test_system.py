"""End-to-end system tests: the full ALDPFL pipeline + a sharded-lowering
integration test run in a subprocess with 8 forced host devices."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_end_to_end_aldpfl_beats_attacked_baseline():
    """The paper's headline: ALDPFL with detection trains to useful accuracy
    under label-flipping + provides a privacy guarantee, at accuracy
    comparable to the non-private baseline."""
    from repro import api
    from repro.data import make_federated_image_data
    from repro.fleet import NodeProfile
    from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

    node_data, test, cloud, malicious = make_federated_image_data(
        0, n_nodes=6, n_malicious=2, n_train=900, n_test=300,
        n_cloud_test=200, hw=(14, 14))
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=6),
        schedule=api.SchedulePolicy(kind="async"),
        privacy=api.PrivacySpec(sigma=0.05),
        defense=api.DefenseSpec(detect=True),
        train=api.TrainSpec(local_steps=15, batch_size=32, lr=0.1),
        rounds=5, seed=0)
    pop = api.Population(
        params=init_cnn(jax.random.PRNGKey(0), in_hw=(14, 14)),
        loss_fn=cnn_loss, acc_fn=cnn_accuracy, node_data=node_data,
        test_data=test, cloud_test=cloud,
        profile=NodeProfile.lognormal(6, 1.0, 0.5, 12.5e6, seed=0))
    aldpfl = api.run(api.compile_plan(spec), pop)
    assert aldpfl.final_accuracy > 0.45
    assert aldpfl.epsilon_spent > 0
    assert aldpfl.kappa >= 0


def test_dryrun_lowering_in_subprocess():
    """Lower + compile a sharded fed step on a forced 8-device host mesh —
    the same machinery the 512-device production dry-run uses."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.core.fed_step import FedStepConfig
        import repro.launch.shapes as LS
        from repro.launch.shapes import InputShape
        from repro.launch.steps import make_step, arg_pspecs
        from repro.sharding.rules import shardings_for
        from repro.sharding.ctx import mesh_context
        from repro.launch.hlo_cost import analyze_hlo_text

        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        LS.SHAPES = dict(LS.SHAPES)
        LS.SHAPES["train_4k"] = InputShape("train_4k", "train", 64, 32)
        cfg = get_smoke_config("smollm-360m").replace(attn_chunk=32)
        fcfg = FedStepConfig(n_nodes=4, local_steps=2, sigma=1e-3)
        spec = LS.input_specs(cfg, "train_4k", fcfg=fcfg)
        step = make_step(cfg, spec["kind"], fcfg=fcfg, spmd_axes=("data",))
        sh = shardings_for(mesh, arg_pspecs(cfg, spec["kind"], mesh, spec["args"]))
        with mesh_context(mesh, ("data",)):
            compiled = jax.jit(step, in_shardings=sh).lower(*spec["args"]).compile()
        cost = analyze_hlo_text(compiled.as_text())
        ma = compiled.memory_analysis()
        print(json.dumps({"flops": cost.flops,
                          "coll": cost.total_coll_bytes,
                          "temp": ma.temp_size_in_bytes}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll"] > 0          # the round's node-sync collective exists
    assert rec["temp"] > 0
