"""Parity tests for the fused upload-pipeline megakernel and the
arrival-order window-fold kernel.

Contracts under test (see kernels/upload_fused.py, kernels/window_fold.py):

  * fused megakernel ≡ the unfused pallas `sparsify -> nnz -> ldp_noise`
    chain **bitwise** (same blocks, same per-block hash noise streams);
  * fused megakernel ≡ the jnp mirror `upload_fused_reference` — bitwise
    on sparsify/residual/nnz, ~1-ulp on the noised upload (XLA contracts
    the scale-multiply + noise-add into an FMA inside the kernel);
  * pallas-backend `upload_pipeline` ≡ reference backend at sigma=0
    (noise streams differ between backends by design, the sparse
    coordinate set and nnz must not);
  * `window_fold_fleet` ≡ the lax.scan reference bitwise, and ≡ a
    sequence of gated `mix_stale` applications via its (a, b)
    coefficients.

Property tests (hypothesis, optional dev dep) randomize cohort sizes,
leaf layouts, ratios, sigmas and gate patterns around those contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # hypothesis, optional

from repro.core import mix_stale_sequence, staleness_alpha
from repro.fleet import stages
from repro.kernels.upload_fused import (block_noise, upload_fused_fleet,
                                        upload_fused_reference)
from repro.kernels.window_fold import window_fold_fleet, window_fold_reference


def _cohort(k, sizes, seed=0, scale=1.0):
    """(flat deltas (k, n), flat residuals, leaf boundaries) with awkward
    (non-LANE-aligned) total length."""
    n = sum(sizes)
    kd, kr = jax.random.split(jax.random.PRNGKey(seed))
    flat = jax.random.normal(kd, (k, n), jnp.float32) * scale
    res = jax.random.normal(kr, (k, n), jnp.float32) * scale
    offs = tuple(int(b) for b in np.cumsum((0,) + tuple(sizes))[:-1])
    return flat, res, offs


def _thresholds(flat, res, offs, ratio):
    from repro.core import accumulator as accum
    comb = flat + res
    ends = list(offs[1:]) + [flat.shape[1]]
    return jnp.stack(
        [jax.vmap(lambda v: accum.leaf_threshold(v, ratio))(
            comb[:, o:e]) for o, e in zip(offs, ends)], axis=1)


# ---------------------------------------------------------------------------
# fused megakernel vs jnp mirror / unfused chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ratio,sigma", [
    (0.3, 0.0),     # sparsify only
    (1.0, 0.5),     # noise only
    (0.3, 0.5),     # full pipeline
])
def test_fused_kernel_matches_jnp_mirror(ratio, sigma):
    k, sizes = 4, (700, 1301, 96)
    flat, res, offs = _cohort(k, sizes, seed=1)
    do_sp = ratio < 1.0
    thr = _thresholds(flat, res, offs, ratio) if do_sp else None
    seeds = jnp.arange(11, 11 + k, dtype=jnp.int32)
    comb = flat + res if do_sp else flat
    if do_sp:
        from repro.kernels.upload_fused import spread_thresholds
        sp = jnp.where(jnp.abs(comb) >= spread_thresholds(
            thr, offs, flat.shape[1]), comb, 0.0)
    else:
        sp = flat
    scales = 1.0 / jnp.maximum(1.0, jnp.sqrt(
        jnp.sum(jnp.square(sp), 1))) if sigma > 0 else None
    args = (flat, res if do_sp else None, thr, seeds, scales, sigma, 1.0)
    up_k, nr_k, nnz_k = upload_fused_fleet(*args, boundaries=offs,
                                           need_nnz=True)
    up_r, nr_r, nnz_r = upload_fused_reference(*args, boundaries=offs,
                                               need_nnz=True)
    np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))
    if do_sp:
        np.testing.assert_array_equal(np.asarray(nr_k), np.asarray(nr_r))
    # noised upload: FMA contraction inside the kernel => ~1 ulp
    np.testing.assert_allclose(np.asarray(up_k), np.asarray(up_r),
                               atol=1e-6)


def test_fused_noise_matches_unfused_ldp_kernel_bitwise():
    """Same seeds, same block decomposition: the megakernel's noise stream
    is the standalone `ldp_noise` kernel's, so the fused pipeline is a pure
    fusion — not a numerics change — relative to the kernel chain."""
    from repro.kernels.ldp_noise import ldp_perturb_fleet
    k, n = 3, 4000
    flat, _, _ = _cohort(k, (n,), seed=2)
    seeds = jnp.arange(5, 5 + k, dtype=jnp.int32)
    norms = jnp.sqrt(jnp.sum(jnp.square(flat), axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms)
    up_f, _, _ = upload_fused_fleet(flat, None, None, seeds, scales,
                                    0.4, 1.0)
    up_u = ldp_perturb_fleet(flat, seeds, scales, 0.4, 1.0)
    np.testing.assert_array_equal(np.asarray(up_f), np.asarray(up_u))


def test_block_noise_is_seed_deterministic_and_node_distinct():
    seeds = jnp.array([7, 7, 8], jnp.int32)
    a = block_noise(3, 2000, seeds, 0.5)
    b = block_noise(3, 2000, seeds, 0.5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(a[1]))
    assert float(np.max(np.abs(np.asarray(a[0] - a[2])))) > 0.0


def test_pallas_pipeline_matches_reference_backend_at_sigma0():
    """Stage-level: the pallas fused upload pipeline returns the reference
    backend's sparse coordinate set, residuals and nnz when no noise is
    drawn (noise streams differ between backends by design)."""
    import dataclasses as dc
    from repro.fleet.engine import FleetConfig
    k = 5
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (k, 37, 29)),
            "b": jax.random.normal(jax.random.PRNGKey(4), (k, 53))}
    res = jax.tree.map(jnp.zeros_like, tree)
    res = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(5), x.shape) * 0.1,
        res)
    k2s = jax.random.split(jax.random.PRNGKey(6), k)
    cfg = FleetConfig(sigma=0.0, sparsify_ratio=0.25, backend="reference")
    up_r, nr_r, nnz_r = stages.upload_pipeline(cfg, tree, res, k2s,
                                               need_nnz=True)
    cfg_p = dc.replace(cfg, backend="pallas")
    up_p, nr_p, nnz_p = stages.upload_pipeline(cfg_p, tree, res, k2s,
                                               need_nnz=True)
    np.testing.assert_array_equal(np.asarray(nnz_p), np.asarray(nnz_r))
    for a, b in zip(jax.tree.leaves(up_p), jax.tree.leaves(up_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(jax.tree.leaves(nr_p), jax.tree.leaves(nr_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fused_pipeline_ratio_one_skips_sparsify_keeps_residuals():
    import dataclasses as dc
    from repro.fleet.engine import FleetConfig
    k = 3
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (k, 64))}
    res = {"w": jnp.full((k, 64), 0.25)}
    k2s = jax.random.split(jax.random.PRNGKey(1), k)
    cfg = FleetConfig(sigma=0.2, sparsify_ratio=1.0, backend="pallas")
    up, nr, nnz = stages.upload_pipeline(cfg, tree, res, k2s, need_nnz=True)
    # residuals untouched, nnz counts the dense (pre-noise) delta
    np.testing.assert_array_equal(np.asarray(nr["w"]), np.asarray(res["w"]))
    np.testing.assert_array_equal(np.asarray(nnz), np.full(k, 64))
    # and the noiseless-noiseless edge is a true no-op fast path
    cfg0 = dc.replace(cfg, sigma=0.0)
    up0, nr0, _ = stages.upload_pipeline(cfg0, tree, res, k2s)
    np.testing.assert_array_equal(np.asarray(up0["w"]),
                                  np.asarray(tree["w"]))
    assert nr0 is res


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 5), st.integers(1, 3),
       st.floats(0.05, 1.0), st.floats(0.0, 1.0),
       st.integers(0, 2**16))
def test_fused_property_matches_mirror(k, n_leaves, ratio, sigma, seed):
    """Property: for random cohort shapes, leaf layouts, DGC ratios and
    noise levels, kernel and jnp mirror agree — bitwise on the sparse
    coordinate set (residuals, nnz), 1e-6 on values."""
    rng = np.random.default_rng(seed)
    sizes = tuple(int(s) for s in rng.integers(1, 1500, n_leaves))
    flat, res, offs = _cohort(k, sizes, seed=seed)
    do_sp = ratio < 1.0
    thr = _thresholds(flat, res, offs, ratio) if do_sp else None
    seeds = jnp.asarray(rng.integers(0, 2**31 - 1, k), jnp.int32)
    scales = (jnp.asarray(rng.uniform(0.1, 1.0, k), jnp.float32)
              if sigma > 0 else None)
    args = (flat, res if do_sp else None, thr, seeds, scales, sigma, 1.0)
    up_k, nr_k, nnz_k = upload_fused_fleet(*args, boundaries=offs,
                                           need_nnz=True)
    up_r, nr_r, nnz_r = upload_fused_reference(*args, boundaries=offs,
                                               need_nnz=True)
    np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))
    if do_sp:
        np.testing.assert_array_equal(np.asarray(nr_k), np.asarray(nr_r))
    np.testing.assert_allclose(np.asarray(up_k), np.asarray(up_r),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# window-fold kernel
# ---------------------------------------------------------------------------

def test_window_fold_matches_scan_reference_bitwise():
    c, n = 7, 3001
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,))
    om = jax.random.normal(jax.random.PRNGKey(1), (c, n))
    gates = jnp.array([1, 0, 1, 1, 0, 1, 1])
    a = jax.random.uniform(jax.random.PRNGKey(2), (c,))
    b = 1.0 - a
    f_k, s_k = window_fold_fleet(p, om, gates, a, b)
    f_r, s_r = window_fold_reference(p, om, gates, a, b)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))


def test_window_fold_all_gates_off_passes_params_through():
    p = jax.random.normal(jax.random.PRNGKey(0), (500,))
    om = jnp.ones((3, 500))
    final, seq = window_fold_fleet(p, om, jnp.zeros(3, jnp.int32),
                                   jnp.full(3, 0.5), jnp.full(3, 0.5))
    np.testing.assert_array_equal(np.asarray(final), np.asarray(p))
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(seq[i]), np.asarray(p))


def test_window_fold_matches_mix_stale_sequence():
    """The kernel under FedAsync coefficients a=1−w(τ), b=w(τ) reproduces
    the public `mix_stale_sequence` building block (gated arrivals and
    all)."""
    c, alpha = 6, 0.5
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (40, 7)),
            "b": jnp.ones((13,))}
    stack = {"w": jax.random.normal(jax.random.PRNGKey(1), (c, 40, 7)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (c, 13))}
    taus = jnp.array([0, 3, 1, 7, 2, 0])
    gates = jnp.array([1, 1, 0, 1, 1, 1])
    w = staleness_alpha(alpha, taus)
    layout = stages.cohort_layout(stack)
    final, _ = window_fold_fleet(layout.flatten_one(tree),
                                 layout.flatten(stack), gates,
                                 1.0 - w, w)
    ref, _ = mix_stale_sequence(tree, stack, taus, alpha,
                                gate=gates.astype(bool))
    for got, want in zip(jax.tree.leaves(layout.unflatten_one(final)),
                         jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# pallas backend end-to-end (api level): the engines running the fused
# megakernel + window-fold kernel against the reference backend / mesh
# ---------------------------------------------------------------------------

def _scheme_run(kind, sigma, backend, obs=None):
    from repro import api
    from repro.data import make_federated_image_data
    from repro.fleet import NodeProfile
    from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss
    n = 6
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=n, n_malicious=2, n_train=240, n_test=128,
        n_cloud_test=64, hw=(8, 8))
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=n),
        schedule=api.SchedulePolicy(kind=kind),
        privacy=api.PrivacySpec(sigma=sigma),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True),
        topology=api.Topology(kind="single", backend=backend),
        train=api.TrainSpec(local_steps=3, batch_size=16, lr=0.1),
        rounds=3, seed=0, obs=obs if obs is not None else api.ObsSpec())
    pop = api.Population(
        params=init_mlp(jax.random.PRNGKey(0), 64), loss_fn=mlp_loss,
        acc_fn=mlp_accuracy, node_data=node_data, test_data=test,
        cloud_test=cloud,
        profile=NodeProfile.lognormal(n, 1.0, 0.5, 12.5e6, seed=0))
    return api.run(api.compile_plan(spec), pop)


@pytest.mark.parametrize("kind", ["sync", "async"])
def test_pallas_backend_api_matches_reference_sigma0(kind):
    """σ=0 removes the only backend-divergent piece (the noise stream):
    the fused-megakernel engines must reproduce the reference backend's
    trajectory through the full api path (sync round fold and the async
    window-fold kernel both exercised)."""
    ref = _scheme_run(kind, 0.0, "reference")
    pal = _scheme_run(kind, 0.0, "pallas")
    assert len(ref.records) == len(pal.records)
    np.testing.assert_allclose([r.accuracy for r in pal.records],
                               [r.accuracy for r in ref.records], atol=2e-3)
    assert [r.n_rejected for r in pal.records] == \
        [r.n_rejected for r in ref.records]
    for a, b in zip(jax.tree.leaves(pal.final_params),
                    jax.tree.leaves(ref.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("kind", ["sync", "async"])
def test_pallas_backend_with_noise_trains_and_charges_budget(kind):
    rep = _scheme_run(kind, 0.05, "pallas")
    assert rep.epsilon_spent > 0
    assert 0.0 <= rep.final_accuracy <= 1.0
    assert len(rep.records) == 3


@pytest.mark.parametrize("kind", ["sync", "async"])
def test_pallas_backend_obs_tracing_does_not_change_results(kind, tmp_path):
    """Enabling the obs layer must not perturb the fused-kernel engines:
    record streams agree field-for-field with the obs-off run."""
    from repro import api
    off = _scheme_run(kind, 0.05, "pallas")
    on = _scheme_run(kind, 0.05, "pallas", obs=api.ObsSpec(
        enabled=True, events_jsonl=str(tmp_path / f"{kind}.jsonl")))
    assert len(on.records) == len(off.records)
    for a, b in zip(on.records, off.records):
        assert (a.t, a.version, a.accuracy, a.comm_bytes, a.n_rejected) == \
            (b.t, b.version, b.accuracy, b.comm_bytes, b.n_rejected)
    assert (tmp_path / f"{kind}.jsonl").exists()


def test_pallas_mesh_matches_single_device_on_8_devices():
    """Shard-obliviousness acceptance: the fused megakernel + window-fold
    kernel inside `shard_map` on a forced-8-device host mesh reproduce the
    single-device pallas trajectories for all four schemes."""
    import json
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, numpy as np
        from repro import api
        from repro.data import make_federated_image_data
        from repro.fleet import NodeProfile
        from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

        n = 8
        node_data, test, cloud, _ = make_federated_image_data(
            0, n_nodes=n, n_malicious=2, n_train=320, n_test=128,
            n_cloud_test=64, hw=(8, 8))
        out = {"n_devices": len(jax.devices())}
        schemes = {"sfl": ("sync", 0.0), "afl": ("async", 0.0),
                   "sldpfl": ("sync", 0.05), "aldpfl": ("async", 0.05)}
        for mode, (kind, sigma) in schemes.items():
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(n_nodes=n),
                schedule=api.SchedulePolicy(kind=kind),
                privacy=api.PrivacySpec(sigma=sigma),
                compression=api.CompressionSpec(sparsify_ratio=0.5),
                defense=api.DefenseSpec(detect=True),
                topology=api.Topology(kind="single", backend="pallas"),
                train=api.TrainSpec(local_steps=3, batch_size=16, lr=0.1),
                rounds=2, seed=0)

            def pop():
                return api.Population(
                    params=init_mlp(jax.random.PRNGKey(0), 64),
                    loss_fn=mlp_loss, acc_fn=mlp_accuracy,
                    node_data=node_data, test_data=test, cloud_test=cloud,
                    profile=NodeProfile.lognormal(n, 1.0, 0.5, 12.5e6,
                                                  seed=0))

            ref = api.run(api.compile_plan(spec), population=pop())
            mesh_spec = dataclasses.replace(
                spec, topology=api.Topology(kind="mesh", devices=8,
                                            backend="pallas"))
            rep = api.run(api.compile_plan(mesh_spec), population=pop())
            assert rep.engine == "fleet-mesh", rep.engine
            hist = ref.records
            out[f"{mode}_len"] = len(hist) - len(rep.records)
            out[f"{mode}_acc"] = max(abs(a.accuracy - b.accuracy)
                                     for a, b in zip(hist, rep.records))
            out[f"{mode}_rej"] = int(sum(a.n_rejected != b.n_rejected
                                         for a, b in zip(hist,
                                                         rep.records)))
        print(json.dumps(out))
    """)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)          # the child forces its own devices
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    for mode in ("sfl", "afl", "sldpfl", "aldpfl"):
        assert out[f"{mode}_len"] == 0, (mode, out)
        assert out[f"{mode}_acc"] < 2e-3, (mode, out)
        assert out[f"{mode}_rej"] == 0, (mode, out)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 8), st.integers(1, 4000), st.integers(0, 2**16))
def test_window_fold_property_matches_reference(c, n, seed):
    """Property: random window sizes, param lengths (incl. < one lane),
    gate patterns and coefficients — kernel ≡ scan reference bitwise."""
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=n), jnp.float32)
    om = jnp.asarray(rng.normal(size=(c, n)), jnp.float32)
    gates = jnp.asarray(rng.integers(0, 2, c), jnp.int32)
    a = jnp.asarray(rng.uniform(0.0, 1.0, c), jnp.float32)
    b = jnp.asarray(rng.uniform(0.0, 1.0, c), jnp.float32)
    f_k, s_k = window_fold_fleet(p, om, gates, a, b)
    f_r, s_r = window_fold_reference(p, om, gates, a, b)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
