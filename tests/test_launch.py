"""Launch-layer tests: shapes, specs, config resolution, cost analyzer,
sharding context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, \
    long_context_variant
from repro.core.fed_step import FedStepConfig
from repro.launch.shapes import SHAPES, fed_layout, input_specs
from repro.launch.roofline import (analytic_memory_bytes, attention_flops,
                                   model_flops, roofline_terms)


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_fed_layout_factorisation():
    n, h, per = fed_layout(SHAPES["train_4k"], 16, 4)
    assert n * h * per == 256
    n, h, per = fed_layout(SHAPES["train_4k"], 32, 4)
    assert n * h * per == 256


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_structures(arch):
    """Every (arch × shape) produces weak-type-correct structs (no alloc)."""
    cfg = get_smoke_config(arch)
    fcfg = FedStepConfig(n_nodes=4, local_steps=2)
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        spec = input_specs(cfg, shape_name, fcfg=fcfg)
        assert spec["kind"] in ("fed_train", "prefill", "decode")
        leaves = jax.tree.leaves(spec["args"])
        assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
        if spec["kind"] == "fed_train":
            toks = spec["args"][1]["tokens"]
            assert toks.shape[:2] == (4, 2)


def test_long_context_variant():
    dense = get_config("codeqwen1.5-7b")
    assert long_context_variant(dense).sliding_window == 8192
    ssm = get_config("falcon-mamba-7b")
    assert long_context_variant(ssm).sliding_window == 0  # already O(1) state


def test_all_archs_have_full_and_smoke():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        full = get_config(arch)
        smoke = get_smoke_config(arch)
        assert full.family == smoke.family
        assert smoke.n_layers <= 4 and smoke.d_model <= 512
        if smoke.moe:
            assert smoke.moe.n_experts <= 4


def test_assigned_dims_exact():
    spec = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
        "qwen2-vl-72b": (80, 8192, 64, 8, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 32000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 151936),
        "whisper-large-v3": (32, 1280, 20, 20, 51866),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 92416),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 202048),
        "falcon-mamba-7b": (64, 4096, 1, 1, 65024),
        "olmo-1b": (16, 2048, 16, 16, 50304),
        "smollm-360m": (32, 960, 15, 5, 49152),
    }
    for arch, (L, d, H, KV, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab) == (L, d, H, KV, V), arch


# ---------------------------------------------------------------------------
# cost analyzer details
# ---------------------------------------------------------------------------

def test_hlo_cost_slicing_not_quadratic():
    """Scan loops dynamic-slice their stacked xs each iteration; bytes must
    scale ~linearly with trip count, not quadratically."""
    from repro.launch.hlo_cost import analyze_hlo_text

    def make(n):
        def f(xs):
            def body(c, x):
                return c + jnp.tanh(x).sum(), None
            c, _ = jax.lax.scan(body, jnp.zeros(()), xs)
            return c
        xs = jnp.ones((n, 256, 64))
        compiled = jax.jit(f).lower(xs).compile()
        return analyze_hlo_text(compiled.as_text()).bytes

    b8, b16 = make(8), make(16)
    assert b16 / b8 < 2.6, (b8, b16)


def test_roofline_model_flops_moe_active():
    kimi = get_config("kimi-k2-1t-a32b")
    dense_equiv = kimi.n_params()
    active = kimi.active_params()
    assert active < dense_equiv / 10          # top-8 of 384 experts
    assert model_flops(kimi, "fed_train", 1000) == 6.0 * active * 1000


def test_attention_flops_windowed_smaller():
    cfg = get_config("codeqwen1.5-7b")
    full = attention_flops(cfg, "decode", 1, 524288)
    win = attention_flops(long_context_variant(cfg), "decode", 1, 524288)
    assert win < full / 10


def test_analytic_memory_decode_cache_dominated():
    b = analytic_memory_bytes("decode", params_bytes=1e9, cache_bytes=1e12,
                              act_ckpt_bytes=0, logits_bytes=1e6, n_dev=256)
    assert b > 2 * 1e12 / 256 * 0.99


# ---------------------------------------------------------------------------
# sharding ctx
# ---------------------------------------------------------------------------

def test_constrain_noop_outside_mesh():
    from repro.sharding.ctx import constrain_batch, constrain_axis
    x = jnp.ones((4, 4))
    assert constrain_batch(x) is x
    assert constrain_axis(x, 0) is x


def test_constrain_inside_trivial_mesh():
    from jax.sharding import Mesh
    from repro.sharding.ctx import (constrain_axis, constrain_batch,
                                    mesh_context, suspended)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with mesh_context(mesh, ("data",)):
        y = constrain_batch(jnp.ones((4, 4)))
        assert y.shape == (4, 4)
        with suspended():
            z = constrain_batch(jnp.ones((4, 4)))    # dp suspended -> no-op
            w = constrain_axis(jnp.ones((4, 4)), 1)  # model stays active
            assert z.shape == w.shape == (4, 4)
