"""Unit + property tests for the paper's core mechanisms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # hypothesis, optional

from repro.core import (MomentsAccountant, aldp_perturb, clip_by_global_norm,
                        detect, detection_threshold, epsilon_for_sigma,
                        global_norm, masked_mean, mix, mix_stale,
                        sigma_for_epsilon, staleness_alpha)
from repro.core import accumulator as accum
from repro.core.async_update import communication_efficiency


# ---------------------------------------------------------------------------
# ALDP (Eq. 8)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(st.floats(0.1, 10.0), st.integers(0, 2**31 - 1))
def test_clip_invariant(clip_s, seed):
    """Property: after clipping at S, the global norm is ≤ S (+eps)."""
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (13, 7)) * 10,
            "b": {"c": jax.random.normal(key, (5,)) * 10}}
    clipped, nrm = clip_by_global_norm(tree, clip_s)
    assert float(global_norm(clipped)) <= clip_s * (1 + 1e-4)
    # no-op when already within the ball
    small = jax.tree.map(lambda x: x * 1e-6, tree)
    same, _ = clip_by_global_norm(small, clip_s)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(small["a"]),
                               rtol=1e-6)


def test_sigma_epsilon_roundtrip():
    for eps in (0.5, 1.0, 8.0):
        sigma = sigma_for_epsilon(eps, 1e-3)
        assert abs(epsilon_for_sigma(sigma, 1e-3) - eps) < 1e-9
    # paper's operating point: eps=8, delta=1e-3
    assert sigma_for_epsilon(8.0, 1e-3) == pytest.approx(0.4716, abs=1e-3)


def test_aldp_noise_magnitude():
    key = jax.random.PRNGKey(0)
    tree = {"w": jnp.zeros((2000,))}
    sigma, clip_s = 0.5, 2.0
    pert, _ = aldp_perturb(tree, key, sigma, clip_s)
    std = float(jnp.std(pert["w"]))
    assert abs(std - sigma * clip_s) / (sigma * clip_s) < 0.1


# ---------------------------------------------------------------------------
# Moments accountant
# ---------------------------------------------------------------------------

def test_accountant_monotonic_in_steps():
    acc = MomentsAccountant(sigma=1.0, sampling_rate=1.0)
    eps = []
    for _ in range(5):
        acc.step(10)
        eps.append(acc.epsilon(1e-5))
    assert all(b > a for a, b in zip(eps, eps[1:]))


def test_accountant_decreasing_in_sigma():
    out = []
    for sigma in (0.5, 1.0, 2.0, 4.0):
        acc = MomentsAccountant(sigma=sigma)
        acc.step(100)
        out.append(acc.epsilon(1e-5))
    assert all(a > b for a, b in zip(out, out[1:]))


def test_accountant_subsampling_amplifies():
    a1 = MomentsAccountant(sigma=1.0, sampling_rate=1.0)
    a2 = MomentsAccountant(sigma=1.0, sampling_rate=0.1)
    a1.step(50)
    a2.step(50)
    assert a2.epsilon(1e-5) < a1.epsilon(1e-5)


def test_accountant_rejects_zero_sigma():
    """No-noise runs must not construct an accountant — the old trainer
    sentinel (`sigma or 1e9`) silently produced a near-zero ε instead."""
    with pytest.raises(ValueError):
        MomentsAccountant(sigma=0.0)
    with pytest.raises(ValueError):
        MomentsAccountant(sigma=-1.0)


def test_no_noise_runs_have_no_accountant():
    import numpy as _np
    from repro import api
    from repro.fleet import NodeProfile
    from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss
    x = _np.zeros((8, 4, 4, 1), _np.float32)
    y = _np.zeros((8,), _np.int32)
    params = init_mlp(jax.random.PRNGKey(0), 16)
    for kind in ("sync", "async"):
        for sigma, has_acct in [(0.0, False), (0.05, True)]:
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(n_nodes=2),
                schedule=api.SchedulePolicy(kind=kind),
                privacy=api.PrivacySpec(sigma=sigma), rounds=1)
            plan = api.compile_plan(spec)
            pop = api.Population(params=params, loss_fn=mlp_loss,
                                 acc_fn=mlp_accuracy,
                                 node_data=[(x, y), (x, y)],
                                 test_data=(x, y), cloud_test=(x, y),
                                 profile=NodeProfile.lognormal(
                                     2, 1.0, 0.5, 12.5e6, seed=0))
            state = api.init_state(plan, pop)
            assert (state.accountant is not None) == has_acct, (kind, sigma)
            if not has_acct:
                assert plan.sigma == 0.0


def test_accountant_single_gaussian_close_to_classic():
    """One release, q=1: RDP ε should be within ~2x of the classic bound."""
    sigma = 2.0
    acc = MomentsAccountant(sigma=sigma)
    acc.step(1)
    classic = epsilon_for_sigma(sigma, 1e-5)
    got = acc.epsilon(1e-5)
    assert 0.3 * classic < got < 2.0 * classic


# ---------------------------------------------------------------------------
# Async mixing (Eq. 6) + staleness
# ---------------------------------------------------------------------------

def test_mix_algebra():
    g = {"w": jnp.ones((4,))}
    n = {"w": jnp.full((4,), 3.0)}
    out = mix(g, n, alpha=0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)
    # alpha=1 keeps global; alpha=0 takes new
    np.testing.assert_allclose(np.asarray(mix(g, n, 1.0)["w"]), 1.0)
    np.testing.assert_allclose(np.asarray(mix(g, n, 0.0)["w"]), 3.0)


def test_staleness_weight_decreases():
    w0 = float(staleness_alpha(0.5, 0))
    w5 = float(staleness_alpha(0.5, 5))
    assert w0 == pytest.approx(0.5)
    assert w5 < w0


def test_mix_stale_fresh_equals_mix():
    g = {"w": jnp.arange(4.0)}
    n = {"w": jnp.arange(4.0) + 2}
    np.testing.assert_allclose(np.asarray(mix_stale(g, n, 0.5, 0)["w"]),
                               np.asarray(mix(g, n, 0.5)["w"]), rtol=1e-6)


def test_mix_stale_tau0_reproduces_eq6():
    """τ=0: α_eff = (1−α)·(0+1)^(−a) = 1−α exactly, so mix_stale is Eq. (6)
    (up to one f32 rounding of the complementary weight 1−(1−α))."""
    key = jax.random.PRNGKey(3)
    g = {"w": jax.random.normal(key, (32,)),
         "b": {"c": jax.random.normal(jax.random.PRNGKey(4), (4, 4))}}
    n = jax.tree.map(lambda x: x + 1.5, g)
    for alpha in (0.1, 0.5, 0.9):
        assert float(staleness_alpha(alpha, 0)) == np.float32(1.0 - alpha)
        fresh = mix_stale(g, n, alpha, 0)
        eq6 = mix(g, n, alpha)
        for a, b in zip(jax.tree.leaves(fresh), jax.tree.leaves(eq6)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-7)


def test_staleness_weights_decay_monotonically():
    taus = jnp.arange(0, 25)
    w = np.asarray(staleness_alpha(0.5, taus))
    assert (np.diff(w) < 0).all(), w          # strictly decreasing in τ
    assert (w > 0).all() and w[0] == pytest.approx(0.5)
    # stronger damping exponent decays faster at every positive staleness
    w_strong = np.asarray(staleness_alpha(0.5, taus, a=1.0))
    assert (w_strong[1:] < w[1:]).all()


def test_mix_stale_large_tau_keeps_global():
    g = {"w": jnp.arange(8.0)}
    n = {"w": jnp.arange(8.0) + 100.0}
    out = mix_stale(g, n, 0.5, 10_000)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=0.51)  # w_new ≈ 0.5/100 ⇒ drift ≤ 0.5


def test_kappa():
    assert communication_efficiency(1.0, 3.0) == pytest.approx(0.25)
    assert communication_efficiency(0.0, 0.0) == 0.0


def test_async_mix_converges_on_quadratic():
    """Theorem 6 sanity: α-mixing of noisy local SGD on a strongly-convex
    quadratic converges to a neighbourhood of the optimum."""
    key = jax.random.PRNGKey(0)
    target = jnp.array([1.0, -2.0, 3.0])
    w = {"w": jnp.zeros(3)}
    for t in range(300):
        key, k1, k2 = jax.random.split(key, 3)
        # local SGD from the current global model (2 steps)
        local = w
        for _ in range(2):
            g = jax.tree.map(lambda x: x - target, local)
            local = jax.tree.map(lambda x, gg: x - 0.2 * gg, local, g)
        delta = jax.tree.map(lambda a, b: a - b, local, w)
        pert, _ = aldp_perturb(delta, k2, sigma=0.01, clip_s=1.0)
        w_new = jax.tree.map(lambda a, b: a + b, w, pert)
        w = mix(w, w_new, alpha=0.5)
    err = float(jnp.linalg.norm(w["w"] - target))
    assert err < 0.2, err


# ---------------------------------------------------------------------------
# Detection (Alg. 2)
# ---------------------------------------------------------------------------

def test_detect_flags_low_accuracy():
    accs = jnp.array([0.9, 0.92, 0.91, 0.88, 0.3, 0.25, 0.93, 0.89, 0.9, 0.87])
    mask, thr = detect(accs, s=30.0)
    assert not bool(mask[4]) and not bool(mask[5])
    assert bool(mask[1]) and bool(mask[6])


@settings(deadline=None, max_examples=30)
@given(st.lists(st.floats(0.0, 1.0), min_size=4, max_size=32),
       st.floats(10.0, 90.0))
def test_detect_threshold_within_range(accs, s):
    a = jnp.asarray(accs, jnp.float32)
    thr = detection_threshold(a, s)
    assert float(a.min()) - 1e-6 <= float(thr) <= float(a.max()) + 1e-6


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 1000))
def test_detect_never_empty(seed):
    """Guard property: detection always keeps at least one node."""
    key = jax.random.PRNGKey(seed)
    accs = jax.random.uniform(key, (10,))
    mask, _ = detect(accs, s=80.0)
    assert int(mask.sum()) >= 1


def test_masked_mean():
    trees = {"w": jnp.array([[1.0, 1.0], [3.0, 3.0], [100.0, 100.0]])}
    mask = jnp.array([True, True, False])
    out = masked_mean(trees, mask)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 2.0])


# ---------------------------------------------------------------------------
# Gradient accumulation container (DGC)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 1.0))
def test_accumulator_conservation(seed, ratio):
    """Property: upload + residual == residual_in + grad exactly."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    g = {"a": jax.random.normal(k1, (40,)), "b": jax.random.normal(k2, (9, 3))}
    r0 = accum.init_residual(g)
    up, r1, frac = accum.accumulate_and_sparsify(r0, g, ratio)
    tot_in = jax.tree.map(lambda a, b: a + b, r0, g)
    tot_out = jax.tree.map(lambda a, b: a + b, up, r1)
    for x, y in zip(jax.tree.leaves(tot_in), jax.tree.leaves(tot_out)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)
    assert 0.0 <= float(frac) <= 1.0


def test_accumulator_small_values_accumulate_then_upload():
    g = {"w": jnp.array([1.0, 0.01, 0.01, 0.01])}
    r = accum.init_residual(g)
    up, r, _ = accum.accumulate_and_sparsify(r, g, 0.25)
    assert float(up["w"][0]) == pytest.approx(1.0)
    # after enough rounds the residual for index>0 grows and gets uploaded
    for _ in range(200):
        up, r, _ = accum.accumulate_and_sparsify(
            r, {"w": jnp.array([0.0, 0.01, 0.01, 0.01])}, 0.25)
    assert float(jnp.abs(up["w"][1:]).max()) > 0.0


def test_upload_bytes():
    tree = {"w": jnp.zeros((1000,))}
    assert accum.upload_bytes(tree, 1.0) == 4000
    assert accum.upload_bytes(tree, 0.1) == 100 * 8
