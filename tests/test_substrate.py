"""Substrate tests: data, optimizers, checkpointing, sharding rules, HLO cost."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional import given, settings, st  # hypothesis, optional

from repro.checkpointing import load_checkpoint, save_checkpoint
from repro.data.synthetic import (make_image_dataset, make_token_dataset,
                                  partition_dirichlet, partition_iid)
from repro.optim import SGD, AdamW, Momentum, make_optimizer


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_image_dataset_learnable_structure():
    x, y = make_image_dataset(0, 400, hw=(14, 14))
    assert x.shape == (400, 14, 14, 1) and x.min() >= 0 and x.max() <= 1
    # same-class samples are closer than cross-class on average
    d_same, d_diff = [], []
    for c in range(3):
        xi = x[y == c][:10].reshape(-1, 196)
        xo = x[y != c][:10].reshape(-1, 196)
        d_same.append(np.linalg.norm(xi[0] - xi[1:], axis=1).mean())
        d_diff.append(np.linalg.norm(xi[0] - xo, axis=1).mean())
    assert np.mean(d_same) < np.mean(d_diff)


def test_partition_iid_covers_all():
    parts = partition_iid(100, 7, 0)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(100))


def test_partition_dirichlet_nonuniform():
    y = np.random.default_rng(0).integers(0, 10, 2000)
    parts = partition_dirichlet(y, 5, alpha=0.1, seed=0)
    assert sum(len(p) for p in parts) == 2000
    # non-IID: per-node class distributions differ a lot
    dists = []
    for p in parts:
        h = np.bincount(y[p], minlength=10) / max(len(p), 1)
        dists.append(h)
    spread = np.std(np.stack(dists), axis=0).mean()
    assert spread > 0.05


def test_token_dataset_markov():
    seqs = make_token_dataset(0, 50, 32, vocab=64)
    assert seqs.shape == (50, 33)
    assert seqs.max() < 64 and seqs.min() >= 0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
def test_optimizers_descend_quadratic(name):
    opt = make_optimizer(name, lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.tree.map(lambda w: 2 * w, params)
        params, state = opt.update(params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_state_shapes():
    opt = AdamW(lr=1e-3)
    params = {"a": jnp.ones((3, 4)), "b": {"c": jnp.ones(2)}}
    state = opt.init(params)
    assert state["m"]["a"].shape == (3, 4)
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2 = opt.update(params, g, state)
    assert int(s2["t"]) == 1


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = load_checkpoint(path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# sharding rules (AbstractMesh — no devices needed)
# ---------------------------------------------------------------------------

def _abstract_mesh(shape=(("data", 4), ("model", 2))):
    from jax.sharding import AbstractMesh
    try:  # jax >= 0.5 signature: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(tuple(s for _, s in shape),
                            tuple(n for n, _ in shape))
    except TypeError:  # jax 0.4.x signature: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(shape))


@pytest.mark.parametrize("arch", ["smollm-360m", "kimi-k2-1t-a32b",
                                  "falcon-mamba-7b", "whisper-large-v3"])
def test_param_pspecs_divisible(arch):
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.sharding import param_pspecs
    cfg = get_smoke_config(arch)
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = _abstract_mesh()
    specs = param_pspecs(mesh, params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
                             x.__class__.__name__ == "PartitionSpec")
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            n = int(np.prod([dict(data=4, model=2)[a] for a in axes]))
            assert dim % n == 0, (arch, leaf.shape, spec)


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_loop_trips():
    from repro.launch.hlo_cost import analyze_hlo_text
    M = 64

    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        c, _ = jax.lax.scan(body, a, None, length=7)
        return c

    a = jnp.ones((M, M))
    b = jnp.ones((M, M))
    compiled = jax.jit(f).lower(a, b).compile()
    cost = analyze_hlo_text(compiled.as_text())
    assert cost.flops == pytest.approx(7 * 2 * M ** 3, rel=0.01)
    assert cost.unknown_trip_counts == 0


def test_hlo_cost_single_dot():
    from repro.launch.hlo_cost import analyze_hlo_text
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    cost = analyze_hlo_text(compiled.as_text())
    assert cost.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)


def test_roofline_terms():
    from repro.launch.roofline import roofline_terms, PEAK_FLOPS
    t = roofline_terms(PEAK_FLOPS, 0.0, 0.0)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute_s"
