"""`repro.checkpointing` acceptance: bit-exact pytree round trips (incl.
bfloat16 bit views, int rings, float64-with-x64-disabled), descriptive
`CheckpointError`s for structure mismatches, and suffix-only ``.npz``
path handling."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _optional import given, settings, st

from repro.checkpointing import (CheckpointError, load_checkpoint,
                                 read_manifest, save_checkpoint)


def _zeros_like_tree(tree):
    """Template tree: same structure/shapes/dtypes/array-kinds, no values."""
    return jax.tree.map(
        lambda x: (jnp.zeros_like(x) if isinstance(x, jax.Array)
                   else np.zeros_like(np.asarray(x))), tree)


def _assert_bitwise_equal(loaded, orig):
    for got, want in zip(jax.tree.leaves(loaded), jax.tree.leaves(orig)):
        want_np = np.asarray(want)
        got_np = np.asarray(got)
        assert got_np.dtype == want_np.dtype
        if want_np.dtype.name == "bfloat16":
            np.testing.assert_array_equal(got_np.view(np.uint16),
                                          want_np.view(np.uint16))
        else:
            np.testing.assert_array_equal(got_np, want_np)


# ---------------------------------------------------------------------------
# property: nested pytrees round-trip bit-exactly
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 7), st.integers(1, 5))
def test_nested_pytree_round_trip_is_bitwise(seed, n, m):
    rng = np.random.default_rng(seed)
    tree = {
        "params": {
            "w": jnp.asarray(rng.normal(size=(n, m)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(m,)).astype(np.float32),
                             dtype=jnp.bfloat16),
        },
        "rings": [jnp.asarray(rng.integers(-5, 5, size=(n,)), jnp.int32),
                  np.asarray(rng.integers(0, 9, size=(m,)), np.int64)],
        # float64 loop clocks must survive with jax x64 disabled
        "clock": np.asarray(rng.normal(), np.float64),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, tree, step=int(seed % 97),
                        extra={"tag": "prop"})
        loaded, step = load_checkpoint(path, _zeros_like_tree(tree))
        assert step == int(seed % 97)
        assert read_manifest(path)["extra"] == {"tag": "prop"}
        _assert_bitwise_equal(loaded, tree)
        # jax leaves come back as jax arrays, numpy leaves as numpy
        assert isinstance(loaded["params"]["w"], jax.Array)
        assert not isinstance(loaded["clock"], jax.Array)
        assert np.asarray(loaded["clock"]).dtype == np.float64


def test_float64_and_int64_survive_without_x64():
    """The x64-disabled default truncates through jnp — numpy template
    leaves must restore through numpy (heap clocks, net counters)."""
    assert not jax.config.jax_enable_x64
    tree = {"heap_t": np.asarray([1.5, np.pi, 1e-300], np.float64),
            "counters": np.asarray([2**40, 7], np.int64)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, tree)
        loaded, _ = load_checkpoint(path, _zeros_like_tree(tree))
        assert loaded["heap_t"].dtype == np.float64
        assert loaded["counters"].dtype == np.int64
        _assert_bitwise_equal(loaded, tree)


# ---------------------------------------------------------------------------
# structure mismatches raise descriptive CheckpointError (not bare asserts)
# ---------------------------------------------------------------------------

def test_missing_leaf_raises_checkpoint_error():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, {"a": np.ones(3)})
        with pytest.raises(CheckpointError, match="no entry for leaf 'b'"):
            load_checkpoint(path, {"a": np.zeros(3), "b": np.zeros(2)})


def test_shape_mismatch_raises_checkpoint_error():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, {"a": np.ones((3, 2))})
        with pytest.raises(CheckpointError, match=r"shape \(3, 2\)"):
            load_checkpoint(path, {"a": np.zeros((4, 2))})


def test_missing_manifest_raises_checkpoint_error():
    with pytest.raises(CheckpointError, match="manifest"):
        read_manifest("/nonexistent/ck")


# ---------------------------------------------------------------------------
# path handling: ".npz" stripped only as a suffix
# ---------------------------------------------------------------------------

def test_npz_suffix_strip_is_suffix_only():
    tree = {"a": np.arange(4, dtype=np.int32)}
    with tempfile.TemporaryDirectory() as d:
        # a ".npz" mid-path must survive untouched
        base = os.path.join(d, "runs.npz.d", "ck")
        save_checkpoint(base, tree)
        assert os.path.exists(base + ".npz")
        assert os.path.exists(base + ".json")
        loaded, _ = load_checkpoint(base, _zeros_like_tree(tree))
        _assert_bitwise_equal(loaded, tree)
        # an explicit ".npz" suffix addresses the same checkpoint
        loaded2, _ = load_checkpoint(base + ".npz", _zeros_like_tree(tree))
        _assert_bitwise_equal(loaded2, tree)
        save_checkpoint(base + ".npz", tree, step=3)
        assert not os.path.exists(base + ".npz.npz")
        _, step = load_checkpoint(base, _zeros_like_tree(tree))
        assert step == 3
