"""`repro.sim` acceptance: the always-on simulation service.

* Kill-and-resume parity: a run checkpointed at round k and resumed
  reproduces the uninterrupted trajectory *bit-exactly* — sequential
  reference loops, single-device fleet engines (sync/async, with
  repro.net + traces + events live), and the forced-8-device mesh
  (subprocess).
* Traffic traces: pure-in-virtual-time modulation math, and the
  `DynamicSampler` availability indirection.
* SimEvents: attack onset at round k flows through rematerialization into
  detection/trust response; membership churn; compile-time validation of
  the whole timeline.
* Schema v5: RunReport resume metadata round trip, pre-v5 acceptance.
"""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro import api
from repro.fleet import UniformSampler
from repro.sim import DynamicSampler, SimService, modulation, region_mask

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _recs(report):
    return [(r.t, r.version, r.accuracy, r.comm_bytes, r.comp_time,
             r.comm_time, r.n_rejected, r.bytes_source)
            for r in report.records]


def _spec(kind="sync", topology="sequential", **kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=4),
        schedule=api.SchedulePolicy(kind=kind),
        privacy=api.PrivacySpec(sigma=0.05),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True),
        topology=api.Topology(kind=topology),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=3, seed=0)
    base.update(kw)
    return api.ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# kill-and-resume parity, all four local execution paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology,kind", [
    ("sequential", "sync"), ("sequential", "async"),
    ("single", "sync"), ("single", "async")])
def test_kill_and_resume_is_bit_exact(topology, kind):
    spec = _spec(kind=kind, topology=topology)
    base = api.run(api.compile_plan(spec))
    svc = SimService(api.compile_plan(spec))
    svc.run(max_records=1)
    with tempfile.TemporaryDirectory() as d:
        path = svc.checkpoint(os.path.join(d, "ck"))
        resumed = SimService.resume(path)
        rep = resumed.run()
        assert _recs(rep) == _recs(base)
        assert rep.resumed_from == path and rep.resume_round == 1
        assert base.resumed_from is None and base.resume_round is None
        assert rep.epsilon_spent == base.epsilon_spent


def test_empty_simspec_service_matches_batch_run():
    spec = _spec(kind="async", topology="single")
    base = api.run(api.compile_plan(spec))
    withsim = dataclasses.replace(spec, sim=api.SimSpec())
    rep = api.run(api.compile_plan(withsim))   # auto-routes through sim
    assert _recs(rep) == _recs(base)


def test_auto_checkpoint_cadence_writes_files():
    spec = _spec(kind="sync", topology="sequential")
    with tempfile.TemporaryDirectory() as d:
        svc = SimService(api.compile_plan(spec), checkpoint_dir=d,
                         checkpoint_every=1)
        svc.run()
        names = sorted(os.listdir(d))
        assert "ckpt_000001.npz" in names and "ckpt_000003.json" in names
        resumed = SimService.resume(os.path.join(d, "ckpt_000002"))
        rep = resumed.run()
    assert len(rep.records) == spec.rounds
    assert rep.resume_round == 2


# ---------------------------------------------------------------------------
# traces + events over the fleet engines (with repro.net live)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_spec():
    sim = api.SimSpec(
        traces=(api.TrafficTrace(kind="diurnal", period_s=50.0,
                                 amplitude=0.4),),
        events=(
            api.SimEvent(at_round=1, kind="attack",
                         payload={"malicious_frac": 0.5,
                                  "kind": "label_flip"}),
            api.SimEvent(at_round=3, kind="defense",
                         payload={"detect": True}),
        ))
    return _spec(
        kind="async", topology="single", rounds=5,
        network=api.NetworkSpec(codec="sparse_coo", bandwidth_sigma=0.3,
                                latency_s=0.01),
        defense=api.DefenseSpec(detect=False), sim=sim)


@pytest.fixture(scope="module")
def traced_base(traced_spec):
    return SimService(api.compile_plan(traced_spec)).run()


def test_attack_onset_event_triggers_detection(traced_spec, traced_base):
    """Attack at round 1 + defense toggle at round 3: the detector must
    start rejecting only after the toggle, and the trajectory must differ
    from the event-free run."""
    rejected = [r.n_rejected for r in traced_base.records]
    assert sum(rejected[:3]) == 0          # detector off until round 3
    assert sum(rejected[3:]) > 0           # then it fires on the attack
    assert traced_base.detections          # and the report logs it
    quiet = dataclasses.replace(
        traced_spec, sim=dataclasses.replace(traced_spec.sim, events=()))
    base = SimService(api.compile_plan(quiet)).run()
    assert _recs(base) != _recs(traced_base)


@pytest.mark.parametrize("kill_at", [2, 4])
def test_resume_across_event_boundaries(traced_spec, traced_base, kill_at):
    """Resuming from a checkpoint taken after events applied (mutated
    spec in the manifest) continues bit-exactly, including the NetSim
    byte accounting."""
    svc = SimService(api.compile_plan(traced_spec))
    svc.run(max_records=kill_at)
    with tempfile.TemporaryDirectory() as d:
        path = svc.checkpoint(os.path.join(d, "ck"))
        rep = SimService.resume(path).run()
    assert _recs(rep) == _recs(traced_base)
    assert rep.net == traced_base.net


def test_membership_events_and_outage_trace_resume():
    sim = api.SimSpec(
        traces=(api.TrafficTrace(kind="outage", t_start=0.0,
                                 duration_s=1e9, node_frac=0.4,
                                 region_start=0.5),),
        events=(api.SimEvent(at_round=1, kind="nodes",
                             payload={"leave": [0]}),
                api.SimEvent(at_round=2, kind="nodes",
                             payload={"join": [0]})))
    spec = _spec(kind="sync", topology="single", rounds=4,
                 network=api.NetworkSpec(codec="sparse_coo"), sim=sim)
    base = SimService(api.compile_plan(spec)).run()
    svc = SimService(api.compile_plan(spec))
    svc.run(max_records=1)
    with tempfile.TemporaryDirectory() as d:
        path = svc.checkpoint(os.path.join(d, "ck"))
        rep = SimService.resume(path).run()
    assert _recs(rep) == _recs(base)
    # the trace + membership actually moved the trajectory
    plain = dataclasses.replace(spec, sim=None)
    assert _recs(api.run(api.compile_plan(plain))) != _recs(base)


def test_records_jsonl_stream_rebuilt_on_resume(tmp_path):
    stream = str(tmp_path / "records.jsonl")
    spec = _spec(kind="sync", topology="sequential",
                 obs=api.ObsSpec(enabled=True, records_jsonl=stream))
    svc = SimService(api.compile_plan(spec))
    svc.run(max_records=2)
    path = svc.checkpoint(str(tmp_path / "ck"))
    rep = SimService.resume(path).run()
    replayed = api.replay_records(stream)
    assert len(replayed.records) == spec.rounds
    assert _recs(replayed) == _recs(rep)


# ---------------------------------------------------------------------------
# traffic math + sampler indirection (no runs)
# ---------------------------------------------------------------------------

def test_diurnal_modulation_math():
    trc = api.TrafficTrace(kind="diurnal", period_s=100.0, amplitude=0.5,
                           phase_s=0.0)
    scale, up = modulation((trc,), 4, 0.0)
    np.testing.assert_allclose(scale, 0.75)   # sin(0)=0 -> 1 - a/2
    assert up.all()
    scale, _ = modulation((trc,), 4, 25.0)    # sin peak -> 1 - a
    np.testing.assert_allclose(scale, 0.5)
    scale, _ = modulation((trc,), 4, 75.0)    # sin trough -> 1
    np.testing.assert_allclose(scale, 1.0)


def test_flash_crowd_and_outage_are_regional_and_epochal():
    flash = api.TrafficTrace(kind="flash_crowd", t_start=10.0,
                             duration_s=5.0, amplitude=0.8, node_frac=0.5,
                             region_start=0.5)
    out = api.TrafficTrace(kind="outage", t_start=10.0, duration_s=5.0,
                           node_frac=0.25, region_start=0.0)
    scale, up = modulation((flash, out), 8, 0.0)     # before both epochs
    assert scale is None and up.all()
    scale, up = modulation((flash, out), 8, 12.0)    # inside both
    region = region_mask(8, 0.5, 0.5)
    np.testing.assert_allclose(scale[region], 0.2)
    np.testing.assert_allclose(scale[~region], 1.0)
    np.testing.assert_array_equal(up, ~region_mask(8, 0.25, 0.0))
    scale, up = modulation((flash, out), 8, 15.0)    # epochs are half-open
    assert scale is None and up.all()


def test_region_mask_wraps():
    np.testing.assert_array_equal(
        region_mask(4, 0.5, 0.75),
        np.asarray([True, False, False, True]))


def test_dynamic_sampler_wraps_and_masks():
    dyn = DynamicSampler(4)
    idx, valid = dyn.cohort(0, 4)
    np.testing.assert_array_equal(idx, np.arange(4))
    assert valid.all()                       # == FullParticipation
    dyn.up[1] = False
    _, valid = dyn.cohort(1, 4)
    np.testing.assert_array_equal(valid, [True, False, True, True])
    # wrapping an RNG sampler: same draws, availability intersected
    a, b = UniformSampler(3, seed=7), UniformSampler(3, seed=7)
    wrapped = DynamicSampler(4, inner=a)
    idx_w, valid_w = wrapped.cohort(0, 4)
    idx_b, valid_b = b.cohort(0, 4)
    np.testing.assert_array_equal(idx_w, idx_b)
    np.testing.assert_array_equal(valid_w, valid_b & wrapped.up[idx_w])


# ---------------------------------------------------------------------------
# spec validation + serialization
# ---------------------------------------------------------------------------

def test_sim_spec_round_trips_through_json(traced_spec):
    d = json.loads(json.dumps(traced_spec.to_dict()))
    assert api.ExperimentSpec.from_dict(d) == traced_spec
    assert api.ExperimentSpec.from_dict(_spec().to_dict()).sim is None


def test_compile_validates_sim_timeline():
    with pytest.raises(api.SpecError, match="checkpoint_dir"):
        api.compile_plan(_spec(sim=api.SimSpec(checkpoint_every=2)))
    with pytest.raises(api.SpecError, match="net"):    # traces need repro.net
        api.compile_plan(_spec(
            topology="single",
            sim=api.SimSpec(traces=(api.TrafficTrace(kind="diurnal"),))))
    with pytest.raises(api.SpecError, match="at_round"):
        api.compile_plan(_spec(sim=api.SimSpec(events=(
            api.SimEvent(at_round=99, kind="defense",
                         payload={"detect": False}),))))
    with pytest.raises(api.SpecError, match="sequential"):
        api.compile_plan(_spec(sim=api.SimSpec(events=(
            api.SimEvent(at_round=1, kind="nodes",
                         payload={"leave": [0]}),))))
    # an event whose cumulative spec is invalid is rejected at compile
    with pytest.raises(api.SpecError, match="yields an invalid spec"):
        api.compile_plan(_spec(sim=api.SimSpec(events=(
            api.SimEvent(at_round=1, kind="attack",
                         payload={"malicious_frac": 2.0}),))))


def test_apply_sim_event_kinds():
    spec = _spec()
    ev = api.SimEvent(at_round=1, kind="defense", payload={"detect": False})
    assert not api.apply_sim_event(spec, ev).defense.detect
    assert api.apply_sim_event(
        spec, api.SimEvent(at_round=1, kind="nodes",
                           payload={"leave": [0]})) == spec
    with pytest.raises(ValueError, match="unknown SimEvent"):
        api.apply_sim_event(
            spec, dataclasses.replace(ev, kind="wormhole"))


def test_external_population_rejects_attack_events(traced_spec):
    pop = api.materialize(_spec(kind="async", topology="single"))
    with pytest.raises(ValueError, match="rematerialize"):
        SimService(api.compile_plan(traced_spec), population=pop)


def test_report_resume_metadata_round_trip():
    rep = api.RunReport(mode="sync", engine="fleet",
                        resumed_from="/ck/ckpt_000002", resume_round=2)
    d = json.loads(rep.to_json())
    assert d["schema_version"] == api.SCHEMA_VERSION
    loaded = api.RunReport.from_dict(d)
    assert loaded.resumed_from == "/ck/ckpt_000002"
    assert loaded.resume_round == 2
    # pre-v5 payloads carry no resume metadata -> uninterrupted
    old = {k: v for k, v in d.items()
           if k not in ("resumed_from", "resume_round")}
    old["schema_version"] = 4
    loaded = api.RunReport.from_dict(old)
    assert loaded.resumed_from is None and loaded.resume_round is None


# ---------------------------------------------------------------------------
# mesh topology: kill-and-resume on 8 forced host devices (subprocess,
# pattern from test_fleet_shard.py)
# ---------------------------------------------------------------------------

def test_mesh_resume_parity_on_8_devices_in_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, tempfile
        import jax
        from repro import api
        from repro.sim import SimService

        def recs(report):
            return [(r.t, r.version, r.accuracy, r.comm_bytes, r.comp_time,
                     r.comm_time, r.n_rejected) for r in report.records]

        out = {"n_devices": len(jax.devices())}
        for kind in ("sync", "async"):
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(n_nodes=6),
                schedule=api.SchedulePolicy(kind=kind),
                privacy=api.PrivacySpec(sigma=0.05),
                defense=api.DefenseSpec(detect=True),
                topology=api.Topology(kind="mesh", devices=8),
                train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
                rounds=3, seed=0)
            base = api.run(api.compile_plan(spec))
            svc = SimService(api.compile_plan(spec))
            svc.run(max_records=1)
            with tempfile.TemporaryDirectory() as d:
                p = svc.checkpoint(d + "/ck")
                rep = SimService.resume(p).run()
            out[kind + "_exact"] = recs(rep) == recs(base)
            out[kind + "_engine"] = rep.engine
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)          # the child forces its own devices
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    assert rec["sync_exact"] and rec["async_exact"]
    assert rec["sync_engine"] == "fleet-mesh"
