"""The `repro.api` redesign acceptance suite.

* Parity: for every scheme in {sync, async} × {σ=0, σ>0} (the paper's
  sfl/afl/sldpfl/aldpfl), the single-device fleet engines and the
  forced-8-device mesh reproduce the sequential reference loop's
  round-record trajectory bit-equal-to-float-close — the reference loops
  (`Topology(kind="sequential")`) are the retained parity oracles from
  the seed implementation.
* Shim retirement: the legacy `FederatedTrainer`/`FedConfig` surface is
  gone (its deprecation horizon was PR 4 -> ~PR 7).
* Spec/plan validation: `compile_plan` rejects the cross-field
  contradictions the old flag soup let through.
* Serialization: `ExperimentSpec` and `RunReport` JSON round trips
  (example-based + hypothesis).
* Window policies: resolve math and the load-aware target-arrivals
  policy vs the conservative parity-auto window.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from _optional import given, settings, st

from repro import api
from repro.api import RoundRecord
from repro.data import make_federated_image_data
from repro.fleet import NodeProfile
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# shared small population
# ---------------------------------------------------------------------------

N, ROUNDS = 5, 3

# the paper's four schemes as (schedule kind, noise multiplier)
SCHEMES = {"sfl": ("sync", 0.0), "afl": ("async", 0.0),
           "sldpfl": ("sync", 0.05), "aldpfl": ("async", 0.05)}


@pytest.fixture(scope="module")
def small_data():
    return make_federated_image_data(
        0, n_nodes=N, n_malicious=1, n_train=200, n_test=128,
        n_cloud_test=64, hw=(8, 8))


def _parity_spec(mode, topology="single", **kw):
    kind, sigma = SCHEMES[mode]
    base = dict(
        fleet=api.FleetSpec(n_nodes=N),
        schedule=api.SchedulePolicy(kind=kind),
        privacy=api.PrivacySpec(sigma=sigma),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True),
        topology=api.Topology(kind=topology),
        train=api.TrainSpec(local_steps=3, batch_size=16, lr=0.1),
        rounds=ROUNDS, seed=0)
    base.update(kw)
    return api.ExperimentSpec(**base)


def _population(small_data):
    node_data, test, cloud, _ = small_data
    return api.Population(
        params=init_mlp(jax.random.PRNGKey(0), 64), loss_fn=mlp_loss,
        acc_fn=mlp_accuracy, node_data=node_data, test_data=test,
        cloud_test=cloud,
        profile=NodeProfile.lognormal(N, 1.0, 0.5, 12.5e6, seed=0))


def _records_close(a, b, atol=2e-3, t_rtol=1e-5):
    # cross-engine virtual time accumulates in a different op order, so the
    # event-loop vs batched-window clocks agree to ~1e-5 relative (the same
    # tolerance the fleet-vs-sequential suite has pinned since PR 2), not
    # bitwise.
    assert len(a) == len(b)
    np.testing.assert_allclose([r.accuracy for r in a],
                               [r.accuracy for r in b], atol=atol)
    np.testing.assert_allclose([r.t for r in a], [r.t for r in b],
                               rtol=t_rtol)
    assert [r.n_rejected for r in a] == [r.n_rejected for r in b]
    assert [r.comm_bytes for r in a] == [r.comm_bytes for r in b]
    assert [r.version for r in a] == [r.version for r in b]


# ---------------------------------------------------------------------------
# parity: fleet engines ≡ sequential reference loop, all four schemes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sfl", "afl", "sldpfl", "aldpfl"])
def test_api_fleet_matches_sequential_reference(mode, small_data):
    """Single-device acceptance: the batched fleet engines reproduce the
    sequential reference loop (the seed per-node/per-arrival
    implementation, kept as `Topology(kind='sequential')`)
    bit-equal-to-float-close."""
    seq_plan = api.compile_plan(_parity_spec(mode, topology="sequential"))
    assert seq_plan.engine == "sequential"
    ref = api.run(seq_plan, population=_population(small_data))

    plan = api.compile_plan(_parity_spec(mode, topology="single"))
    assert plan.engine == "fleet"
    rep = api.run(plan, population=_population(small_data))
    _records_close(ref.records, rep.records)
    assert rep.epsilon_spent == pytest.approx(ref.epsilon_spent)
    assert rep.kappa == pytest.approx(ref.kappa)
    # report invariants
    assert rep.final_accuracy == rep.records[-1].accuracy
    assert rep.mode == ("sync" if mode in ("sfl", "sldpfl") else "async")
    assert all(d["n_rejected"] > 0 for d in rep.detections)


def test_legacy_trainer_shim_removed():
    """The `FederatedTrainer`/`FedConfig` deprecation shim (horizon set at
    PR 4) is gone: neither the legacy classes nor the lowering helpers
    survive anywhere on the public surface."""
    import repro.core as core
    assert not hasattr(core, "FedConfig")
    assert not hasattr(core, "FederatedTrainer")
    assert not hasattr(api, "spec_from_fed_config")
    assert not hasattr(api, "plan_from_fed_config")
    with pytest.raises(ImportError):
        from repro.core import federated  # noqa: F401


def test_api_mesh_matches_single_device_on_8_devices():
    """Mesh acceptance: all four schemes, forced-8-device host mesh —
    Topology('mesh') float-closes the single-device fleet trajectory
    (subprocess pattern from test_fleet_shard.py)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, numpy as np
        from repro import api
        from repro.data import make_federated_image_data
        from repro.fleet import NodeProfile
        from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

        n = 8
        node_data, test, cloud, _ = make_federated_image_data(
            0, n_nodes=n, n_malicious=2, n_train=320, n_test=128,
            n_cloud_test=64, hw=(8, 8))
        out = {"n_devices": len(jax.devices())}
        schemes = {"sfl": ("sync", 0.0), "afl": ("async", 0.0),
                   "sldpfl": ("sync", 0.05), "aldpfl": ("async", 0.05)}
        for mode, (kind, sigma) in schemes.items():
            spec = api.ExperimentSpec(
                fleet=api.FleetSpec(n_nodes=n),
                schedule=api.SchedulePolicy(kind=kind),
                privacy=api.PrivacySpec(sigma=sigma),
                compression=api.CompressionSpec(sparsify_ratio=0.5),
                defense=api.DefenseSpec(detect=True),
                topology=api.Topology(kind="single"),
                train=api.TrainSpec(local_steps=3, batch_size=16, lr=0.1),
                rounds=2, seed=0)

            def pop():
                return api.Population(
                    params=init_mlp(jax.random.PRNGKey(0), 64),
                    loss_fn=mlp_loss, acc_fn=mlp_accuracy,
                    node_data=node_data, test_data=test, cloud_test=cloud,
                    profile=NodeProfile.lognormal(n, 1.0, 0.5, 12.5e6,
                                                  seed=0))

            ref = api.run(api.compile_plan(spec), population=pop())
            mesh_spec = dataclasses.replace(
                spec, topology=api.Topology(kind="mesh", devices=8))
            rep = api.run(api.compile_plan(mesh_spec), population=pop())
            assert rep.engine == "fleet-mesh", rep.engine
            hist = ref.records
            out[f"{mode}_len"] = len(hist) - len(rep.records)
            out[f"{mode}_acc"] = max(abs(a.accuracy - b.accuracy)
                                     for a, b in zip(hist, rep.records))
            out[f"{mode}_t"] = max(abs(a.t - b.t)
                                   for a, b in zip(hist, rep.records))
            out[f"{mode}_rej"] = int(sum(a.n_rejected != b.n_rejected
                                         for a, b in zip(hist,
                                                         rep.records)))
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)          # the child forces its own devices
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8
    for mode in ("sfl", "afl", "sldpfl", "aldpfl"):
        assert rec[f"{mode}_len"] == 0, rec
        assert rec[f"{mode}_acc"] < 2e-3, rec
        assert rec[f"{mode}_t"] < 1e-6, rec
        assert rec[f"{mode}_rej"] == 0, rec


def test_execute_hands_back_state(small_data):
    """`execute` keeps the run state's PRNG key/residuals faithful —
    follow-on `execute` calls continue the chain, like the pre-redesign
    trainer's repeated run() did."""
    plan = api.compile_plan(_parity_spec("aldpfl"))
    pop = _population(small_data)
    state = api.init_state(plan, pop)
    key_before = np.asarray(state.key).copy()
    api.execute(plan, pop, state)
    assert not np.array_equal(np.asarray(state.key), key_before)
    assert len(state.history) == ROUNDS
    assert any(float(np.abs(np.asarray(leaf)).sum()) > 0
               for res in state.residuals
               for leaf in jax.tree.leaves(res))
    # a second execute continues the chain and the history
    key_mid = np.asarray(state.key).copy()
    api.execute(plan, pop, state)
    assert not np.array_equal(np.asarray(state.key), key_mid)
    assert len(state.history) == 2 * ROUNDS


def test_execute_rejects_mismatched_population(small_data):
    """An explicit Population must match the spec's fleet size — the
    arrival budget and record cadence derive from the spec, so a silent
    mismatch would run the wrong experiment (or return an empty report)."""
    spec = dataclasses.replace(
        _parity_spec("afl"), fleet=api.FleetSpec(n_nodes=N + 1))
    with pytest.raises(api.SpecError, match="population has"):
        api.run(api.compile_plan(spec),
                population=_population(small_data))


def test_sync_cohort_accountant_charges_participants_only():
    """ε accounting for sampled sync cohorts: only the nodes that
    actually uploaded a noised delta spend budget, not the whole fleet."""
    spec = _spec(
        fleet=api.FleetSpec(n_nodes=6, cohort_frac=0.5, samples_per_node=20,
                            n_test=32, n_cloud_test=16),
        privacy=api.PrivacySpec(sigma=0.05), rounds=2)
    plan = api.compile_plan(spec)
    pop = api.materialize(spec)
    state = api.init_state(plan, pop)
    api.execute(plan, pop, state)
    # UniformSampler(3 of 6) cohorts, 2 rounds -> 6 accountant steps
    assert state.accountant.steps == 2 * 3


# ---------------------------------------------------------------------------
# validation: compile_plan cross-field errors
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=4, samples_per_node=20, n_test=32,
                            n_cloud_test=16),
        schedule=api.SchedulePolicy(kind="sync"),
        train=api.TrainSpec(local_steps=1, batch_size=4, lr=0.1),
        rounds=1)
    base.update(kw)
    return api.ExperimentSpec(**base)


@pytest.mark.parametrize("bad,match", [
    (dict(schedule=api.SchedulePolicy(kind="fedsgd")), "schedule.kind"),
    (dict(topology=api.Topology(kind="cluster")), "topology.kind"),
    (dict(topology=api.Topology(kind="single", devices=4)),
     "not 'mesh'"),
    (dict(topology=api.Topology(kind="sequential"),
          schedule=api.SchedulePolicy(kind="buffered")),
     "no sequential reference"),
    (dict(topology=api.Topology(kind="sequential", backend="pallas")),
     "pallas"),
    (dict(schedule=api.SchedulePolicy(kind="sync",
                                      staleness_adaptive=True)),
     "staleness"),
    (dict(schedule=api.SchedulePolicy(
        kind="sync", window=api.FixedWindow(2.0))), "window"),
    (dict(schedule=api.SchedulePolicy(
        kind="async", window=api.TargetArrivalsWindow(4))), "buffered"),
    (dict(schedule=api.SchedulePolicy(
        kind="buffered", window=api.FixedWindow(-1.0))), "positive"),
    (dict(fleet=api.FleetSpec(n_nodes=4, availability=0.5,
                              cohort_frac=0.5)), "participation"),
    (dict(privacy=api.PrivacySpec(sigma=-0.1)), "sigma"),
    (dict(privacy=api.PrivacySpec(sigma=None, delta=2.0)), "delta"),
    (dict(compression=api.CompressionSpec(sparsify_ratio=0.0)),
     "sparsify"),
    (dict(defense=api.DefenseSpec(detect_s=100.0)), "percentile"),
    (dict(rounds=0), "rounds"),
])
def test_compile_plan_rejects_contradictions(bad, match):
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(**bad))


def test_compile_plan_resolves_derived_fields():
    plan = api.compile_plan(_spec(privacy=api.PrivacySpec(sigma=None)))
    assert plan.sigma == pytest.approx(
        np.sqrt(2 * np.log(1.25 / 1e-3)) / 8.0)
    assert plan.accountant
    assert plan.detect_window == 4          # default_window(4)
    assert plan.total_arrivals == 4
    plan0 = api.compile_plan(_spec())
    assert plan0.sigma == 0.0 and not plan0.accountant
    assert "aldp_perturb" not in plan0.stages


@pytest.mark.parametrize("bad,match", [
    (dict(fleet=api.FleetSpec(n_nodes=0)), "n_nodes"),
    (dict(train=api.TrainSpec(local_steps=1, batch_size=4, lr=0.0)), "lr"),
    (dict(schedule=api.SchedulePolicy(kind="sync", alpha=1.5)), "alpha"),
    (dict(defense=api.DefenseSpec(detect_warmup=0)), "detect_warmup"),
    (dict(defense=api.DefenseSpec(detect_window=0)), "detect_window"),
    (dict(privacy=api.PrivacySpec(sigma=-1.0)), "sigma"),
    (dict(fleet=api.FleetSpec(
        n_nodes=4, profile=api.NodeHeterogeneity(bandwidth_bps=0.0))),
     "bandwidth"),
    (dict(fleet=api.FleetSpec(
        n_nodes=4, profile=api.NodeHeterogeneity(heterogeneity=-0.1))),
     "heterogeneity"),
])
def test_compile_plan_rejects_out_of_range_knobs(bad, match):
    """The range checks the old FedConfig.validate carried now live only
    in `compile_plan` — out-of-range knobs fail at compile time, not deep
    inside a jitted round."""
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(**bad))


# ---------------------------------------------------------------------------
# serialization round trips
# ---------------------------------------------------------------------------

def test_spec_json_round_trip_example():
    spec = _spec(schedule=api.SchedulePolicy(
        kind="buffered", alpha=0.3,
        window=api.TargetArrivalsWindow(target_arrivals=6)))
    d = spec.to_dict()
    assert d["schema_version"] == api.SCHEMA_VERSION
    assert d["schedule"]["window"]["kind"] == "target_arrivals"
    spec2 = api.ExperimentSpec.from_json(spec.to_json())
    assert spec2 == spec


def test_spec_from_dict_rejects_wrong_schema():
    d = _spec().to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="schema_version"):
        api.ExperimentSpec.from_dict(d)


def test_report_json_round_trip_example(tmp_path):
    rep = api.RunReport(
        mode="async", engine="fleet",
        records=[RoundRecord(1.5, 0, 0.5, 1e6, 2.0, 0.1, 1),
                 RoundRecord(3.0, 1, 0.6, 1e6, 2.0, 0.1, 0)],
        kappa=0.05, epsilon_spent=1.25, final_accuracy=0.6,
        detections=[{"round": 0, "t": 1.5, "n_rejected": 1}],
        spec=_spec().to_dict())
    rep2 = api.RunReport.from_json(rep.to_json())
    assert rep2 == dataclasses.replace(rep, final_params=None)
    path = os.path.join(tmp_path, "r", "report.json")
    rep.save(path)
    assert api.RunReport.load(path).records == rep.records


def test_append_json_records_stamps_schema(tmp_path):
    path = os.path.join(tmp_path, "traj.json")
    api.append_json_records(path, [{"a": 1}])
    api.append_json_records(path, [{"b": 2, "schema_version": 1}])
    with open(path) as f:
        traj = json.load(f)
    assert len(traj) == 2
    # unstamped records get the current version; explicitly-stamped v1
    # records keep their (still-accepted) stamp — mixed trajectories stay
    # interpretable across the v2 bump
    assert traj[0]["schema_version"] == api.SCHEMA_VERSION
    assert traj[1]["schema_version"] == 1
    assert all(t["schema_version"] in api.ACCEPTED_SCHEMA_VERSIONS
               for t in traj)


_window_strategy = st.one_of(
    st.builds(api.AutoWindow),
    st.builds(api.FixedWindow,
              seconds=st.floats(0.1, 100.0, allow_nan=False)),
    st.builds(api.TargetArrivalsWindow,
              target_arrivals=st.integers(1, 1000)))

_spec_strategy = st.builds(
    api.ExperimentSpec,
    fleet=st.builds(
        api.FleetSpec,
        n_nodes=st.integers(1, 10_000),
        availability=st.floats(0.1, 1.0),
        cohort_frac=st.just(1.0),
        model=st.sampled_from(["mlp", "cnn"]),
        hw=st.tuples(st.integers(4, 32), st.integers(4, 32)),
        profile=st.builds(
            api.NodeHeterogeneity,
            heterogeneity=st.floats(0.0, 2.0),
            straggler_frac=st.floats(0.0, 1.0)),
        attack=st.builds(api.AttackMix,
                         malicious_frac=st.floats(0.0, 1.0))),
    schedule=st.builds(
        api.SchedulePolicy,
        kind=st.sampled_from(["async", "buffered"]),
        alpha=st.floats(0.0, 1.0),
        window=_window_strategy),
    privacy=st.builds(
        api.PrivacySpec,
        sigma=st.one_of(st.none(), st.floats(0.0, 2.0))),
    compression=st.builds(api.CompressionSpec,
                          sparsify_ratio=st.floats(0.01, 1.0)),
    defense=st.builds(api.DefenseSpec, detect=st.booleans(),
                      detect_s=st.floats(1.0, 99.0)),
    rounds=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1))


@settings(max_examples=30, deadline=None)
@given(spec=_spec_strategy)
def test_spec_json_round_trip_property(spec):
    assert api.ExperimentSpec.from_json(spec.to_json()) == spec


_records_strategy = st.lists(st.builds(
    RoundRecord,
    t=st.floats(0, 1e6, allow_nan=False),
    version=st.integers(0, 10_000),
    accuracy=st.floats(0, 1),
    comm_bytes=st.floats(0, 1e12, allow_nan=False),
    comp_time=st.floats(0, 1e6, allow_nan=False),
    comm_time=st.floats(0, 1e6, allow_nan=False),
    n_rejected=st.integers(0, 1000)), max_size=10)


@settings(max_examples=30, deadline=None)
@given(records=_records_strategy,
       kappa=st.floats(0, 1), eps=st.floats(0, 1e4))
def test_report_json_round_trip_property(records, kappa, eps):
    rep = api.RunReport(mode="sync", engine="fleet", records=records,
                        kappa=kappa, epsilon_spent=eps,
                        final_accuracy=records[-1].accuracy
                        if records else 0.0,
                        detections=api.detection_log(records))
    assert api.RunReport.from_json(rep.to_json()) == rep


# ---------------------------------------------------------------------------
# window policies
# ---------------------------------------------------------------------------

def test_window_policy_resolve_math():
    profile = NodeProfile(compute_s=np.array([1.0, 2.0, 4.0]),
                          bandwidth_bps=np.array([1e6, 1e6, 2e6]))
    bpn = 1e6                           # 1 MB upload
    assert api.AutoWindow().resolve(profile, bpn) is None
    assert api.FixedWindow(3.5).resolve(profile, bpn) == 3.5
    # periods: 1+1=2, 2+1=3, 4+0.5=4.5 -> rate = 1/2 + 1/3 + 1/4.5
    rate = 1 / 2 + 1 / 3 + 1 / 4.5
    got = api.TargetArrivalsWindow(target_arrivals=7).resolve(profile, bpn)
    assert got == pytest.approx(7 / rate)


def test_window_policy_registry_round_trip():
    for pol in (api.AutoWindow(), api.FixedWindow(2.0),
                api.TargetArrivalsWindow(16)):
        assert api.window_policy_from_dict(pol.to_dict()) == pol
    with pytest.raises(ValueError, match="unknown window policy"):
        api.window_policy_from_dict({"kind": "nope"})


def test_target_arrivals_beats_conservative_auto_window():
    """The load-aware buffered window processes the same arrival budget in
    (strictly) fewer, fatter device dispatches than the parity-safe auto
    window — the ROADMAP's target-arrivals-per-window item."""
    n, total = 8, 24
    base = _spec(
        fleet=api.FleetSpec(n_nodes=n, samples_per_node=20, n_test=32,
                            n_cloud_test=16,
                            profile=api.NodeHeterogeneity(heterogeneity=1.0)),
        schedule=api.SchedulePolicy(kind="buffered"),
        rounds=total // n)

    def run_windows(window):
        spec = dataclasses.replace(base, schedule=dataclasses.replace(
            base.schedule, window=window))
        plan = api.compile_plan(spec)
        eng = api.make_engine(plan, api.materialize(spec))
        eng.run_arrivals(total)
        assert sum(r.n_processed for r in eng.history) == total
        return len(eng.history)

    windows_auto = run_windows(api.AutoWindow())
    windows_target = run_windows(api.TargetArrivalsWindow(target_arrivals=n))
    assert windows_target < windows_auto, (windows_target, windows_auto)


# ---------------------------------------------------------------------------
# scenarios emit specs
# ---------------------------------------------------------------------------

def test_scenario_to_spec():
    from repro.fleet import get_scenario
    sc = get_scenario("async_buffered")
    spec = sc.to_spec(kind=sc.async_kind(), seed=3)
    assert spec.schedule.kind == "buffered"
    # kind=None falls back to the scenario's own declared schedule
    assert sc.to_spec().schedule.kind == "buffered"
    assert get_scenario("async_stragglers").to_spec().schedule.kind == \
        "async"
    assert get_scenario("honest").to_spec().schedule.kind == "sync"
    assert spec.schedule.window == api.FixedWindow(2.0)
    assert spec.seed == 3
    plan = api.compile_plan(spec)
    assert plan.mixing == "buffered"

    flip = get_scenario("label_flip_20").to_spec()
    assert flip.fleet.attack.malicious_frac == pytest.approx(0.2)
    assert flip.defense.detect
    # every named scenario lowers to a valid plan
    from repro.fleet import SCENARIOS
    for name, sc in SCENARIOS.items():
        kind = sc.async_kind() if name.startswith("async") else "sync"
        api.compile_plan(sc.to_spec(kind=kind))
