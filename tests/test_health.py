"""repro.obs fleet health: analytics, SLO probes, incidents, postmortems.

Tiers:
  * unit        — `FleetAnalytics` folds hand-built event streams into
    hand-computed indicators (straggler scores, occupancy/skew, byte
    accounting, confusion matrix); `HealthMonitor` opens/closes/
    finalizes level-triggered incidents with the right spans;
  * api         — `HealthSpec` serialization round trip, `compile_plan`
    rejections for contradictory health axes;
  * acceptance  — a hostile SimService run (straggler tail + armed
    detector + tight byte budget) fires real incidents reconstructable
    from the events JSONL alone; health disabled leaves the trajectory
    bit-identical; the postmortem and run-diff render from trace-only
    input, including through the `tools/obs_report.py` CLI.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro import api
from repro.obs import (FleetAnalytics, HealthMonitor, HealthSpec,
                       MemorySink, TraceEvent, Tracer, read_events,
                       read_jsonl)
from repro.obs.report import postmortem_md, run_diff_md
from repro.sim import SimService


def _ev(kind, name, virt_t=None, virt_dur=None, value=None, seq=0, **tags):
    return TraceEvent(kind=kind, name=name, wall_t=0.0, virt_t=virt_t,
                      virt_dur=virt_dur, value=value, tags=tags, seq=seq)


def _arrival(node, t):
    return _ev("instant", "arrival", virt_t=t, node=node, arrived=True)


def _verdict(node, rejected, threshold=0.5, detect=True):
    return _ev("instant", "detect.verdict", node=node, rejected=rejected,
               threshold=threshold, accuracy=0.6, detect=detect)


# ---------------------------------------------------------------------------
# unit: FleetAnalytics
# ---------------------------------------------------------------------------

def test_analytics_straggler_scores_hand_computed():
    events = [_ev("instant", "fleet.population", n_nodes=3, malicious=[])]
    events += [_arrival(0, t) for t in (0.0, 1.0, 2.0, 3.0)]   # gap 1.0
    events += [_arrival(1, t) for t in (0.0, 1.5, 3.0)]        # gap 1.5
    events += [_arrival(2, t) for t in (0.0, 9.0)]             # gap 9.0
    an = FleetAnalytics.from_events(events)
    # arrival counts [4, 3, 2]: median 3 >= min_arrivals, fleet is scored;
    # gaps [1.0, 1.5, 9.0], median 1.5
    scores = an.straggler_scores(min_arrivals=2)
    assert scores[0] == pytest.approx(1.0 / 1.5)
    assert scores[1] == pytest.approx(1.0)
    assert scores[2] == pytest.approx(9.0 / 1.5)
    top = an.top_stragglers(k=1)
    assert top[0]["node"] == 2 and top[0]["score"] == pytest.approx(6.0)
    # a cold fleet (median below min_arrivals) is not scored at all
    assert FleetAnalytics.from_events(
        events[:1] + [_arrival(0, 0.0), _arrival(1, 1.0)]
    ).straggler_scores() == {}


def test_analytics_scores_barely_seen_nodes_by_extent():
    """The straggler signature in a fixed-arrival-budget run is *absence*:
    a node with 0-1 arrivals must still score, via the run-extent lower
    bound, or the slowest nodes would be invisible to the probe."""
    events = [_ev("instant", "fleet.population", n_nodes=3, malicious=[])]
    events += [_arrival(0, float(t)) for t in range(11)]       # gap 1.0
    events += [_arrival(1, float(t)) for t in range(11)]       # gap 1.0
    events += [_arrival(2, 5.0)]                               # seen once
    an = FleetAnalytics.from_events(events)
    scores = an.straggler_scores(min_arrivals=2)
    # extent 10.0 over one arrival: gap lower-bound 10, median gap 1.0
    assert scores[2] == pytest.approx(10.0)
    # an entirely unseen node scores the same way (extent / 1)
    an2 = FleetAnalytics.from_events(events[:-1])
    assert an2.straggler_scores(min_arrivals=2)[2] == pytest.approx(10.0)


def test_analytics_occupancy_skew_and_bytes():
    events = [_ev("instant", "fleet.population", n_nodes=4, malicious=[])]
    for w, n_proc in enumerate((4, 4, 1)):
        events.append(_ev("span", "window", virt_t=float(w), virt_dur=1.0,
                          window=w, n_processed=n_proc, n_rejected=0))
        events.append(_ev("instant", "net.upload", node=0, window=w,
                          encoded_bytes=100 * (w + 1), retransmits=w))
    an = FleetAnalytics.from_events(events)
    assert an.recent_occupancy() == pytest.approx((4 + 4 + 1) / 3 / 4)
    assert an.window_skew() == pytest.approx(4.0 / 4.0)  # median 4, max 4
    assert an.total_upload_bytes == 600.0
    assert an.total_retransmits == 3
    assert an.bytes_by_record == {"window:0": 100.0, "window:1": 200.0,
                                  "window:2": 300.0}
    snap = an.snapshot()
    assert snap["n_windows"] == 3 and snap["n_nodes"] == 4
    json.dumps(snap)                            # snapshot is JSON-ready


def test_analytics_confusion_matrix_against_ground_truth():
    events = [_ev("instant", "fleet.population", n_nodes=4,
                  malicious=[1, 3])]
    events += [
        _verdict(1, rejected=True),             # malicious rejected: TP
        _verdict(3, rejected=False),            # malicious accepted: FN
        _verdict(0, rejected=True),             # honest rejected:    FP
        _verdict(2, rejected=False),            # honest accepted:    TN
        _verdict(2, rejected=False),            # honest accepted:    TN
        _verdict(1, rejected=True, detect=False),  # unarmed: not a verdict
    ]
    an = FleetAnalytics.from_events(events)
    det = an.detection_quality()
    assert (det["tp"], det["fp"], det["tn"], det["fn"]) == (1, 1, 2, 1)
    assert det["precision"] == pytest.approx(0.5)
    assert det["recall"] == pytest.approx(0.5)
    assert det["accuracy"] == pytest.approx(3 / 5)
    assert an.n_verdicts == 5 and an.n_rejected == 2
    assert an.recent_reject_rate(4) == pytest.approx(0.25)
    assert an.recent_reject_rate(6) is None     # not enough verdicts yet
    # without ground truth the confusion stays zeroed but rates still work
    an2 = FleetAnalytics.from_events(events[1:])
    assert an2.detection_quality()["ground_truth"] is False
    assert an2.reject_rate() == pytest.approx(0.4)


# ---------------------------------------------------------------------------
# unit: HealthMonitor
# ---------------------------------------------------------------------------

def _monitor(spec, n_nodes=4):
    an = FleetAnalytics(n_nodes=n_nodes)
    sink = MemorySink()
    tr = Tracer([sink, an])
    return HealthMonitor(spec, an, tr, n_nodes=n_nodes), tr, sink


def test_monitor_reject_rate_open_close_cycle():
    spec = HealthSpec(reject_rate_threshold=0.5, reject_rate_window=4,
                      warmup_records=0)
    mon, tr, sink = _monitor(spec)
    for i in range(4):
        tr.instant("detect.verdict", node=i % 4, rejected=True,
                   threshold=0.5, detect=True)
    mon.evaluate(virt_t=10.0, records_done=1)
    alerts = [e for e in sink.events if e.name == "health.alert"]
    assert len(alerts) == 1
    assert alerts[0].tags["probe"] == "reject_rate"
    assert alerts[0].tags["value"] == pytest.approx(1.0)
    assert not [e for e in sink.events if e.name == "health.incident"]
    # condition persists: same incident, no second alert
    mon.evaluate(virt_t=11.0, records_done=2)
    assert len([e for e in sink.events if e.name == "health.alert"]) == 1
    # condition clears: the incident closes with its full virtual extent
    for i in range(4):
        tr.instant("detect.verdict", node=i % 4, rejected=False,
                   threshold=0.5, detect=True)
    mon.evaluate(virt_t=15.0, records_done=3)
    (inc,) = [e for e in sink.events if e.name == "health.incident"]
    assert inc.kind == "span" and inc.virt_t == 10.0
    assert inc.virt_dur == pytest.approx(5.0)
    assert inc.tags["resolved"] is True and inc.tags["polls"] == 2
    assert inc.tags["worst"] == pytest.approx(1.0)
    assert tr.metrics.snapshot()["health.incidents"]["value"] == 1.0


def test_monitor_byte_budget_and_warmup():
    spec = HealthSpec(bytes_per_record_budget=100.0, warmup_records=2)
    mon, tr, sink = _monitor(spec)
    tr.instant("net.upload", node=0, encoded_bytes=500, window=0)
    mon.evaluate(virt_t=1.0, records_done=0)     # warmup: no probe fires
    mon.evaluate(virt_t=2.0, records_done=1)
    assert not [e for e in sink.events if e.name == "health.alert"]
    # past warmup the probe meters the post-warmup byte delta per record
    tr.instant("net.upload", node=1, encoded_bytes=400, window=2)
    mon.evaluate(virt_t=3.0, records_done=2)
    (alert,) = [e for e in sink.events if e.name == "health.alert"]
    assert alert.tags["probe"] == "byte_budget"
    assert alert.tags["value"] == pytest.approx(400.0)
    # finalize closes the still-open incident, tagged unresolved
    mon.finalize(virt_t=4.0, records_done=3)
    (inc,) = [e for e in sink.events if e.name == "health.incident"]
    assert inc.tags["resolved"] is False
    mon.finalize(virt_t=5.0, records_done=3)     # idempotent
    assert len([e for e in sink.events
                if e.name == "health.incident"]) == 1


def test_monitor_straggler_per_node_incidents():
    spec = HealthSpec(straggler_factor=3.0, straggler_min_arrivals=2,
                      warmup_records=0)
    mon, tr, sink = _monitor(spec, n_nodes=3)
    for t in range(8):
        tr.instant("arrival", virt_t=float(t), node=0)
        tr.instant("arrival", virt_t=float(t), node=1)
    tr.instant("arrival", virt_t=0.0, node=2)    # the slow tail: seen once
    mon.evaluate(virt_t=8.0, records_done=4)
    (alert,) = [e for e in sink.events if e.name == "health.alert"]
    assert alert.tags["probe"] == "straggler" and alert.tags["node"] == 2
    mon.finalize(virt_t=9.0, records_done=5)
    (inc,) = [e for e in sink.events if e.name == "health.incident"]
    assert inc.tags["node"] == 2


# ---------------------------------------------------------------------------
# api: serialization + compile_plan validation
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=4, samples_per_node=20, n_test=32,
                            n_cloud_test=16,
                            attack=api.AttackMix(malicious_frac=0.25)),
        schedule=api.SchedulePolicy(kind="async"),
        defense=api.DefenseSpec(detect=True),
        network=api.NetworkSpec(codec="sparse_coo"),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=2, seed=0)
    base.update(kw)
    return api.ExperimentSpec(**base)


def test_health_spec_round_trips_and_lowers():
    h = HealthSpec(straggler_factor=4.0, bytes_per_record_budget=1e4,
                   reject_rate_threshold=0.4, warmup_records=3)
    spec = _spec(obs=api.ObsSpec(enabled=True, health=h))
    back = api.ExperimentSpec.from_dict(json.loads(spec.to_json()))
    assert back.obs.health == h and back == spec
    plan = api.compile_plan(spec)
    assert "health_probes" in plan.stages
    assert "health_probes" not in api.compile_plan(_spec()).stages
    # pre-health payloads (schema v5) still load, health defaulting off
    d = spec.to_dict()
    d["schema_version"] = 5
    del d["obs"]["health"]
    assert api.ExperimentSpec.from_dict(d).obs.health is None


@pytest.mark.parametrize("spec_kw, health_kw, match", [
    (dict(obs=None), dict(straggler_factor=3.0), "enabled"),
    (dict(), dict(), "no probe"),
    (dict(), dict(straggler_factor=0.5), "must be > 1"),
    (dict(), dict(straggler_factor=3.0, straggler_min_arrivals=1),
     "min_arrivals"),
    (dict(), dict(reject_rate_threshold=1.5), "reject_rate_threshold"),
    (dict(), dict(reject_rate_threshold=0.5, reject_rate_window=0),
     "reject_rate_window"),
    (dict(), dict(occupancy_floor=1.0), "occupancy_floor"),
    (dict(), dict(straggler_factor=3.0, warmup_records=-1), "warmup"),
    (dict(schedule=api.SchedulePolicy(kind="sync")),
     dict(straggler_factor=3.0), "arrival"),
    (dict(network=api.NetworkSpec()), dict(bytes_per_record_budget=1e3),
     "codec"),
    (dict(defense=api.DefenseSpec(detect=False)),
     dict(reject_rate_threshold=0.5), "detect"),
])
def test_compile_plan_rejects_bad_health(spec_kw, health_kw, match):
    obs_kw = spec_kw.pop("obs", "default")
    obs = (api.ObsSpec(enabled=False, health=HealthSpec(**health_kw))
           if obs_kw is None
           else api.ObsSpec(enabled=True, health=HealthSpec(**health_kw)))
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(obs=obs, **spec_kw))


# ---------------------------------------------------------------------------
# acceptance: a hostile SimService run pages, trace-only
# ---------------------------------------------------------------------------

def _hostile_spec(events_jsonl, health=True):
    hlt = HealthSpec(straggler_factor=3.0, straggler_min_arrivals=2,
                     bytes_per_record_budget=2000.0,
                     reject_rate_threshold=0.2, reject_rate_window=4,
                     warmup_records=1) if health else None
    return api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=4, samples_per_node=20, n_test=32, n_cloud_test=16,
            attack=api.AttackMix(malicious_frac=0.5),
            profile=api.NodeHeterogeneity(straggler_frac=0.25,
                                          straggler_slowdown=8.0)),
        schedule=api.SchedulePolicy(kind="async"),
        defense=api.DefenseSpec(detect=True, detect_warmup=2),
        network=api.NetworkSpec(codec="sparse_coo"),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        obs=api.ObsSpec(enabled=True, events_jsonl=events_jsonl,
                        health=hlt),
        topology=api.Topology(kind="single"),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        sim=api.SimSpec(), rounds=4, seed=0)


@pytest.fixture(scope="module")
def hostile_run(tmp_path_factory):
    td = tmp_path_factory.mktemp("health")
    path = str(td / "events.jsonl")
    rep = SimService(api.compile_plan(_hostile_spec(path))).run()
    return rep, path


def test_hostile_run_incidents_from_trace_alone(hostile_run):
    rep, path = hostile_run
    an = FleetAnalytics.from_events(read_events(path))
    probes = {str(i["probe"]) for i in an.incidents}
    assert {"straggler", "byte_budget"} <= probes, probes
    for inc in an.incidents:
        assert inc["duration"] is not None and inc["duration"] >= 0.0
        assert inc["t"] is not None
    assert len(an.alerts) >= len({(i["probe"], i.get("node"))
                                  for i in an.incidents})
    # ground truth rode the stream: confusion matrix is reconstructable
    det = an.detection_quality()
    assert det["ground_truth"] is True
    assert det["tp"] + det["fp"] + det["tn"] + det["fn"] == an.n_verdicts
    assert an.n_verdicts > 0


def test_health_disabled_is_bit_identical(hostile_run, tmp_path):
    """The off-by-default contract: the same hostile run without the
    health axis (and without it plus without obs entirely) produces the
    identical trajectory — probes observe, never steer."""
    rep, _ = hostile_run
    plain = str(tmp_path / "plain.jsonl")
    spec_off = dataclasses.replace(
        _hostile_spec(plain, health=False))
    rep_off = SimService(api.compile_plan(spec_off)).run()
    assert rep_off.records == rep.records
    assert rep_off.final_accuracy == rep.final_accuracy
    assert rep_off.detections == rep.detections
    spec_dark = dataclasses.replace(_hostile_spec(None, health=False),
                                    obs=api.ObsSpec())
    rep_dark = SimService(api.compile_plan(spec_dark)).run()
    assert rep_dark.records == rep.records


def test_postmortem_and_diff_render_trace_only(hostile_run, tmp_path):
    rep, path = hostile_run
    rows = read_jsonl(path)
    md = postmortem_md(rows, top_k=3)
    for section in ("# Fleet postmortem", "## Run summary", "## Incidents",
                    "## Top 3 stragglers", "## Detection quality"):
        assert section in md
    assert "straggler" in md and "byte_budget" in md
    # self-diff: no regressions, every metric unchanged
    diff, n_reg = run_diff_md(rows, rows)
    assert n_reg == 0 and "No regressions" in diff
    assert "unchanged" in diff


def test_obs_report_cli_subprocess(hostile_run, tmp_path):
    _, path = hostile_run
    repo = os.path.join(os.path.dirname(__file__), "..")
    tool = os.path.join(repo, "tools", "obs_report.py")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"))
    out_md = str(tmp_path / "pm.md")
    r = subprocess.run([sys.executable, tool, "postmortem", path,
                        "-o", out_md], capture_output=True, text=True,
                       env=env, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "# Fleet postmortem" in open(out_md).read()
    r = subprocess.run([sys.executable, tool, "diff", path, path,
                        "--fail-on-regression"], capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "No regressions" in r.stdout
