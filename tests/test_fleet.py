"""Fleet engine tests: stacked state, samplers, batched round equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.data import make_federated_image_data
from repro.fleet import (AvailabilityTrace, FleetData, FullParticipation,
                         SCENARIOS, UniformSampler, build_engine,
                         chain_node_keys, detect_masked, gather_nodes,
                         get_scenario, scatter_nodes, stack_trees,
                         unstack_tree)
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss


# ---------------------------------------------------------------------------
# stacked-state helpers
# ---------------------------------------------------------------------------

def test_stack_gather_scatter_roundtrip():
    trees = [{"w": jnp.full((3,), float(i)), "b": {"c": jnp.ones((2, 2)) * i}}
             for i in range(5)]
    stacked = stack_trees(trees)
    assert stacked["w"].shape == (5, 3)
    got = unstack_tree(stacked, 5)
    for a, b in zip(got, trees):
        np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))

    idx = jnp.array([3, 1])
    cohort = gather_nodes(stacked, idx)
    np.testing.assert_array_equal(np.asarray(cohort["w"][0]), 3.0)
    back = scatter_nodes(stacked, idx, jax.tree.map(lambda x: x * 10, cohort))
    np.testing.assert_array_equal(np.asarray(back["w"][3]), 30.0)
    np.testing.assert_array_equal(np.asarray(back["w"][0]), 0.0)  # untouched


def test_scatter_nodes_debug_rejects_conflicting_duplicates():
    """Duplicate scatter indices must carry identical values (the padded-
    cohort contract); the debug check catches silent last-write-wins."""
    tree = {"w": jnp.zeros((4, 2))}
    idx = jnp.array([1, 1, 3])
    same = {"w": jnp.ones((3, 2)).at[2].set(5.0)}
    out = scatter_nodes(tree, idx, same, debug=True)     # identical dups: ok
    np.testing.assert_array_equal(np.asarray(out["w"][1]), [1.0, 1.0])

    differing = {"w": jnp.asarray([[1.0, 1.0], [2.0, 2.0], [5.0, 5.0]])}
    with pytest.raises(ValueError, match="duplicated index 1"):
        scatter_nodes(tree, idx, differing, debug=True)
    # debug off: documented last-write-wins, no check
    out = scatter_nodes(tree, idx, differing, debug=False)
    np.testing.assert_array_equal(np.asarray(out["w"][1]), [2.0, 2.0])


def test_fleet_data_rejects_empty_shards():
    """`from_node_data` must fail loudly — not with `sizes.max()` blowing up
    or a padded size-0 shard poisoning randint — on empty input."""
    with pytest.raises(ValueError, match="empty node list"):
        FleetData.from_node_data([])
    good = (np.ones((3, 2), np.float32), np.ones(3, np.int32))
    empty = (np.zeros((0, 2), np.float32), np.zeros(0, np.int32))
    with pytest.raises(ValueError, match=r"node\(s\) \[1\]"):
        FleetData.from_node_data([good, empty])


def test_fleet_data_pads_unequal_shards():
    node_data = [(np.ones((4, 2), np.float32), np.ones(4, np.int32)),
                 (np.ones((7, 2), np.float32), np.ones(7, np.int32))]
    fd = FleetData.from_node_data(node_data)
    assert fd.x.shape == (2, 7, 2) and fd.y.shape == (2, 7)
    np.testing.assert_array_equal(np.asarray(fd.sizes), [4, 7])
    assert float(fd.x[0, 4:].sum()) == 0.0  # right-padding is zeros


def test_chain_node_keys_matches_sequential_split():
    key = jax.random.PRNGKey(42)
    seq = []
    k = key
    for _ in range(6):
        k, k1, k2 = jax.random.split(k, 3)
        seq.append((k1, k2))
    kend, k1s, k2s = chain_node_keys(key, 6)
    np.testing.assert_array_equal(np.asarray(kend), np.asarray(k))
    for i, (k1, k2) in enumerate(seq):
        np.testing.assert_array_equal(np.asarray(k1s[i]), np.asarray(k1))
        np.testing.assert_array_equal(np.asarray(k2s[i]), np.asarray(k2))


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def test_uniform_sampler_static_cohort():
    s = UniformSampler(4, seed=0)
    seen = set()
    for r in range(20):
        idx, valid = s.cohort(r, 10)
        assert idx.shape == (4,) and valid.all()
        assert len(set(idx)) == 4          # without replacement
        seen.update(idx.tolist())
    assert len(seen) > 4                   # cohorts actually rotate


def test_availability_trace_never_starves():
    s = AvailabilityTrace(probs=np.zeros(8), seed=0)
    for r in range(5):
        idx, valid = s.cohort(r, 8)
        assert idx.shape == (8,) and valid.sum() == 1

    trace = np.zeros((3, 8), bool)
    trace[1, 2] = True
    st = AvailabilityTrace(trace=trace, seed=0)
    _, v1 = st.cohort(1, 8)
    assert v1[2] and v1.sum() == 1


def test_availability_requires_exactly_one_source():
    with pytest.raises(ValueError):
        AvailabilityTrace()
    with pytest.raises(ValueError):
        AvailabilityTrace(probs=np.ones(4), trace=np.ones((2, 4), bool))


def test_availability_rejects_too_narrow_coverage():
    with pytest.raises(ValueError, match="covers 4 nodes"):
        AvailabilityTrace(trace=np.ones((2, 4), bool)).cohort(0, 8)
    with pytest.raises(ValueError, match="covers 4 nodes"):
        AvailabilityTrace(probs=np.ones(4)).cohort(0, 8)


# ---------------------------------------------------------------------------
# masked detection
# ---------------------------------------------------------------------------

def test_detect_masked_reduces_to_detect_when_all_valid():
    from repro.core.detection import detect
    accs = jnp.array([0.9, 0.92, 0.91, 0.88, 0.3, 0.25, 0.93, 0.89])
    m1, t1 = detect(accs, 30.0)
    m2, t2 = detect_masked(accs, jnp.ones(8, bool), 30.0)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert float(t1) == pytest.approx(float(t2))


def test_detect_masked_excludes_invalid_slots():
    accs = jnp.array([0.9, 0.91, 0.92, 0.0, 0.0])   # last two are padding
    valid = jnp.array([True, True, True, False, False])
    mask, thr = detect_masked(accs, valid, 50.0)
    assert not bool(mask[3]) and not bool(mask[4])
    # threshold from the valid three only: median 0.91, not dragged to 0
    assert float(thr) == pytest.approx(0.91, abs=1e-6)


# ---------------------------------------------------------------------------
# engine ≡ sequential reference loop (the acceptance bar: K=8, 5 rounds)
# ---------------------------------------------------------------------------

def _paired_sync_reports(sigma, sparsify):
    """(fleet report, sequential-reference report) for one sync scheme —
    the seed per-node loop (`Topology('sequential')`) is the parity
    oracle the batched engine is held to."""
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=8, n_malicious=2, n_train=640, n_test=256,
        n_cloud_test=128, hw=(8, 8))

    def run(topology):
        from repro.fleet import NodeProfile
        spec = api.ExperimentSpec(
            fleet=api.FleetSpec(n_nodes=8),
            schedule=api.SchedulePolicy(kind="sync"),
            privacy=api.PrivacySpec(sigma=sigma),
            compression=api.CompressionSpec(sparsify_ratio=sparsify),
            defense=api.DefenseSpec(detect=True),
            topology=api.Topology(kind=topology),
            train=api.TrainSpec(local_steps=8, batch_size=16, lr=0.1),
            rounds=5, seed=0)
        pop = api.Population(
            params=init_mlp(jax.random.PRNGKey(0), 64), loss_fn=mlp_loss,
            acc_fn=mlp_accuracy, node_data=node_data, test_data=test,
            cloud_test=cloud,
            profile=NodeProfile.lognormal(8, 1.0, 0.5, 12.5e6, seed=0))
        return api.run(api.compile_plan(spec), population=pop)

    return run("single"), run("sequential")


@pytest.mark.parametrize("sigma,sparsify", [
    (0.0, 1.0),           # plain sync FedAvg + detection (sfl)
    (0.05, 1.0),          # + LDP noise, shared PRNG chain (sldpfl)
    (0.05, 0.25),         # + DGC sparsified uploads
])
def test_fleet_sync_matches_sequential(sigma, sparsify):
    fleet_rep, seq_rep = _paired_sync_reports(sigma, sparsify)
    hf, hs = fleet_rep.records, seq_rep.records
    accs_f = np.array([r.accuracy for r in hf])
    accs_s = np.array([r.accuracy for r in hs])
    np.testing.assert_allclose(accs_f, accs_s, atol=2e-3)
    for a, b in zip(jax.tree.leaves(fleet_rep.final_params),
                    jax.tree.leaves(seq_rep.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # simulated clock, wire bytes and rejections agree too
    np.testing.assert_allclose([r.t for r in hf], [r.t for r in hs],
                               rtol=1e-9)
    assert [r.n_rejected for r in hf] == [r.n_rejected for r in hs]
    assert [r.comm_bytes for r in hf] == [r.comm_bytes for r in hs]
    assert fleet_rep.epsilon_spent == pytest.approx(seq_rep.epsilon_spent)


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def test_all_scenarios_build_and_run_one_round():
    for name in SCENARIOS:
        sc = get_scenario(name).with_nodes(min(SCENARIOS[name].n_nodes, 8))
        eng = build_engine(sc, seed=0)
        rec = eng.run(1)[-1]
        assert 0.0 <= rec.accuracy <= 1.0
        assert rec.n_participating >= 1


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_honest_fleet_learns():
    sc = dataclasses.replace(get_scenario("honest"), local_steps=10, lr=0.2)
    eng = build_engine(sc, seed=0)
    hist = eng.run(12)
    assert hist[-1].accuracy > hist[0].accuracy + 0.15, \
        [r.accuracy for r in hist]


def test_straggler_scenario_slows_rounds():
    base = build_engine(get_scenario("honest"), seed=0)
    slow = build_engine(get_scenario("stragglers").with_nodes(10), seed=0)
    base.run(1)
    slow.run(1)
    assert slow.history[0].comp_time > base.history[0].comp_time


def test_churn_scenario_partial_participation():
    eng = build_engine(get_scenario("churn"), seed=0)
    recs = eng.run(4)
    parts = [r.n_participating for r in recs]
    assert min(parts) >= 1 and max(parts) <= eng.n_nodes
    assert any(p < eng.n_nodes for p in parts)


def test_cohort_sampling_updates_only_sampled_residuals():
    """DGC residuals of nodes outside the cohort must stay untouched."""
    class LoggingSampler(UniformSampler):
        def __init__(self):
            super().__init__(3, seed=7)
            self.seen = set()

        def cohort(self, round_idx, n_nodes):
            idx, valid = super().cohort(round_idx, n_nodes)
            self.seen.update(idx.tolist())
            return idx, valid

    sc = dataclasses.replace(get_scenario("honest"), sparsify_ratio=0.25,
                             local_steps=3)
    sampler = LoggingSampler()
    eng = build_engine(sc, seed=0, sampler=sampler)
    eng.run(3)
    res_norm = np.asarray(jnp.stack([
        jnp.sqrt(sum(jnp.sum(jnp.square(leaf[i]))
                     for leaf in jax.tree.leaves(eng.state.residuals)))
        for i in range(eng.n_nodes)]))
    for node in range(eng.n_nodes):
        if node in sampler.seen:
            assert res_norm[node] > 0.0, node
        else:
            assert res_norm[node] == 0.0, node


# ---------------------------------------------------------------------------
# pallas backend (node-batched sparsify / ldp_noise kernels)
# ---------------------------------------------------------------------------

def test_ldp_fleet_kernel_matches_flat():
    from repro.kernels.ldp_noise import ldp_perturb_flat, ldp_perturb_fleet
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(3, 2000)).astype(np.float32))
    seeds = jnp.array([11, 22, 33], jnp.int32)
    scales = jnp.array([0.5, 1.0, 0.25], jnp.float32)
    batched = ldp_perturb_fleet(flat, seeds, scales, 0.3, 1.5)
    for i in range(3):
        single = ldp_perturb_flat(flat[i], seeds[i], scales[i], 0.3, 1.5)
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(single), atol=1e-6)


def test_sparsify_fleet_kernel_matches_flat():
    from repro.kernels.sparsify import sparsify_flat, sparsify_fleet
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(3, 1500)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(3, 1500)).astype(np.float32))
    thr = jnp.array([0.5, 1.0, 2.0], jnp.float32)
    up_b, nr_b = sparsify_fleet(g, r, thr)
    for i in range(3):
        up, nr = sparsify_flat(g[i], r[i], thr[i])
        np.testing.assert_allclose(np.asarray(up_b[i]), np.asarray(up),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(nr_b[i]), np.asarray(nr),
                                   atol=1e-6)


def test_pallas_backend_matches_reference_without_noise():
    """σ=0 removes the only backend-divergent piece (noise source); the
    sparsify threshold rule is shared, so trajectories must agree."""
    sc = dataclasses.replace(get_scenario("honest"), sparsify_ratio=0.25,
                             local_steps=4)
    ref = build_engine(sc, seed=0, backend="reference")
    pal = build_engine(sc, seed=0, backend="pallas")
    hr = ref.run(3)
    hp = pal.run(3)
    np.testing.assert_allclose([r.accuracy for r in hp],
                               [r.accuracy for r in hr], atol=2e-3)
    for a, b in zip(jax.tree.leaves(pal.params), jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_pallas_backend_noise_magnitude():
    """With σ>0 the pallas noise source differs from jax.random but its
    statistics must match N(0, (σS)²) on the uploaded deltas."""
    from repro.fleet.stages import aldp_pallas_cohort
    zeros = {"w": jnp.zeros((4, 4096))}
    k2s = jax.random.split(jax.random.PRNGKey(0), 4)
    sigma, clip_s = 0.5, 2.0
    out = aldp_pallas_cohort(zeros, k2s, sigma, clip_s)["w"]
    stds = np.asarray(out).std(axis=1)
    np.testing.assert_allclose(stds, sigma * clip_s, rtol=0.1)
    # node-distinct seeds => node-distinct noise
    assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))
