"""Hypothesis property tests for `repro.net` (skip cleanly without it).

* Codec round trips over arbitrary sparsity patterns — duplicate-free
  index sets, adversarial values, exactness for the f32 codecs and the
  scale/2 error bound for the quantized variant, with measured payload
  lengths always matching the closed-form `nbytes`.
* Bit packing: `_pack_bits`/`_unpack_bits` inverse for any width.
* Link-model determinism under the fixed counter-based PRNG chain: the
  k-th upload of node i costs the same virtual time no matter how uploads
  batch into windows, and two simulators with equal seeds agree draw for
  draw.
"""
import numpy as np
import pytest

from _optional import HAVE_HYPOTHESIS, given, settings, st

from repro import net
from repro.net.codecs import _pack_bits, _unpack_bits, index_bits
from repro.net.link import LinkProfile, draw_transfer, draw_transfer_batch


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

def _make_update(n: int, nnz_frac: float, seed: int, scale: float):
    """(n_params, update) with a duplicate-free random support set."""
    rng = np.random.default_rng(seed)
    nnz = int(min(n, 200) * nnz_frac)
    u = np.zeros(n, np.float32)
    if nnz:
        idx = rng.choice(n, nnz, replace=False)       # duplicate-free
        vals = rng.normal(scale=scale, size=nnz)
        vals[vals == 0] = 1.0                          # keep support exact
        u[idx] = vals.astype(np.float32)
    return n, u


def sparse_updates():
    # plain-strategy composition (st.composite has no no-hypothesis shim)
    return st.builds(_make_update,
                     n=st.integers(1, 3000),
                     nnz_frac=st.floats(0.0, 1.0),
                     seed=st.integers(0, 2**31 - 1),
                     scale=st.floats(1e-3, 1e3)) if HAVE_HYPOTHESIS else None


# ---------------------------------------------------------------------------
# codec properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(nu=sparse_updates(),
       name=st.sampled_from(["dense_f32", "sparse_coo", "sparse_bitpack"]))
def test_codec_round_trip_property(nu, name):
    n, u = nu
    codec = net.get_codec(name)
    msg = codec.encode(u)
    dec = codec.decode(msg)
    assert np.array_equal(dec, u)
    nnz = int((u != 0).sum())
    assert msg.nbytes == int(np.asarray(codec.nbytes(nnz, n)))


@settings(max_examples=60, deadline=None)
@given(nu=sparse_updates(), value_bits=st.sampled_from([8, 16]))
def test_quantized_codec_error_bound_property(nu, value_bits):
    n, u = nu
    codec = net.get_codec("sparse_bitpack", value_bits=value_bits)
    msg = codec.encode(u)
    dec = codec.decode(msg)
    scale = msg.meta.get("scale", 1.0)
    # |error| <= scale/2 per element (f32 rounding slack on top)
    bound = scale / 2 + 1e-6 * max(1.0, scale)
    assert float(np.abs(dec.astype(np.float64)
                        - u.astype(np.float64)).max()) <= bound
    # the support never grows (indices are exact)
    assert set(np.flatnonzero(dec)) <= set(np.flatnonzero(u))
    assert msg.nbytes == int(np.asarray(codec.nbytes(int((u != 0).sum()),
                                                     n)))


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 2**20),
       seed=st.integers(0, 2**31 - 1),
       count=st.integers(0, 64))
def test_pack_unpack_bits_inverse(n, seed, count):
    rng = np.random.default_rng(seed)
    bits = index_bits(n)
    vals = rng.integers(0, n, size=count)
    buf = _pack_bits(vals, bits)
    assert len(buf) == (count * bits + 7) // 8
    assert np.array_equal(_unpack_bits(buf, bits, count), vals)


# ---------------------------------------------------------------------------
# link-model determinism
# ---------------------------------------------------------------------------

_link_strategy = st.builds(
    LinkProfile,
    bandwidth_sigma=st.floats(0.0, 2.0),
    latency_s=st.floats(0.0, 1.0),
    jitter_s=st.floats(0.0, 1.0),
    loss_prob=st.floats(0.0, 0.9),
    mtu_bytes=st.integers(64, 9000))


@settings(max_examples=40, deadline=None)
@given(link=_link_strategy, seed=st.integers(0, 2**31 - 1),
       node=st.integers(0, 100), seq=st.integers(0, 1000))
def test_draw_transfer_deterministic_per_upload(link, seed, node, seq):
    """The fixed PRNG chain: the same (seed, node, seq) triple always
    yields the same transfer time, and a different seq (fresh chain
    counter) is free to differ."""
    a = draw_transfer(link, 1e6, 1e6, seed, node, seq)
    b = draw_transfer(link, 1e6, 1e6, seed, node, seq)
    assert a == b
    t, overhead, retrans = a
    assert t >= link.latency_s
    assert overhead == retrans * link.mtu_bytes
    if link.loss_prob == 0.0:
        assert retrans == 0


@settings(max_examples=20, deadline=None)
@given(link=_link_strategy, seed=st.integers(0, 2**31 - 1),
       split=st.integers(1, 5))
def test_netsim_draws_independent_of_batching(link, seed, split):
    """Window composition must not change per-upload times (absent shared-
    uplink contention): drawing 6 uploads in one batch or in two batches
    split anywhere yields identical transfer times, byte overheads and
    sequence numbers."""
    bw = np.full(6, 2e6)
    nodes = np.array([0, 1, 2, 3, 4, 5])
    s1 = net.NetSim("sparse_coo", link, bw, 10_000, sparsify_ratio=0.1,
                    seed=seed)
    s2 = net.NetSim("sparse_coo", link, bw, 10_000, sparsify_ratio=0.1,
                    seed=seed)
    d1 = s1.draw(nodes)
    d2a = s2.draw(nodes[:split])
    d2b = s2.draw(nodes[split:])
    merged_t = np.concatenate([d2a.transfer_s, d2b.transfer_s])
    merged_seq = np.concatenate([d2a.seqs, d2b.seqs])
    assert np.array_equal(d1.seqs, merged_seq)
    assert np.array_equal(d1.transfer_s, merged_t)
    # second pass advances every node's chain: same nodes, new seqs
    d3 = s1.draw(nodes)
    assert np.array_equal(d3.seqs, d1.seqs + 1)


def test_batched_draws_bit_equal_scalar_loop():
    """The vectorized stochastic path is the per-upload scalar loop,
    bit for bit — batching is a pure implementation detail of the
    counter-based hash stream."""
    link = LinkProfile(latency_s=0.02, jitter_s=0.4, loss_prob=0.25,
                       mtu_bytes=700)
    rng = np.random.default_rng(3)
    nodes = rng.integers(0, 50, size=64)
    seqs = rng.integers(0, 200, size=64)
    bw = rng.uniform(5e5, 5e6, size=64)
    bt, bo, br = draw_transfer_batch(link, 123_456, bw, 9, nodes, seqs,
                                     concurrency=64)
    for i in range(64):
        t, o, r = draw_transfer(link, 123_456, float(bw[i]), 9,
                                int(nodes[i]), int(seqs[i]), concurrency=64)
        assert (t, o, r) == (bt[i], bo[i], br[i])


def test_batched_draws_independent_of_packet_chunking(monkeypatch):
    """The packet-axis memory chunking never changes the bits."""
    from repro.net import link as link_mod
    link = LinkProfile(loss_prob=0.3, mtu_bytes=256)
    nodes = np.arange(16)
    seqs = np.zeros(16, np.int64)
    bw = np.full(16, 1e6)
    ref = draw_transfer_batch(link, 65_536, bw, 5, nodes, seqs)
    monkeypatch.setattr(link_mod, "_CHUNK_DRAWS", 32)
    tiny = draw_transfer_batch(link, 65_536, bw, 5, nodes, seqs)
    for a, b in zip(ref, tiny):
        assert np.array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(link=_link_strategy, seed=st.integers(0, 2**31 - 1),
       batches=st.lists(st.integers(1, 6), min_size=1, max_size=5),
       nnz_seed=st.integers(0, 2**31 - 1))
def test_netsim_summary_invariants_property(link, seed, batches, nnz_seed):
    """`NetTrace`/`NetSim.summary()` accounting invariants over arbitrary
    commit sequences: total_encoded_bytes is exactly the sum of the
    per-commit encodings, and n_uploads grows monotonically by each
    batch's size."""
    rng = np.random.default_rng(nnz_seed)
    sim = net.NetSim("sparse_coo", link, np.full(8, 1e6), 5_000,
                     sparsify_ratio=0.5, seed=seed)
    total, uploads = 0.0, 0
    for b in batches:
        nodes = rng.choice(8, size=b, replace=False)
        draw = sim.draw(nodes)
        enc = sim.commit(draw, rng.integers(0, 5_000, size=b))
        total += float(enc.sum())
        prev, uploads = uploads, sim.trace.n_uploads
        assert uploads == prev + b          # monotone, exact increments
    s = sim.summary()
    assert s == sim.trace.summary()
    assert s["n_uploads"] == uploads == sum(batches)
    assert s["encoded_bytes"] == sim.trace.total_encoded_bytes == total
    assert s["wire_bytes"] >= s["encoded_bytes"]
    assert s["retransmits"] >= 0


def test_shared_uplink_contention_depends_on_concurrency():
    """The documented exception to batching-independence: a shared uplink
    divides capacity across the window's concurrent uploads."""
    link = LinkProfile(shared_uplink_bps=4e6)
    bw = np.full(4, 1e9)                    # node uplinks never the cap
    s_wide = net.NetSim("dense_f32", link, bw, 1000, seed=0)
    s_solo = net.NetSim("dense_f32", link, bw, 1000, seed=0)
    wide = s_wide.draw(np.arange(4))        # 4-way contention
    solo = s_solo.draw(np.array([0]))       # alone on the uplink
    assert wide.transfer_s[0] == pytest.approx(4 * solo.transfer_s[0])
