"""repro.obs: event tracing, metrics, sinks, and the instrumented stack.

Tiers:
  * unit        — TraceEvent round trip, disabled-tracer no-ops, span
    nesting/seq order, metrics registry semantics, JSONL torn-tail
    handling, Chrome-trace structure, `bench_kernel`/`timed_stage`
    gating;
  * api         — `ObsSpec` validation in `compile_plan`, crash-safe
    `append_json_records`;
  * acceptance  — a traced async run over a lossy network produces a
    Perfetto-loadable Chrome trace plus a streaming records JSONL whose
    replay reconstructs the final `RunReport` exactly, and a detection
    audit log that reconstructs Fig. 6's rejection series;
  * net         — `NetTrace`/`NetSim.summary()` invariants;
  * mesh        — obs event ordering on a forced-8-device host
    (subprocess pattern from test_fleet_shard.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro import obs
from repro.net import LinkProfile, NetSim
from repro.obs import (MemorySink, MetricsRegistry, TraceEvent, Tracer,
                       bench_kernel, chrome_trace, read_events, read_jsonl,
                       timed_stage, use_tracer)
from repro.obs.timers import _NULL_STAGE


# ---------------------------------------------------------------------------
# unit: events
# ---------------------------------------------------------------------------

def test_trace_event_round_trip():
    ev = TraceEvent(kind="span", name="window", wall_t=1.5, virt_t=10.0,
                    dur=0.25, virt_dur=3.0, tags={"window": 2}, seq=7)
    back = TraceEvent.from_dict(ev.to_dict())
    assert back == ev
    with pytest.raises(ValueError, match="kind"):
        TraceEvent.from_dict({"kind": "nope", "name": "x", "wall_t": 0.0})


def test_disabled_tracer_is_noop():
    sink = MemorySink()
    tr = Tracer([sink], enabled=False)
    tr.instant("a", node=1)
    tr.counter("b", 1.0)
    s1, s2 = tr.span("c"), tr.span("d")
    with s1:
        pass
    assert s1 is s2, "disabled span must be the shared null context"
    assert sink.events == []


def test_span_nesting_seq_order_and_tags():
    sink = MemorySink()
    tr = Tracer([sink])
    with tr.span("outer", window=0) as outer:
        tr.instant("inner.point", node=3)
        with tr.span("inner") as inner:
            inner.set(found=2)
        outer.set_virtual(virt_t=5.0, virt_end=9.0)
    names = [e.name for e in sink.events]
    # spans emit at *exit*: inner closes before outer
    assert names == ["inner.point", "inner", "outer"]
    seqs = [e.seq for e in sink.events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert sink.events[1].tags == {"found": 2}
    outer_ev = sink.events[2]
    assert outer_ev.virt_t == 5.0 and outer_ev.virt_dur == 4.0
    assert outer_ev.dur is not None and outer_ev.dur >= 0.0


# ---------------------------------------------------------------------------
# unit: metrics
# ---------------------------------------------------------------------------

def test_metrics_registry_semantics():
    mx = MetricsRegistry()
    mx.counter("up").inc(3)
    mx.counter("up").inc(2.5)
    mx.gauge("ver").set(7)
    h = mx.histogram("lat", [1.0, 2.0, 4.0])
    for v in (0.5, 1.5, 100.0):
        h.observe(v)
    snap = mx.snapshot()
    assert snap["up"] == {"type": "counter", "value": 5.5}
    assert snap["ver"]["value"] == 7.0
    assert snap["lat"]["counts"] == [1, 1, 0, 1]       # +inf overflow bucket
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["min"] == 0.5 and snap["lat"]["max"] == 100.0
    assert list(snap) == sorted(snap)
    with pytest.raises(ValueError, match="edges"):
        mx.histogram("lat", [1.0, 999.0])              # edges are frozen
    with pytest.raises(TypeError, match="Counter"):
        mx.gauge("up")                                 # type-checked re-touch


# ---------------------------------------------------------------------------
# unit: JSONL sinks (satellite: crash-exposure)
# ---------------------------------------------------------------------------

def test_jsonl_torn_tail_rejected_cleanly(tmp_path):
    p = str(tmp_path / "ev.jsonl")
    sink = obs.JsonlSink(p, header={"stream": "t"})
    tr = Tracer([sink])
    for i in range(3):
        tr.instant("tick", i=i)
    tr.close()
    clean = read_jsonl(p)
    assert clean[0]["kind"] == "header" and clean[0]["obs_schema"] == 1
    assert len(clean) == 4
    # simulate a crash mid-append: torn final line
    with open(p, "a") as f:
        f.write('{"kind":"instant","name":"tor')
    with pytest.raises(ValueError, match="truncated final"):
        read_jsonl(p)
    dropped = read_jsonl(p, strict=False)
    assert dropped == clean, "strict=False must drop exactly the torn tail"
    assert len(read_events(p, strict=False)) == 3
    # a torn line *before* the end is corruption and always raises
    with open(p, "a") as f:
        f.write('\n{"kind":"instant","name":"fine","wall_t":0}\n')
    with pytest.raises(ValueError, match="corrupt"):
        read_jsonl(p, strict=False)


def test_chrome_trace_structure():
    sink = MemorySink()
    tr = Tracer([sink])
    with tr.span("window", window=0) as sp:
        tr.instant("arrival", virt_t=2.0, node=4)
        tr.counter("bytes", 128.0, virt_t=2.5)
        sp.set_virtual(virt_t=0.0, virt_end=3.0)
    doc = chrome_trace(sink.events)
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"M", "X", "i", "C"}
    tracks = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert tracks == {"cloud", "node 4"}
    arr = next(e for e in evs if e["ph"] == "i")
    assert arr["ts"] == pytest.approx(2.0 * 1e6)       # virtual clock wins
    span = next(e for e in evs if e["ph"] == "X")
    assert span["dur"] == pytest.approx(3.0 * 1e6)
    json.dumps(doc)                                    # serializable as-is


def test_chrome_trace_counter_tracks_perfetto_shape():
    """Counter events must export as Perfetto *counter tracks*: phase
    "C", value under args keyed by the counter name, and per-node
    counters on distinctly named tracks (Perfetto identifies counter
    tracks by (pid, name) — two nodes sharing one name would interleave
    into a single garbled series)."""
    sink = MemorySink()
    tr = Tracer([sink])
    tr.counter("bytes", 100.0, virt_t=1.0, node=0)
    tr.counter("bytes", 250.0, virt_t=2.0, node=1)
    tr.counter("ring.held", 3.0, virt_t=2.5)           # cloud-side counter
    doc = chrome_trace(sink.events)
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len(counters) == 3
    for c in counters:
        assert set(c) >= {"ph", "name", "pid", "tid", "ts", "args"}
        assert len(c["args"]) == 1                     # one series per track
    by_name = {c["name"]: c for c in counters}
    # per-node counters: distinct track names, value keyed by counter name
    assert by_name["bytes (node 0)"]["args"] == {"bytes": 100.0}
    assert by_name["bytes (node 1)"]["args"] == {"bytes": 250.0}
    # cloud-track counters keep the bare name
    assert by_name["ring.held"]["args"] == {"ring.held": 3.0}
    assert by_name["ring.held"]["tid"] == 1            # the cloud track
    json.dumps(doc)


def test_histogram_quantile_hand_computed():
    mx = MetricsRegistry()
    h = mx.histogram("lat", [1.0, 2.0, 4.0])
    assert h.quantile(0.5) is None                     # empty histogram
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    # counts [1, 1, 1, 1]: one per bucket incl. the +inf overflow; outer
    # bounds are the observed min/max (0.5 and 100.0)
    assert h.quantile(0.0) == pytest.approx(0.5)
    assert h.quantile(0.25) == pytest.approx(1.0)
    assert h.quantile(0.5) == pytest.approx(2.0)
    assert h.quantile(0.9) == pytest.approx(4.0 + (100.0 - 4.0) * 0.6)
    assert h.quantile(1.0) == pytest.approx(100.0)
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)
    # single-value histogram: every quantile is that value
    h1 = MetricsRegistry().histogram("one", [10.0])
    h1.observe(3.0)
    assert h1.quantile(0.5) == pytest.approx(3.0)


def test_to_prom_text_hand_computed():
    mx = MetricsRegistry()
    mx.counter("net.uploads").inc(12)
    mx.gauge("ring.occupancy").set(0.75)
    h = mx.histogram("lat", [1.0, 2.0])
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    text = mx.to_prom_text()
    lines = text.splitlines()
    assert "# TYPE lat histogram" in lines
    assert 'lat_bucket{le="1"} 1' in lines              # cumulative
    assert 'lat_bucket{le="2"} 2' in lines
    assert 'lat_bucket{le="+Inf"} 3' in lines
    assert "lat_sum 11" in lines
    assert "lat_count 3" in lines
    # dots sanitized to the Prometheus charset
    assert "# TYPE net_uploads counter" in lines
    assert "net_uploads 12" in lines
    assert "ring_occupancy 0.75" in lines
    assert text.endswith("\n")
    assert MetricsRegistry().to_prom_text() == ""


# ---------------------------------------------------------------------------
# unit: read_jsonl edge cases (satellite: crash-exposure corners)
# ---------------------------------------------------------------------------

def test_read_jsonl_header_only_file(tmp_path):
    p = str(tmp_path / "empty.jsonl")
    w = obs.JsonlWriter(p, header={"stream": "events"})
    w.close()
    rows = read_jsonl(p)
    assert len(rows) == 1 and rows[0]["kind"] == "header"
    assert read_events(p) == []
    assert read_jsonl(p, strict=False) == rows


def test_read_jsonl_tail_valid_json_prefix_is_kept(tmp_path):
    """A crash between the JSON bytes and the trailing newline leaves a
    final line that is *complete valid JSON* — indistinguishable from a
    clean last line, so it is kept under both strictness modes (the
    documented limit of newline-framed crash detection)."""
    p = str(tmp_path / "ev.jsonl")
    sink = obs.JsonlSink(p, header={"stream": "t"})
    tr = Tracer([sink])
    tr.instant("tick", i=0)
    tr.instant("tick", i=1)
    tr.close()
    clean = read_jsonl(p)
    with open(p) as f:
        body = f.read()
    assert body.endswith("\n")
    with open(p, "w") as f:
        f.write(body[:-1])                  # crash ate only the newline
    assert read_jsonl(p) == clean
    assert read_jsonl(p, strict=False) == clean


def test_read_jsonl_strict_false_drops_exactly_one(tmp_path):
    p = str(tmp_path / "ev.jsonl")
    sink = obs.JsonlSink(p, header={"stream": "t"})
    tr = Tracer([sink])
    for i in range(5):
        tr.instant("tick", i=i)
    tr.close()
    clean = read_jsonl(p)
    with open(p, "a") as f:
        f.write('{"kind":"instant","name":"torn","wall_t":1.2,"ta')
    dropped = read_jsonl(p, strict=False)
    assert dropped == clean                 # exactly the torn tail is gone
    assert len(dropped) == 6                # header + 5 complete records


# ---------------------------------------------------------------------------
# unit: timers
# ---------------------------------------------------------------------------

def test_timed_stage_gating():
    off = Tracer(enabled=False)
    assert timed_stage(off, "x") is _NULL_STAGE
    on_untimed = Tracer([MemorySink()], enabled=True, stage_timings=False)
    assert timed_stage(on_untimed, "x") is _NULL_STAGE, \
        "stage timing must be a separate opt-in (fencing changes perf)"
    sink = MemorySink()
    on = Tracer([sink], enabled=True, stage_timings=True)
    with timed_stage(on, "round.device", round=3) as st:
        assert st.fence({"a": 1}) == {"a": 1}
    (ev,) = sink.events
    assert ev.name == "stage.round.device" and ev.tags == {"round": 3}


def test_bench_kernel_emits_counter_and_histogram():
    import jax.numpy as jnp
    sink = MemorySink()
    tr = Tracer([sink])
    us = bench_kernel("dot", lambda a: a @ a, jnp.eye(8), iters=2, tracer=tr)
    assert us > 0.0
    (ev,) = [e for e in sink.events if e.kind == "counter"]
    assert ev.name == "kernel.dot" and ev.value == pytest.approx(us)
    snap = tr.metrics.snapshot()["kernel.us_per_call"]
    assert snap["count"] == 1


# ---------------------------------------------------------------------------
# api: ObsSpec validation + crash-safe trajectory appends
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(
        fleet=api.FleetSpec(n_nodes=4, samples_per_node=20, n_test=32,
                            n_cloud_test=16,
                            attack=api.AttackMix(malicious_frac=0.25)),
        schedule=api.SchedulePolicy(kind="async"),
        defense=api.DefenseSpec(detect=True),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=2, seed=0)
    base.update(kw)
    return api.ExperimentSpec(**base)


@pytest.mark.parametrize("obs_kw, match", [
    (dict(events_jsonl="x.jsonl"), "enabled"),
    (dict(chrome_trace="t.json"), "enabled"),
    (dict(records_jsonl="r.jsonl"), "enabled"),
    (dict(stage_timings=True), "enabled"),
    (dict(enabled=True, events_jsonl=""), "empty"),
])
def test_compile_plan_rejects_bad_obs(obs_kw, match):
    with pytest.raises(api.SpecError, match=match):
        api.compile_plan(_spec(obs=api.ObsSpec(**obs_kw)))


def test_compile_plan_rejects_stage_timings_on_sequential():
    spec = _spec(obs=api.ObsSpec(enabled=True, stage_timings=True),
                 topology=api.Topology(kind="sequential"))
    with pytest.raises(api.SpecError, match="sequential"):
        api.compile_plan(spec)


def test_obs_stage_lowered_and_spec_round_trips():
    plan = api.compile_plan(_spec(obs=api.ObsSpec(enabled=True)))
    assert "obs_trace" in plan.stages
    plan_off = api.compile_plan(_spec())
    assert "obs_trace" not in plan_off.stages
    spec = _spec(obs=api.ObsSpec(enabled=True, events_jsonl="e.jsonl",
                                 stage_timings=True))
    back = api.ExperimentSpec.from_dict(spec.to_dict())
    assert back.obs == spec.obs


def test_append_json_records_crash_safe(tmp_path):
    p = str(tmp_path / "traj.json")
    api.append_json_records(p, [{"name": "a", "v": 1}])
    api.append_json_records(p, [{"name": "b", "v": 2}])
    traj = api.load_json_records(p)
    assert [t["name"] for t in traj] == ["a", "b"]
    assert all(t["schema_version"] == api.SCHEMA_VERSION for t in traj)
    # a stale half-written temp file from a crashed appender must not
    # poison the next append (write goes to tmp, then os.replace)
    with open(p + ".tmp", "w") as f:
        f.write('[{"torn": ')
    api.append_json_records(p, [{"name": "c"}])
    assert not os.path.exists(p + ".tmp")
    assert [t["name"] for t in api.load_json_records(p)] == ["a", "b", "c"]
    # non-list file: loud error, file untouched
    solo = str(tmp_path / "solo.json")
    with open(solo, "w") as f:
        json.dump({"not": "a list"}, f)
    with pytest.raises(ValueError, match="trajectory list"):
        api.append_json_records(solo, [{"name": "d"}])
    with pytest.raises(ValueError, match="trajectory list"):
        api.load_json_records(solo)


# ---------------------------------------------------------------------------
# acceptance: one traced async run over a lossy network
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    td = tmp_path_factory.mktemp("obs")
    paths = {"events": str(td / "events.jsonl"),
             "chrome": str(td / "trace.json"),
             "records": str(td / "records.jsonl")}
    spec = _spec(
        network=api.NetworkSpec(codec="sparse_coo", loss_prob=0.1,
                                jitter_s=0.5, bandwidth_sigma=1.0),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        obs=api.ObsSpec(enabled=True, events_jsonl=paths["events"],
                        chrome_trace=paths["chrome"],
                        records_jsonl=paths["records"],
                        stage_timings=True),
        rounds=3)
    rep = api.run(api.compile_plan(spec))
    return spec, rep, paths


def test_traced_run_event_stream(traced_run):
    _, rep, paths = traced_run
    rows = read_jsonl(paths["events"])
    assert rows[0]["kind"] == "header"
    names = {r["name"] for r in rows if r.get("kind") in
             ("span", "instant", "counter")}
    assert {"window", "arrival", "detect.verdict", "net.upload"} <= names
    assert any(n.startswith("stage.") for n in names)
    # the run-end metrics snapshot rides the same stream
    (mrow,) = [r for r in rows if r.get("kind") == "metrics"]
    mx = mrow["metrics"]
    # every processed arrival is one committed upload on the net path
    assert mx["window.arrivals"]["value"] == rep.net["n_uploads"]
    assert mx["net.uploads"]["value"] == rep.net["n_uploads"]
    assert mx["net.encoded_bytes"]["value"] == rep.net["encoded_bytes"]
    # per-upload link events reconcile with the NetTrace totals
    ups = [r for r in rows if r.get("name") == "net.upload"]
    assert len(ups) == rep.net["n_uploads"]
    assert sum(u["tags"]["encoded_bytes"] for u in ups) == \
        rep.net["encoded_bytes"]


def test_traced_run_chrome_trace_loadable(traced_run):
    _, _, paths = traced_run
    with open(paths["chrome"]) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    assert len(evs) > 10
    assert {e["ph"] for e in evs} <= {"M", "X", "i", "C"}
    assert all(set(e) >= {"ph", "pid"} for e in evs)
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "cloud" in names and any(n.startswith("node ") for n in names)
    # simulation-side slices carry virtual-time stamps (µs, nonnegative)
    slices = [e for e in evs if e["ph"] == "X" and e["name"] == "window"]
    assert slices and all(e["ts"] >= 0.0 and e["dur"] >= 0.0
                          for e in slices)


def test_traced_run_replay_reconstructs_report(traced_run):
    _, rep, paths = traced_run
    rep2 = api.replay_records(paths["records"])
    assert rep2 == dataclasses.replace(rep, final_params=None)
    # crashed stream: drop the footer + tear the last record line — the
    # lenient replay returns the faithful prefix
    rows = open(paths["records"]).read().splitlines()
    torn = [r for r in rows if '"kind":"report"' not in r]
    crash = paths["records"] + ".crash"
    with open(crash, "w") as f:
        f.write("\n".join(torn[:-1]) + "\n" + torn[-1][:len(torn[-1]) // 2])
    with pytest.raises(ValueError, match="truncated final"):
        api.replay_records(crash)
    part = api.replay_records(crash, strict=False)
    assert part.records == rep.records[:-1]
    assert part.mode == rep.mode and part.engine == rep.engine


def test_detection_audit_reconstructs_fig6(traced_run):
    """Fig. 6's per-round rejection series must be derivable from the
    detect.verdict audit log alone (accuracy, threshold, ring occupancy,
    verdict per cloud evaluation)."""
    _, rep, paths = traced_run
    verdicts = [r for r in read_jsonl(paths["events"])
                if r.get("name") == "detect.verdict"]
    assert verdicts, "detection audit log missing"
    for v in verdicts:
        assert {"node", "accuracy", "threshold", "ring_held",
                "rejected"} <= set(v["tags"])
    assert sum(v["tags"]["rejected"] for v in verdicts) == \
        sum(r.n_rejected for r in rep.records)


def test_obs_disabled_is_bit_identical(traced_run):
    """The default-off contract: the identical experiment without obs
    produces the identical trajectory (tracing observes, never perturbs)."""
    spec, rep, _ = traced_run
    off = dataclasses.replace(spec, obs=api.ObsSpec())
    rep_off = api.run(api.compile_plan(off))
    assert rep_off.records == rep.records
    assert rep_off.kappa == rep.kappa
    assert rep_off.final_accuracy == rep.final_accuracy
    assert rep_off.detections == rep.detections


# ---------------------------------------------------------------------------
# net: NetTrace / NetSim summary invariants
# ---------------------------------------------------------------------------

def test_netsim_summary_invariants():
    rng = np.random.default_rng(0)
    sim = NetSim("sparse_coo",
                 LinkProfile(loss_prob=0.1, jitter_s=0.2, latency_s=0.01),
                 bandwidth_bps=np.full(6, 1e6), n_params=1_000,
                 sparsify_ratio=0.5, seed=7)
    sink = MemorySink()
    commits, uploads_after = [], []
    with use_tracer(Tracer([sink])):
        for _ in range(4):
            nodes = rng.choice(6, size=3, replace=False)
            draw = sim.draw(nodes)
            assert (draw.transfer_s > 0).all()
            enc = sim.commit(draw, rng.integers(100, 500, size=3))
            commits.append(float(enc.sum()))
            uploads_after.append(sim.trace.n_uploads)
    # totals are exactly the sum of commits; upload count is monotone
    assert sim.trace.total_encoded_bytes == sum(commits)
    assert uploads_after == [3, 6, 9, 12]
    s = sim.summary()
    assert s == sim.trace.summary()
    assert s["n_uploads"] == 12
    assert s["encoded_bytes"] == sum(commits)
    assert s["wire_bytes"] >= s["encoded_bytes"]
    assert s["transfer_s"] == pytest.approx(sum(sim.trace.transfer_s))
    assert s["retransmits"] == sum(sim.trace.retransmits) >= 0
    # the tracer saw one net.upload instant per committed upload
    ups = [e for e in sink.events if e.name == "net.upload"]
    assert len(ups) == 12
    assert sum(e.tags["encoded_bytes"] for e in ups) == s["encoded_bytes"]


# ---------------------------------------------------------------------------
# mesh: obs event ordering on a forced-8-device host
# ---------------------------------------------------------------------------

def test_mesh_obs_event_ordering_forced_8dev(tmp_path):
    """On a forced-8-device host the mesh async engine's event stream must
    keep the obs ordering contract: seq strictly increasing in file order,
    window spans closing in window order, and every detection verdict
    preceded by its node's arrival instant in the same window."""
    ev_path = str(tmp_path / "mesh_events.jsonl")
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro import api

        spec = api.ExperimentSpec(
            fleet=api.FleetSpec(n_nodes=8, samples_per_node=20, n_test=32,
                                n_cloud_test=16,
                                attack=api.AttackMix(malicious_frac=0.25),
                                profile=api.NodeHeterogeneity(
                                    heterogeneity=0.8)),
            schedule=api.SchedulePolicy(kind="async"),
            defense=api.DefenseSpec(detect=True),
            topology=api.Topology(kind="mesh", devices=8),
            obs=api.ObsSpec(enabled=True, events_jsonl={ev_path!r}),
            train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
            rounds=2, seed=0)
        rep = api.run(api.compile_plan(spec))
        print(json.dumps({{"n_devices": len(jax.devices()),
                          "engine": rep.engine,
                          "n_rejected": sum(r.n_rejected
                                            for r in rep.records)}}))
    """)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8 and out["engine"] == "fleet-mesh"

    rows = read_jsonl(ev_path)
    evs = [r for r in rows if r.get("kind") in ("span", "instant", "counter")]
    seqs = [e["seq"] for e in evs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    windows = [e["tags"]["window"] for e in evs
               if e["kind"] == "span" and e["name"] == "window"]
    assert windows == sorted(windows) and len(windows) > 0
    verdicts = [e for e in evs if e["name"] == "detect.verdict"]
    arrivals = {(e["tags"]["node"], e["tags"]["window"]): e["seq"]
                for e in evs if e["name"] == "arrival"}
    assert verdicts, "mesh path must carry the detection audit log"
    for v in verdicts:
        key = (v["tags"]["node"], v["tags"]["window"])
        assert key in arrivals and arrivals[key] < v["seq"], \
            "verdict must follow its arrival in stream order"
    assert sum(v["tags"]["rejected"] for v in verdicts) == out["n_rejected"]
