"""Fleet-scale micro-benchmark: sequential reference loop vs. FleetEngine.

Sweeps n_nodes ∈ {10, 100, 1000} on the `honest` synthetic-MLP scenario and
reports per-round wall-clock for (a) the sequential per-node reference loop
(`repro.api` with `Topology(kind="sequential")`) and (b) the cohort-batched
`FleetEngine`. The sequential loop is O(n_nodes) Python dispatches per round,
so it is *measured* up to 100 nodes and linearly *extrapolated* (flagged) at
1000 — running it for real there takes minutes and measures nothing new.

Each invocation appends one record per swept size to the JSON trajectory at
``results/fleet_scale.json`` so speedups are tracked across commits.

  PYTHONPATH=src python -m benchmarks.fleet_scale            # the sweep
  PYTHONPATH=src python -m benchmarks.fleet_scale --smoke    # 2-round CI run
"""
from __future__ import annotations

import argparse
import os
import time

from .common import append_trajectory, emit

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "fleet_scale.json")
SWEEP = (10, 100, 1000)
SEQ_MEASURE_MAX = 100      # sequential dispatch loop: extrapolate beyond this
TIMED_ROUNDS = 3


def _scenario(n_nodes: int):
    from repro.fleet import get_scenario
    return get_scenario("honest").with_nodes(n_nodes)


def _build_fleet(n_nodes: int):
    from repro.fleet import build_engine
    return build_engine(_scenario(n_nodes), seed=0)


def _build_sequential(n_nodes: int, kind: str = "sync", rounds: int = 1):
    """(plan, population, state) for the per-node reference loop — each
    `api.execute(plan, pop, state)` call processes `rounds` rounds (sync)
    or rounds×n_nodes arrivals (async), continuing the chain state like
    the pre-redesign trainer's repeated run() did."""
    from repro import api
    sc = _scenario(n_nodes)
    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=n_nodes, hw=sc.hw,
                            samples_per_node=sc.samples_per_node,
                            n_test=sc.n_test, n_cloud_test=sc.n_cloud_test),
        schedule=api.SchedulePolicy(kind=kind),
        defense=api.DefenseSpec(detect=False),
        topology=api.Topology(kind="sequential"),
        train=api.TrainSpec(local_steps=sc.local_steps,
                            batch_size=sc.batch_size, lr=sc.lr),
        rounds=rounds, seed=0)
    plan = api.compile_plan(spec)
    pop = api.materialize(spec)
    return plan, pop, api.init_state(plan, pop)


def _time_fleet_round(n_nodes: int) -> float:
    eng = _build_fleet(n_nodes)
    eng.run_round()                          # compile + warm
    t0 = time.perf_counter()
    for _ in range(TIMED_ROUNDS):
        eng.run_round()
    return (time.perf_counter() - t0) / TIMED_ROUNDS


def _time_sequential_round(n_nodes: int) -> float:
    from repro import api
    plan, pop, state = _build_sequential(n_nodes)
    api.execute(plan, pop, state)            # compile + warm (1 round)
    t0 = time.perf_counter()
    for _ in range(TIMED_ROUNDS):
        api.execute(plan, pop, state)        # rounds=1 per call
    return (time.perf_counter() - t0) / TIMED_ROUNDS


def run() -> None:
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    records = []
    seq_per_node = None
    for n in SWEEP:
        fleet_s = _time_fleet_round(n)
        if n <= SEQ_MEASURE_MAX:
            seq_s = _time_sequential_round(n)
            seq_per_node = seq_s / n
            estimated = False
        else:
            seq_s = seq_per_node * n         # linear in dispatch count
            estimated = True
        speedup = seq_s / fleet_s
        emit(f"fleet_round_n{n}", fleet_s * 1e6,
             f"seq_s={seq_s:.4f}{'(est)' if estimated else ''};"
             f"speedup={speedup:.1f}x")
        records.append({
            "ts": stamp, "n_nodes": n, "fleet_s_per_round": fleet_s,
            "seq_s_per_round": seq_s, "seq_estimated": estimated,
            "speedup": speedup,
        })
    append_trajectory(RESULTS_PATH, records)


def smoke() -> None:
    """2-round fleet run on synthetic data — the CI liveness check."""
    eng = _build_fleet(32)
    recs = eng.run(2)
    for r in recs:
        print(f"round={r.round} acc={r.accuracy:.3f} "
              f"participants={r.n_participating} t={r.t:.2f}s")
    assert len(recs) == 2


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2-round 32-node fleet run (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()
