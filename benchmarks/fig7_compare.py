"""Paper Fig. 7: ALDPFL vs SLDPFL vs AFL vs SFL — accuracy and running time."""
from __future__ import annotations

from .common import Timer, build_trainer, emit


def run() -> None:
    for mode in ("sfl", "afl", "sldpfl", "aldpfl"):
        tr = build_trainer(mode, n_malicious=0, detect=False)
        with Timer() as t:
            hist = tr.run()
        emit(f"fig7a_accuracy_{mode}", t.us / len(hist),
             f"accuracy={hist[-1].accuracy:.3f}")
        emit(f"fig7b_runtime_{mode}", t.us / len(hist),
             f"sim_clock_s={hist[-1].t:.2f};kappa={tr.kappa():.4f};"
             f"eps={tr.epsilon_spent():.2f}")


if __name__ == "__main__":
    run()
