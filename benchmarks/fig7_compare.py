"""Paper Fig. 7: ALDPFL vs SLDPFL vs AFL vs SFL — accuracy and running time.

The async schemes (afl/aldpfl) are emitted twice: through the per-arrival
event loop (``topology="sequential"``, the seed reference) and through the
window-batched `AsyncFleetEngine` (the default path). Both land in the
``results/async_scale.json`` trajectory (tagged ``"bench": "fig7"``) so the
event-loop/fleet agreement and their wall-clocks are tracked across commits.
"""
from __future__ import annotations

import os
import time

from repro import api

from .common import Timer, append_trajectory, emit, prepare_mode

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "async_scale.json")


def run() -> None:
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    records = []
    for mode in ("sfl", "afl", "sldpfl", "aldpfl"):
        paths = (("single", "fleet"), ("sequential", "loop")) \
            if mode in ("afl", "aldpfl") else (("single", "fleet"),)
        for topology, path in paths:
            plan, pop = prepare_mode(mode, n_malicious=0, detect=False,
                                     topology=topology)
            with Timer() as t:
                rep = api.run(plan, population=pop)
            hist = rep.records
            tag = mode if path == "fleet" else f"{mode}_loop"
            emit(f"fig7a_accuracy_{tag}", t.us / len(hist),
                 f"accuracy={rep.final_accuracy:.3f}")
            emit(f"fig7b_runtime_{tag}", t.us / len(hist),
                 f"sim_clock_s={hist[-1].t:.2f};kappa={rep.kappa:.4f};"
                 f"eps={rep.epsilon_spent:.2f}")
            if mode in ("afl", "aldpfl"):
                records.append({
                    "ts": stamp, "bench": "fig7", "mode": mode, "path": path,
                    "accuracy": rep.final_accuracy,
                    "sim_clock_s": hist[-1].t, "kappa": rep.kappa,
                    "wall_s": t.us / 1e6,
                    "comm_bytes_total": sum(r.comm_bytes for r in hist),
                })
    append_trajectory(RESULTS_PATH, records)


if __name__ == "__main__":
    run()
