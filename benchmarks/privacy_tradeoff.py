"""Privacy–utility curve: accuracy vs noise multiplier σ (with the moments-
accountant ε at δ=1e-3). σ≈0.47 is the paper's own ε=8 calibration — our
honest reproduction shows its accuracy cost (see EXPERIMENTS.md §Paper)."""
from __future__ import annotations

from .common import Timer, build_trainer, emit


def run() -> None:
    for sigma in (0.0, 0.01, 0.05, 0.1, 0.4716):
        mode = "afl" if sigma == 0.0 else "aldpfl"
        tr = build_trainer(mode, n_malicious=0, detect=False, rounds=3,
                           sigma=(sigma if sigma > 0 else None))
        if sigma == 0.0:
            tr.sigma = 0.0
        with Timer() as t:
            hist = tr.run()
        eps = tr.epsilon_spent()
        emit(f"privacy_sigma{sigma}", t.us / len(hist),
             f"accuracy={hist[-1].accuracy:.3f};eps={eps:.2f};delta=0.001")


if __name__ == "__main__":
    run()
