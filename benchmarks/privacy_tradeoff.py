"""Privacy–utility curve: accuracy vs noise multiplier σ (with the moments-
accountant ε at δ=1e-3). σ≈0.47 is the paper's own ε=8 calibration — our
honest reproduction shows its accuracy cost (see EXPERIMENTS.md §Paper)."""
from __future__ import annotations

from repro import api

from .common import Timer, emit, prepare_mode


def run() -> None:
    for sigma in (0.0, 0.01, 0.05, 0.1, 0.4716):
        # σ=0 is exactly the no-noise async scheme (afl)
        mode = "afl" if sigma == 0.0 else "aldpfl"
        plan, pop = prepare_mode(mode, n_malicious=0, detect=False,
                                 rounds=3, sigma=sigma)
        with Timer() as t:
            rep = api.run(plan, population=pop)
        emit(f"privacy_sigma{sigma}", t.us / len(rep.records),
             f"accuracy={rep.final_accuracy:.3f};"
             f"eps={rep.epsilon_spent:.2f};delta=0.001")


if __name__ == "__main__":
    run()
