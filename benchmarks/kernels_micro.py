"""Pallas kernel micro-benchmarks (interpret mode — correctness-path timing;
derived column reports the HBM bytes the fused kernel saves on real TPU).

Timing goes through `repro.obs.bench_kernel` (warmup + `block_until_ready`
fenced loop).  With ``--profile [events.jsonl]`` the module installs an
enabled tracer first, so every measurement also lands in the shared obs
stream as a ``kernel.<name>`` counter + ``kernel.us_per_call`` histogram
sample — the measurement harness the upload-pipeline megakernel work will
argue from.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from .common import emit

from repro.obs import JsonlSink, MemorySink, Tracer, bench_kernel, use_tracer
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ldp_noise import ldp_perturb_flat
from repro.kernels.sparsify import sparsify_flat


def run() -> None:
    key = jax.random.PRNGKey(0)
    B, H, KV, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(key, (B, KV, S, D), jnp.float32)
    v = jax.random.normal(key, (B, KV, S, D), jnp.float32)
    us = bench_kernel("flash_attention_256",
                      lambda a, b, c: flash_attention(a, b, c, bq=128,
                                                      bk=128), q, k, v)
    flops = 4 * B * H * S * S * D * 0.5
    emit("kernel_flash_attention_256", us, f"flops={flops:.0f};"
         f"vmem_tile=128x128x{D}")

    n = 1 << 20
    g = jax.random.normal(key, (n,), jnp.float32)
    us = bench_kernel("ldp_noise_1M",
                      lambda x: ldp_perturb_flat(x, jnp.int32(1),
                                                 jnp.float32(0.5), 0.1, 1.0),
                      g)
    emit("kernel_ldp_noise_1M", us,
         f"hbm_bytes_fused={2*4*n};hbm_bytes_naive={6*4*n}")

    r = jax.random.normal(key, (n,), jnp.float32)
    us = bench_kernel("sparsify_1M",
                      lambda a, b: sparsify_flat(a, b, jnp.float32(0.5)),
                      g, r)
    emit("kernel_sparsify_1M", us,
         f"hbm_bytes_fused={4*4*n};hbm_bytes_naive={8*4*n}")

    from repro.kernels.selective_scan import selective_scan
    B_, L_, D_, N_ = 1, 128, 64, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B_, L_, D_), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, L_, D_))) * 0.1
    Bm = jax.random.normal(ks[2], (B_, L_, N_))
    Cm = jax.random.normal(ks[3], (B_, L_, N_))
    A = -jnp.exp(jax.random.normal(key, (D_, N_)) * 0.2)
    us = bench_kernel("selective_scan",
                      lambda *a: selective_scan(*a, block_l=64,
                                                block_d=64)[0],
                      x, dt, Bm, Cm, A)
    hbm_fused = 4 * (2 * B_ * L_ * D_ + 2 * B_ * L_ * N_ + B_ * L_ * D_)
    hbm_xla = hbm_fused + 4 * B_ * L_ * D_ * N_ * 7   # h_all × assoc-scan passes
    emit("kernel_selective_scan", us,
         f"hbm_bytes_fused={hbm_fused};hbm_bytes_xla_scan={hbm_xla}")

    from repro.kernels.ssd_scan import ssd_scan
    H_, P_ = 8, 16
    xh = jax.random.normal(ks[0], (1, 128, H_, P_), jnp.float32)
    dth = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, H_))) * 0.2
    Ah = -jnp.exp(jax.random.normal(key, (H_,)) * 0.3)
    Bh = jax.random.normal(ks[2], (1, 128, N_))
    Ch = jax.random.normal(ks[3], (1, 128, N_))
    us = bench_kernel("ssd_scan",
                      lambda *a: ssd_scan(*a, chunk=64, block_h=8)[0],
                      xh, dth, Bh, Ch, Ah)
    emit("kernel_ssd_scan", us,
         f"hbm_bytes_fused={4*(2*128*H_*P_+2*128*N_+128*H_)};"
         f"vmem_state={H_*P_*N_*4}")


def main(argv) -> None:
    if "--profile" in argv:
        i = argv.index("--profile")
        path = argv[i + 1] if len(argv) > i + 1 else None
        sinks = [JsonlSink(path)] if path else [MemorySink()]
        tracer = Tracer(sinks, enabled=True)
        with use_tracer(tracer):
            run()
        snap = tracer.metrics.snapshot()
        h = snap.get("kernel.us_per_call")
        if h:
            emit("kernel_profile_summary", h["sum"] / max(h["count"], 1),
                 f"n={h['count']};min_us={h['min']:.1f};max_us={h['max']:.1f}")
        tracer.close()
    else:
        run()


if __name__ == "__main__":
    main(sys.argv[1:])
