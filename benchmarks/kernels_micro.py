"""Pallas kernel micro-benchmarks (interpret mode — correctness-path timing;
derived column reports the HBM bytes the fused kernel saves on real TPU).

Timing goes through `repro.obs.bench_kernel` (warmup + `block_until_ready`
fenced loop).  With ``--profile [events.jsonl]`` the module installs an
enabled tracer first, so every measurement also lands in the shared obs
stream as a ``kernel.<name>`` counter + ``kernel.us_per_call`` histogram
sample — the measurement harness the upload-pipeline megakernel work will
argue from.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp

from .common import append_trajectory, emit

from repro.obs import JsonlSink, MemorySink, Tracer, bench_kernel, use_tracer
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ldp_noise import ldp_perturb_flat
from repro.kernels.sparsify import sparsify_flat

FUSED_RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                                  "kernels_fused.json")


def bench_upload_pipeline():
    """The upload-pipeline megakernel vs the unfused pallas kernel chain
    (`sparsify_fleet` -> `nnz_fleet` -> `ldp_perturb_fleet`) at identical
    cohort shapes, seeds and thresholds — bit-identical outputs, so the
    delta is pure launch/HBM-traffic overhead.  Returns the records
    appended to ``results/kernels_fused.json``."""
    from repro.core import accumulator as accum
    from repro.kernels.ldp_noise import ldp_perturb_fleet
    from repro.kernels.sparsify import sparsify_fleet
    from repro.kernels.upload_fused import (spread_thresholds,
                                            upload_fused_fleet)
    from repro.kernels.wire_bytes import nnz_fleet
    from repro.kernels.window_fold import (window_fold_fleet,
                                           window_fold_reference)

    key = jax.random.PRNGKey(0)
    C, N = 8, 1 << 16
    sigma, clip_s, ratio = 0.1, 1.0, 0.25
    flat = jax.random.normal(key, (C, N), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(1), (C, N), jnp.float32)
    comb = flat + res
    thr = jax.vmap(lambda v: accum.leaf_threshold(v, ratio))(comb)[:, None]
    seeds = jnp.arange(C, dtype=jnp.int32)
    sp = jnp.where(jnp.abs(comb) >= spread_thresholds(thr, (0,), N),
                   comb, 0.0)
    scales = 1.0 / jnp.maximum(1.0, jnp.sqrt(jnp.sum(jnp.square(sp), 1))
                               / clip_s)

    def fused():
        return upload_fused_fleet(flat, res, thr, seeds, scales, sigma,
                                  clip_s, need_nnz=True)

    def unfused():
        up, newr = sparsify_fleet(flat, res, thr[:, 0])
        nnz = nnz_fleet(up)
        up = ldp_perturb_fleet(up, seeds, scales, sigma, clip_s)
        return up, newr, nnz

    us_fused = bench_kernel("upload_fused_512K", fused)
    us_chain = bench_kernel("upload_unfused_chain_512K", unfused)
    # HBM accounting (f32): fused reads {delta, residual} and writes
    # {upload, residual'} once — 16·C·N; the chain re-reads/re-writes the
    # intermediate upload through nnz (4·C·N) and ldp (8·C·N) — 28·C·N.
    hbm_fused, hbm_chain = 16 * C * N, 28 * C * N
    emit("kernel_upload_fused_512K", us_fused,
         f"unfused_chain_us={us_chain:.1f};"
         f"speedup={us_chain / us_fused:.2f}x;"
         f"hbm_bytes_fused={hbm_fused};hbm_bytes_chain={hbm_chain};"
         f"hbm_bytes_saved={hbm_chain - hbm_fused}")

    W = 16
    p = jax.random.normal(key, (N,), jnp.float32)
    om = jax.random.normal(jax.random.PRNGKey(2), (W, N), jnp.float32)
    gates = jnp.ones((W,), jnp.int32)
    a = jnp.full((W,), 0.5, jnp.float32)
    b = 1.0 - a
    us_fold = bench_kernel("window_fold_16x64K",
                           lambda: window_fold_fleet(p, om, gates, a, b))
    us_scan = bench_kernel("window_fold_scan_16x64K",
                           lambda: window_fold_reference(p, om, gates, a, b))
    # the lax.scan carry round-trips HBM every arrival (read+write carry +
    # read om + write snapshot = 4·W·N); the kernel keeps the accumulator
    # block VMEM-resident (read om + write snapshot + params in/out =
    # (2W+2)·N).
    hbm_fold, hbm_scan = 4 * (2 * W + 2) * N, 4 * 4 * W * N
    emit("kernel_window_fold_16x64K", us_fold,
         f"scan_us={us_scan:.1f};hbm_bytes_fused={hbm_fold};"
         f"hbm_bytes_scan={hbm_scan};hbm_bytes_saved={hbm_scan - hbm_fold}")

    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    records = [
        {"ts": stamp, "bench": "upload_fused", "cohort": C, "n": N,
         "fused_us": us_fused, "unfused_chain_us": us_chain,
         "speedup": us_chain / us_fused, "hbm_bytes_fused": hbm_fused,
         "hbm_bytes_chain": hbm_chain,
         "hbm_bytes_saved": hbm_chain - hbm_fused},
        {"ts": stamp, "bench": "window_fold", "window": W, "n": N,
         "fused_us": us_fold, "scan_us": us_scan,
         "hbm_bytes_fused": hbm_fold, "hbm_bytes_scan": hbm_scan,
         "hbm_bytes_saved": hbm_scan - hbm_fold},
    ]
    append_trajectory(FUSED_RESULTS_PATH, records)
    return records


def run() -> None:
    key = jax.random.PRNGKey(0)
    B, H, KV, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(key, (B, KV, S, D), jnp.float32)
    v = jax.random.normal(key, (B, KV, S, D), jnp.float32)
    us = bench_kernel("flash_attention_256",
                      lambda a, b, c: flash_attention(a, b, c, bq=128,
                                                      bk=128), q, k, v)
    flops = 4 * B * H * S * S * D * 0.5
    emit("kernel_flash_attention_256", us, f"flops={flops:.0f};"
         f"vmem_tile=128x128x{D}")

    n = 1 << 20
    g = jax.random.normal(key, (n,), jnp.float32)
    us = bench_kernel("ldp_noise_1M",
                      lambda x: ldp_perturb_flat(x, jnp.int32(1),
                                                 jnp.float32(0.5), 0.1, 1.0),
                      g)
    emit("kernel_ldp_noise_1M", us,
         f"hbm_bytes_fused={2*4*n};hbm_bytes_naive={6*4*n}")

    r = jax.random.normal(key, (n,), jnp.float32)
    us = bench_kernel("sparsify_1M",
                      lambda a, b: sparsify_flat(a, b, jnp.float32(0.5)),
                      g, r)
    emit("kernel_sparsify_1M", us,
         f"hbm_bytes_fused={4*4*n};hbm_bytes_naive={8*4*n}")

    from repro.kernels.selective_scan import selective_scan
    B_, L_, D_, N_ = 1, 128, 64, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B_, L_, D_), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, L_, D_))) * 0.1
    Bm = jax.random.normal(ks[2], (B_, L_, N_))
    Cm = jax.random.normal(ks[3], (B_, L_, N_))
    A = -jnp.exp(jax.random.normal(key, (D_, N_)) * 0.2)
    us = bench_kernel("selective_scan",
                      lambda *a: selective_scan(*a, block_l=64,
                                                block_d=64)[0],
                      x, dt, Bm, Cm, A)
    hbm_fused = 4 * (2 * B_ * L_ * D_ + 2 * B_ * L_ * N_ + B_ * L_ * D_)
    hbm_xla = hbm_fused + 4 * B_ * L_ * D_ * N_ * 7   # h_all × assoc-scan passes
    emit("kernel_selective_scan", us,
         f"hbm_bytes_fused={hbm_fused};hbm_bytes_xla_scan={hbm_xla}")

    from repro.kernels.ssd_scan import ssd_scan
    H_, P_ = 8, 16
    xh = jax.random.normal(ks[0], (1, 128, H_, P_), jnp.float32)
    dth = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, H_))) * 0.2
    Ah = -jnp.exp(jax.random.normal(key, (H_,)) * 0.3)
    Bh = jax.random.normal(ks[2], (1, 128, N_))
    Ch = jax.random.normal(ks[3], (1, 128, N_))
    us = bench_kernel("ssd_scan",
                      lambda *a: ssd_scan(*a, chunk=64, block_h=8)[0],
                      xh, dth, Bh, Ch, Ah)
    emit("kernel_ssd_scan", us,
         f"hbm_bytes_fused={4*(2*128*H_*P_+2*128*N_+128*H_)};"
         f"vmem_state={H_*P_*N_*4}")

    bench_upload_pipeline()


def main(argv) -> None:
    if "--profile" in argv:
        i = argv.index("--profile")
        path = argv[i + 1] if len(argv) > i + 1 else None
        sinks = [JsonlSink(path)] if path else [MemorySink()]
        tracer = Tracer(sinks, enabled=True)
        with use_tracer(tracer):
            run()
        snap = tracer.metrics.snapshot()
        h = snap.get("kernel.us_per_call")
        if h:
            emit("kernel_profile_summary", h["sum"] / max(h["count"], 1),
                 f"n={h['count']};min_us={h['min']:.1f};max_us={h['max']:.1f}")
        tracer.close()
    else:
        run()


if __name__ == "__main__":
    main(sys.argv[1:])
