"""Simulation-service benchmark: attack onset mid-run + kill/resume.

Drives `repro.sim.SimService` through the scenario the batch runner
cannot express: a clean fleet that comes under attack at round k (an
``attack`` `SimEvent` rematerializes the population with poisoned
shards), with the paper's detector toggled on two rounds later (a
``defense`` event) and a diurnal traffic trace throttling the repro.net
links throughout.  Reports the detection/trust response around the onset
and verifies the service's core contract on the same spec: a run killed
at round k, checkpointed, and resumed reproduces the uninterrupted
trajectory bit-exactly.

Rows land in ``results/service_sim.json`` through the api's
schema-stamped serializer and are pinned by ``tools/bench_check.py``
(wall-clock fields are fingerprint-exempt).

  PYTHONPATH=src python -m benchmarks.service_sim          # full scenario
  PYTHONPATH=src python -m benchmarks.service_sim --smoke  # tiny CI run
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

from repro import api
from repro.sim import SimService

from .common import append_trajectory

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "service_sim.json")


def _spec(smoke: bool) -> api.ExperimentSpec:
    n = 6 if smoke else 10
    rounds = 6 if smoke else 10
    onset = 2 if smoke else 3
    detect_on = onset + 1 if smoke else onset + 2
    sim = api.SimSpec(
        traces=(api.TrafficTrace(kind="diurnal", period_s=40.0,
                                 amplitude=0.3),),
        events=(
            api.SimEvent(at_round=onset, kind="attack",
                         payload={"kind": "label_flip",
                                  "malicious_frac": 0.5}),
            api.SimEvent(at_round=detect_on, kind="defense",
                         payload={"detect": True}),
        ))
    return api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=n, hw=(8, 8),
                            samples_per_node=240 // n,
                            n_test=128, n_cloud_test=64),
        schedule=api.SchedulePolicy(kind="async"),
        network=api.NetworkSpec(codec="sparse_coo", bandwidth_sigma=0.3,
                                latency_s=0.01),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=False),
        topology=api.Topology(kind="single"),
        train=api.TrainSpec(local_steps=4, batch_size=16, lr=0.1),
        sim=sim, rounds=rounds, seed=0)


def _recs(report):
    return [(r.t, r.version, r.accuracy, r.comm_bytes, r.comp_time,
             r.comm_time, r.n_rejected, r.bytes_source)
            for r in report.records]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI variant")
    ap.add_argument("--no-write", action="store_true",
                    help="skip the results/ append (CI smoke)")
    args = ap.parse_args()

    spec = _spec(args.smoke)
    ev = {e.kind: e.at_round for e in spec.sim.events}
    onset, detect_on = ev["attack"], ev["defense"]

    t0 = time.time()
    base = SimService(api.compile_plan(spec)).run()
    base_wall = time.time() - t0
    rejected = [r.n_rejected for r in base.records]
    print(f"attack onset @ {onset}, detector on @ {detect_on}: "
          f"rejected per record = {rejected}", flush=True)

    # kill at the round after onset (mutated spec in the manifest), resume,
    # and demand a bit-exact continuation
    kill_at = onset + 1
    svc = SimService(api.compile_plan(spec))
    svc.run(max_records=kill_at)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        path = svc.checkpoint(os.path.join(d, "ck"))
        ckpt_wall = time.time() - t0
        ckpt_bytes = os.path.getsize(path + ".npz")
        t0 = time.time()
        resumed = SimService.resume(path).run()
        resume_wall = time.time() - t0
    bit_exact = _recs(resumed) == _recs(base)
    net_exact = resumed.net == base.net
    print(f"kill@{kill_at} -> resume: bit_exact={bit_exact} "
          f"net_exact={net_exact}", flush=True)
    if not (bit_exact and net_exact):
        raise SystemExit("resume parity violated")

    rows = [{
        "bench": "service_sim", "phase": "attack_onset",
        "smoke": bool(args.smoke), "mode": base.mode,
        "rounds": len(base.records), "onset_round": onset,
        "detect_round": detect_on,
        "rejected_before_detect": int(sum(rejected[:detect_on])),
        "rejected_after_detect": int(sum(rejected[detect_on:])),
        "detections": base.detections,
        "final_accuracy": float(base.final_accuracy),
        "kappa": float(base.kappa),
        "net_encoded_bytes": float(base.net["encoded_bytes"]),
        "wall_s": base_wall,
    }, {
        "bench": "service_sim", "phase": "resume_parity",
        "smoke": bool(args.smoke), "kill_at": kill_at,
        "bit_exact": bool(bit_exact), "net_exact": bool(net_exact),
        "resumed_from_round": int(resumed.resume_round),
        "ckpt_bytes": int(ckpt_bytes),
        "ckpt_wall_s": ckpt_wall, "resume_wall_s": resume_wall,
    }]
    if not args.no_write:
        append_trajectory(RESULTS_PATH, rows)
        print(f"wrote {len(rows)} rows -> {RESULTS_PATH}", flush=True)


if __name__ == "__main__":
    main()
