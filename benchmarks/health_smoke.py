"""Fleet-health smoke: injected stragglers + attack onset must page.

Drives `repro.sim.SimService` through a hostile scenario — a quarter of
the fleet slowed ~8×, a label-flip attack switching on mid-run against
an armed detector, sparse_coo uploads metered against a deliberately
tight byte budget — with the `ObsSpec.health` probes live, then asserts
the monitoring actually *noticed*: the straggler, byte-budget, and
reject-rate (detection-drift) probes must each have opened at least one
``health.incident``, reconstructed purely from the events JSONL (the
acceptance bar: trace-only, no engine internals).  The same stream is
then rendered through `tools/obs_report.py`-style postmortem and diffed
against a clean-fleet control run to exercise the regression verdicts.

Rows land in ``results/health_smoke.json`` and are pinned by
``tools/bench_check.py`` (wall-clock fields fingerprint-exempt).

  PYTHONPATH=src python -m benchmarks.health_smoke          # full scenario
  PYTHONPATH=src python -m benchmarks.health_smoke --smoke  # tiny CI run
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

from repro import api
from repro.obs import FleetAnalytics, HealthSpec, read_events
from repro.obs.report import postmortem_md, run_diff_md
from repro.sim import SimService

from .common import append_trajectory

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "health_smoke.json")


def _spec(smoke: bool, events_jsonl: str,
          health: bool = True, attack: bool = True) -> api.ExperimentSpec:
    n = 8 if smoke else 12
    rounds = 6 if smoke else 10
    onset = 2 if smoke else 3
    events = ()
    if attack:
        events = (api.SimEvent(at_round=onset, kind="attack",
                               payload={"kind": "label_flip",
                                        "malicious_frac": 0.5}),)
    hlt = None
    if health:
        # thresholds tuned to page on this scenario: the straggler tail
        # sits ~8x over the median gap, sparse_coo windows run well over
        # the (deliberately tight) byte budget, and the armed detector's
        # reject rate jumps past 0.3 once half the fleet flips labels
        hlt = HealthSpec(
            straggler_factor=3.0, straggler_min_arrivals=3,
            bytes_per_record_budget=6000.0,
            reject_rate_threshold=0.3, reject_rate_window=8,
            warmup_records=1)
    return api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=n, hw=(8, 8), samples_per_node=240 // n,
            n_test=128, n_cloud_test=64,
            profile=api.NodeHeterogeneity(
                heterogeneity=0.3, straggler_frac=0.25,
                straggler_slowdown=8.0)),
        schedule=api.SchedulePolicy(kind="async"),
        network=api.NetworkSpec(codec="sparse_coo", bandwidth_sigma=0.3,
                                latency_s=0.01),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True, detect_warmup=4),
        obs=api.ObsSpec(enabled=True, events_jsonl=events_jsonl,
                        health=hlt),
        topology=api.Topology(kind="single"),
        train=api.TrainSpec(local_steps=4, batch_size=16, lr=0.1),
        sim=api.SimSpec(events=events), rounds=rounds, seed=0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI variant")
    ap.add_argument("--no-write", action="store_true",
                    help="skip the results/ append (CI smoke)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        hostile_path = os.path.join(d, "hostile.jsonl")
        control_path = os.path.join(d, "control.jsonl")

        t0 = time.time()
        spec = _spec(args.smoke, hostile_path)
        report = SimService(api.compile_plan(spec)).run()
        wall = time.time() - t0

        # -- acceptance: incidents reconstructable from the trace alone
        events = read_events(hostile_path)
        an = FleetAnalytics.from_events(events)
        fired = sorted({str(i["probe"]) for i in an.incidents})
        print(f"incidents by probe: "
              f"{ {p: sum(1 for i in an.incidents if i['probe'] == p) for p in fired} }",
              flush=True)
        for probe in ("straggler", "byte_budget", "reject_rate"):
            if probe not in fired:
                raise SystemExit(
                    f"health_smoke: probe {probe!r} fired no "
                    f"health.incident (fired: {fired})")

        # -- the postmortem must render from the same trace-only input
        from repro.obs import read_jsonl
        rows_hostile = read_jsonl(hostile_path)
        md = postmortem_md(rows_hostile)
        for section in ("## Incidents", "## Detection quality",
                        "stragglers"):
            if section not in md:
                raise SystemExit(f"health_smoke: postmortem missing "
                                 f"{section!r} section")
        print(f"postmortem: {len(md.splitlines())} lines, "
              f"{len(an.incidents)} incidents", flush=True)

        # -- control run (clean fleet, no attack) + run-vs-run diff
        control = _spec(args.smoke, control_path, health=False,
                        attack=False)
        control = dataclasses.replace(
            control, fleet=dataclasses.replace(
                control.fleet,
                profile=api.NodeHeterogeneity(heterogeneity=0.3)))
        SimService(api.compile_plan(control)).run()
        diff_md, n_reg = run_diff_md(read_jsonl(control_path),
                                     rows_hostile,
                                     label_a="control",
                                     label_b="hostile")
        print(f"run diff: {n_reg} regression(s) hostile vs control",
              flush=True)
        if "| metric |" not in diff_md:
            raise SystemExit("health_smoke: run diff table missing")

    det = an.detection_quality()
    rows = [{
        "bench": "health_smoke", "smoke": bool(args.smoke),
        "rounds": len(report.records),
        "final_accuracy": float(report.final_accuracy),
        "probes_fired": fired,
        "n_incidents": len(an.incidents),
        "n_alerts": len(an.alerts),
        "n_verdicts": int(an.n_verdicts),
        "n_rejected": int(an.n_rejected),
        "detection_tp": int(det["tp"]), "detection_fp": int(det["fp"]),
        "detection_tn": int(det["tn"]), "detection_fn": int(det["fn"]),
        "diff_regressions": int(n_reg),
        "wall_s": wall,
    }]
    if not args.no_write:
        append_trajectory(RESULTS_PATH, rows)
        print(f"wrote {len(rows)} rows -> {RESULTS_PATH}", flush=True)


if __name__ == "__main__":
    main()
