"""Observability smoke: one fully-traced run, end to end.

Runs a small async experiment over a lossy network with every `ObsSpec`
output on, then checks the observability contracts the docs promise:

  * the event JSONL streams header + window/arrival/verdict/net.upload
    events and a run-end metrics snapshot;
  * the Chrome trace is valid ``trace_event`` JSON (Perfetto-loadable
    shape: M/X/i/C phases, one tid per track);
  * replaying the streamed records JSONL reconstructs the final
    `RunReport` exactly;
  * the same spec with obs off produces the identical trajectory.

Emits ``name,us_per_call,derived`` CSV rows like every other benchmark;
any broken contract raises (the harness turns that into a CI failure).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

from repro import api
from repro.obs import read_jsonl

from .common import Timer, emit, spec_for_mode


def run() -> None:
    with tempfile.TemporaryDirectory() as td:
        ev = os.path.join(td, "events.jsonl")
        ct = os.path.join(td, "trace.json")
        rj = os.path.join(td, "records.jsonl")
        spec = spec_for_mode("aldpfl", rounds=2)
        spec = dataclasses.replace(
            spec,
            network=api.NetworkSpec(codec="sparse_coo", loss_prob=0.1,
                                    jitter_s=0.5),
            obs=api.ObsSpec(enabled=True, events_jsonl=ev, chrome_trace=ct,
                            records_jsonl=rj, stage_timings=True))
        plan = api.compile_plan(spec)
        pop = api.materialize(spec)
        with Timer() as t:
            rep = api.run(plan, population=pop)

        rows = read_jsonl(ev)
        names = {r["name"] for r in rows
                 if r.get("kind") in ("span", "instant", "counter")}
        missing = {"window", "arrival", "detect.verdict",
                   "net.upload"} - names
        if missing:
            raise AssertionError(f"event stream missing {sorted(missing)}")
        if not any(r.get("kind") == "metrics" for r in rows):
            raise AssertionError("no run-end metrics snapshot in stream")

        with open(ct) as f:
            doc = json.load(f)
        phases = {e["ph"] for e in doc["traceEvents"]}
        if not (phases <= {"M", "X", "i", "C"} and doc["traceEvents"]):
            raise AssertionError(f"chrome trace malformed: phases={phases}")

        replayed = api.replay_records(rj)
        if replayed != dataclasses.replace(rep, final_params=None):
            raise AssertionError("records replay != in-memory report")

        off = dataclasses.replace(spec, obs=api.ObsSpec())
        rep_off = api.run(api.compile_plan(off), population=pop)
        if rep_off.records != rep.records:
            raise AssertionError("tracing perturbed the trajectory")

        n_ev = sum(r.get("kind") in ("span", "instant", "counter")
                   for r in rows)
        emit("obs_traced_run", t.us / max(len(rep.records), 1),
             f"events={n_ev};chrome_events={len(doc['traceEvents'])};"
             f"replay=exact;disabled=bit_identical")


if __name__ == "__main__":
    run()
