"""Eq. (5) κ and bytes-on-wire: async vs sync and the gradient-accumulation
container at different keep ratios (the paper's communication levers)."""
from __future__ import annotations

from repro import api

from .common import Timer, emit, prepare_mode


def run() -> None:
    for mode in ("sfl", "afl"):
        plan, pop = prepare_mode(mode, n_malicious=0, detect=False,
                                 rounds=3)
        with Timer() as t:
            rep = api.run(plan, population=pop)
        comp = sum(r.comp_time for r in rep.records)
        comm = sum(r.comm_time for r in rep.records)
        emit(f"comm_kappa_{mode}", t.us / len(rep.records),
             f"kappa={rep.kappa:.4f};comp_s={comp:.2f};comm_s={comm:.3f}")
    for ratio in (1.0, 0.25, 0.1, 0.01):
        plan, pop = prepare_mode("aldpfl", n_malicious=0, detect=False,
                                 rounds=2, sparsify=ratio)
        with Timer() as t:
            rep = api.run(plan, population=pop)
        total_bytes = sum(r.comm_bytes for r in rep.records)
        emit(f"comm_sparsify_r{ratio}", t.us / len(rep.records),
             f"bytes_per_round={total_bytes/len(rep.records):.0f};"
             f"final_acc={rep.final_accuracy:.3f}")


if __name__ == "__main__":
    run()
