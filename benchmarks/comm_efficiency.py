"""Eq. (5) κ and bytes-on-wire: async vs sync and the gradient-accumulation
container at different keep ratios (the paper's communication levers)."""
from __future__ import annotations

from .common import Timer, build_trainer, emit


def run() -> None:
    for mode in ("sfl", "afl"):
        tr = build_trainer(mode, n_malicious=0, detect=False, rounds=3)
        with Timer() as t:
            hist = tr.run()
        comp = sum(r.comp_time for r in hist)
        comm = sum(r.comm_time for r in hist)
        emit(f"comm_kappa_{mode}", t.us / len(hist),
             f"kappa={tr.kappa():.4f};comp_s={comp:.2f};comm_s={comm:.3f}")
    for ratio in (1.0, 0.25, 0.1, 0.01):
        tr = build_trainer("aldpfl", n_malicious=0, detect=False, rounds=2,
                           sparsify=ratio)
        with Timer() as t:
            hist = tr.run()
        total_bytes = sum(r.comm_bytes for r in hist)
        emit(f"comm_sparsify_r{ratio}", t.us / len(hist),
             f"bytes_per_round={total_bytes/len(hist):.0f};"
             f"final_acc={hist[-1].accuracy:.3f}")


if __name__ == "__main__":
    run()
