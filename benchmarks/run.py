"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,kernels]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = {
    "api": "api_smoke",
    "fig6": "fig6_detection",
    "fig7": "fig7_compare",
    "fig8": "fig8_flip",
    "leakage": "leakage",
    "privacy": "privacy_tradeoff",
    "ablations": "ablations",
    "comm": "comm_efficiency",
    "net": "net_sweep",
    "fleet": "fleet_scale",
    "async": "async_scale",
    "kernels": "kernels_micro",
    "roofline": "roofline_table",
    "obs": "obs_smoke",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset: "
                    + ",".join(MODULES))
    args = ap.parse_args()
    wanted = [w for w in args.only.split(",") if w] or list(MODULES)
    print("name,us_per_call,derived")
    failures = 0
    for key in wanted:
        mod_name = MODULES[key]
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
        except Exception:
            failures += 1
            print(f"{key},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
