"""Mesh-sharded fleet weak-scaling benchmark: n = 1000 x D nodes on a
D-device host mesh (``--xla_force_host_platform_device_count``).

Each swept point runs in its own subprocess (the forced host device count is
fixed at process start) and shards the node axis of the `honest` scenario
over a `FleetMesh`: one timed synchronous round (local SGD + detection +
aggregation under shard_map) and a few timed asynchronous arrival windows.
Per-device residual-shard bytes are recorded alongside wall-clock, so the
JSON trajectory at ``results/fleet_shard.json`` tracks both the weak-scaling
time curve and the memory win that motivates sharding (per-device state is
O(N/D), letting 10k+ node fleets fit where a single device can't).

  PYTHONPATH=src python -m benchmarks.fleet_shard            # 1k..16k sweep
  PYTHONPATH=src python -m benchmarks.fleet_shard --smoke    # 4-device CI run
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "fleet_shard.json")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DEVICE_SWEEP = (1, 2, 4, 8, 16)
NODES_PER_DEVICE = 1000
TIMED_WINDOWS = 3

_CHILD = r"""
import sys
n, d, timed_windows = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
import os
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
import dataclasses
import json
import time
import jax
from repro.fleet import (FleetMesh, build_async_engine, build_engine,
                         get_scenario)

mesh = FleetMesh.create(d)
sc = dataclasses.replace(get_scenario("honest").with_nodes(n),
                         samples_per_node=20)

eng = build_engine(sc, seed=0, mesh=mesh)
eng.run_round()                               # compile + warm
t0 = time.perf_counter()
eng.run_round()
sync_s = time.perf_counter() - t0
res_bytes = sum(x.nbytes for x in jax.tree.leaves(eng.state.residuals))

aeng = build_async_engine(sc, seed=0, mesh=mesh)
for _ in range(2):
    aeng.run_window(evaluate=False)           # compile likely buckets
warm = len(aeng.history)
t0 = time.perf_counter()
for _ in range(timed_windows):
    aeng.run_window(evaluate=False)
async_s = (time.perf_counter() - t0) / timed_windows
arrivals = sum(r.n_processed for r in aeng.history[warm:]) / timed_windows

print(json.dumps({
    "n_nodes": n, "n_devices": d, "n_pad": eng.n_pad,
    "sync_s_per_round": sync_s, "async_s_per_window": async_s,
    "arrivals_per_window": arrivals,
    "residual_bytes_per_device": res_bytes // d,
    "final_acc": eng.history[-1].accuracy,
}))
"""


def _run_child(n: int, d: int, timed_windows: int = TIMED_WINDOWS) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)        # the child forces its own device count
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(n), str(d), str(timed_windows)],
        capture_output=True, text=True, env=env, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"fleet_shard child (n={n}, d={d}) failed:\n"
                           f"{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run() -> None:
    from .common import append_trajectory, emit
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    records = []
    for d in DEVICE_SWEEP:
        n = NODES_PER_DEVICE * d
        rec = _run_child(n, d)
        rec["ts"] = stamp
        emit(f"fleet_shard_n{n}_d{d}", rec["sync_s_per_round"] * 1e6,
             f"async_window_s={rec['async_s_per_window']:.4f};"
             f"res_bytes_per_dev={rec['residual_bytes_per_device']}")
        records.append(rec)
    append_trajectory(RESULTS_PATH, records)


def smoke() -> None:
    """One 4-device subprocess, uneven n=30 fleet — the CI liveness check
    for the sharded round + window programs."""
    rec = _run_child(30, 4, timed_windows=2)
    print(json.dumps(rec))
    assert rec["n_devices"] == 4
    assert rec["n_pad"] == 32                  # 30 padded to a multiple of 4
    assert rec["arrivals_per_window"] >= 1
    assert 0.0 <= rec["final_acc"] <= 1.0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4-device 30-node sharded run (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()
