"""Network sweep: codec byte costs + accuracy-vs-wall-clock under real links.

Two parts, both appending to ``results/net_sweep.json``:

  1. **Codec table** — every `repro.net` wire codec (dense_f32, sparse_coo,
     sparse_bitpack, and the q8/q16 quantized variants) priced on a
     synthetic update at the paper's sparsity ratios, reporting measured
     payload bytes and the compression ratio vs the dense wire.  The
     payloads are actually encoded (and decode-round-trip-checked), not
     estimated.

  2. **Link sweep** — the ALDPFL async spec run under increasingly hostile
     `NetworkSpec`s (analytic baseline, ideal encoded wire, heterogeneous
     bandwidth, lossy+jittery industrial link, shared congested uplink),
     recording final accuracy, virtual-time span, κ and the NetTrace byte
     totals — the accuracy-vs-wall-clock story the paper's comm-efficiency
     claim lives on.

  PYTHONPATH=src python -m benchmarks.net_sweep            # full sweep
  PYTHONPATH=src python -m benchmarks.net_sweep --smoke    # tiny CI run
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

from repro import api, net

from .common import append_trajectory, emit

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "net_sweep.json")
RATIOS = (0.05, 0.1, 0.25, 0.5)
CODECS = (("dense_f32", 32), ("sparse_coo", 32), ("sparse_bitpack", 32),
          ("sparse_bitpack", 16), ("sparse_bitpack", 8))

LINK_REGIMES = {
    # name -> NetworkSpec kwargs (None = the analytic baseline)
    "analytic": None,
    "ideal_wire": dict(codec="sparse_bitpack"),
    "hetero_bw": dict(codec="sparse_bitpack", bandwidth_sigma=1.0),
    "lossy_industrial": dict(codec="sparse_bitpack", bandwidth_sigma=1.0,
                             latency_s=0.02, jitter_s=0.1, loss_prob=0.2),
    "congested_uplink": dict(codec="sparse_bitpack", latency_s=0.02,
                             shared_uplink_bps=25e6),
}


def codec_table(n_params: int, seed: int = 0):
    """Measured payload bytes per codec × sparsity ratio (with decode
    round-trip checks — the table is backed by real byte buffers)."""
    rng = np.random.default_rng(seed)
    rows = []
    dense = net.get_codec("dense_f32")
    for ratio in RATIOS:
        u = np.zeros(n_params, np.float32)
        k = max(1, int(n_params * ratio))
        idx = rng.choice(n_params, k, replace=False)
        u[idx] = rng.normal(size=k).astype(np.float32)
        dense_bytes = dense.encode(u).nbytes
        for name, vb in CODECS:
            codec = net.get_codec(name, value_bits=vb)
            msg = codec.encode(u)
            dec = codec.decode(msg)
            if vb == 32:
                assert np.array_equal(dec, u), codec.describe()
            else:
                bound = msg.meta.get("scale", 1.0) / 2 + 1e-6
                assert float(np.abs(dec - u).max()) <= bound
            rows.append({
                "bench": "net_codec", "codec": codec.describe(),
                "n_params": n_params, "ratio": ratio, "nnz": int(k),
                "payload_bytes": msg.nbytes,
                "vs_dense": msg.nbytes / dense_bytes,
            })
            emit(f"codec_{codec.describe()}_r{ratio}", 0.0,
                 f"bytes={msg.nbytes};vs_dense={msg.nbytes / dense_bytes:.3f}")
    return rows


def _spec(n_nodes: int, rounds: int, hw, samples: int,
          network: api.NetworkSpec) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=n_nodes, samples_per_node=samples, n_test=128,
            n_cloud_test=64, hw=hw,
            attack=api.AttackMix(malicious_frac=0.2),
            profile=api.NodeHeterogeneity(heterogeneity=0.5)),
        schedule=api.SchedulePolicy(kind="async"),
        privacy=api.PrivacySpec(sigma=0.05),
        compression=api.CompressionSpec(sparsify_ratio=0.1),
        defense=api.DefenseSpec(detect=True),
        network=network,
        train=api.TrainSpec(local_steps=3, batch_size=16, lr=0.1),
        rounds=rounds, seed=0)


def link_sweep(n_nodes: int, rounds: int, hw=(8, 8), samples: int = 40):
    """Accuracy / virtual-clock / κ / byte totals per link regime."""
    rows = []
    for regime, kw in LINK_REGIMES.items():
        network = api.NetworkSpec(**kw) if kw else api.NetworkSpec()
        spec = _spec(n_nodes, rounds, hw, samples, network)
        rep = api.run(api.compile_plan(spec))
        last = rep.records[-1]
        total_bytes = sum(r.comm_bytes for r in rep.records)
        row = {
            "bench": "net_link", "regime": regime,
            "codec": network.codec, "n_nodes": n_nodes, "rounds": rounds,
            "final_accuracy": rep.final_accuracy, "kappa": rep.kappa,
            "t_virtual": last.t, "comm_bytes": total_bytes,
            "bytes_source": last.bytes_source,
        }
        if rep.net is not None:
            row["wire_bytes"] = rep.net["wire_bytes"]
            row["retransmits"] = rep.net["retransmits"]
            assert total_bytes == rep.net["encoded_bytes"]
        rows.append(row)
        emit(f"net_link_{regime}", 0.0,
             f"acc={rep.final_accuracy:.3f};kappa={rep.kappa:.4f};"
             f"t={last.t:.2f}s;MB={total_bytes / 1e6:.3f}")
    return rows


def run() -> None:
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    rows = codec_table(n_params=200_000) + link_sweep(n_nodes=10, rounds=3)
    for r in rows:
        r["ts"] = stamp
    append_trajectory(RESULTS_PATH, rows)


def smoke() -> None:
    """Tiny codec table + a 2-regime link run — the CI liveness check."""
    rows = codec_table(n_params=4096)
    assert all(r["vs_dense"] < 1.0 for r in rows
               if r["codec"].startswith("sparse_bitpack")), \
        "sparse_bitpack must beat the dense wire at paper sparsity ratios"
    small = {k: LINK_REGIMES[k] for k in ("analytic", "lossy_industrial")}
    rows = []
    for regime, kw in small.items():
        network = api.NetworkSpec(**kw) if kw else api.NetworkSpec()
        spec = _spec(4, 1, (8, 8), 24, network)
        rep = api.run(api.compile_plan(spec))
        rows.append((regime, rep))
        emit(f"net_smoke_{regime}", 0.0,
             f"acc={rep.final_accuracy:.3f};"
             f"src={rep.records[-1].bytes_source}")
    (_, base), (_, lossy) = rows
    assert base.net is None and lossy.net is not None
    assert lossy.records[-1].bytes_source == "encoded"
    assert sum(r.comm_bytes for r in lossy.records) == \
        lossy.net["encoded_bytes"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny codec table + 2-regime link run (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()
