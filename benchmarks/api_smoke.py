"""API smoke: compile and run a tiny spec for each schedule × topology.

Covers the declarative surface end-to-end — every `SchedulePolicy.kind`
(sync / async / buffered) against every `Topology.kind` the host can run:
the sequential reference loops, the single-device fleet engines, and
(with ``--mesh D``, under ``XLA_FLAGS=--xla_force_host_platform_
device_count=D``) the mesh-sharded engines.  Each combination compiles,
runs, and must produce a JSON-round-trippable `RunReport`.

  PYTHONPATH=src python -m benchmarks.api_smoke               # seq + single
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
      PYTHONPATH=src python -m benchmarks.api_smoke --mesh 2  # + mesh combos
"""
from __future__ import annotations

import argparse

from repro import api

from .common import Timer, emit


def tiny_spec(kind: str, topology: str, devices: int | None = None,
              backend: str = "reference") -> api.ExperimentSpec:
    return api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=4, samples_per_node=24, n_test=64,
                            n_cloud_test=32,
                            attack=api.AttackMix(malicious_frac=0.25)),
        schedule=api.SchedulePolicy(kind=kind),
        privacy=api.PrivacySpec(sigma=0.05),
        compression=api.CompressionSpec(sparsify_ratio=0.5),
        defense=api.DefenseSpec(detect=True),
        topology=api.Topology(kind=topology, devices=devices,
                              backend=backend),
        train=api.TrainSpec(local_steps=2, batch_size=8, lr=0.1),
        rounds=2, seed=0)


def _combos(mesh_devices: int, backend: str):
    for kind in ("sync", "async", "buffered"):
        for topology in ("sequential", "single"):
            if kind == "buffered" and topology == "sequential":
                continue        # buffered has no sequential reference loop
            if backend == "pallas" and topology == "sequential":
                continue        # kernels are engine-only; plan rejects this
            yield kind, topology, None
        if mesh_devices:
            yield kind, "mesh", mesh_devices


def run(mesh_devices: int = 0, backend: str = "reference") -> None:
    for kind, topology, devices in _combos(mesh_devices, backend):
        spec = tiny_spec(kind, topology, devices, backend)
        plan = api.compile_plan(spec)
        with Timer() as t:
            rep = api.run(plan)
        assert rep.records, f"{kind}/{topology}: empty report"
        assert api.RunReport.from_json(rep.to_json()).records == rep.records
        tag = topology if devices is None else f"mesh{devices}"
        if backend != "reference":
            tag = f"{tag}_{backend}"
        emit(f"api_smoke_{kind}_{tag}", t.us / len(rep.records),
             f"engine={rep.engine};acc={rep.final_accuracy:.3f};"
             f"records={len(rep.records)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0, metavar="D",
                    help="also run mesh-topology combos over D local "
                         "devices (force them with XLA_FLAGS on CPU)")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"),
                    help="upload-pipeline backend: pallas runs the fused "
                         "megakernel + window-fold kernel paths")
    args = ap.parse_args()
    run(mesh_devices=args.mesh, backend=args.backend)
    print("API SMOKE OK")


if __name__ == "__main__":
    main()
