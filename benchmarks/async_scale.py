"""Async-scale micro-benchmark: event loop vs windowed AsyncFleetEngine.

Sweeps n_nodes ∈ {10, 100} on the `honest` synthetic-MLP scenario. The
fleet engine is run for a fixed number of arrival windows; the sequential
event loop (`repro.api` with `Topology(kind="sequential")`, async
schedule) is then run over the *same number of processed arrivals*, so

    speedup = event_loop_wall_clock / fleet_wall_clock

is a per-window (equivalently per-arrival) comparison at identical
simulated work. The event loop pays one Python/JAX dispatch per arrival;
the engine one dispatch per window.

Each invocation appends one record per swept size to the JSON trajectory at
``results/async_scale.json`` (shared with `benchmarks.fig7_compare`'s async
records) so speedups are tracked across commits.

  PYTHONPATH=src python -m benchmarks.async_scale            # the sweep
  PYTHONPATH=src python -m benchmarks.async_scale --smoke    # 2-window CI run
"""
from __future__ import annotations

import argparse
import os
import time

from .common import append_trajectory, emit

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "async_scale.json")
SWEEP = (10, 100)
TIMED_WINDOWS = 4


def _scenario(n_nodes: int):
    from repro.fleet import get_scenario
    return get_scenario("honest").with_nodes(n_nodes)


def _build_async_fleet(n_nodes: int):
    from repro.fleet import build_async_engine
    return build_async_engine(_scenario(n_nodes), seed=0)


def _build_event_loop(n_nodes: int, rounds: int):
    """(plan, population, state) for the per-arrival reference event loop
    — each `api.execute` call processes rounds×n_nodes arrivals,
    continuing the chain state across timing iterations."""
    from .fleet_scale import _build_sequential
    return _build_sequential(n_nodes, kind="async", rounds=rounds)


def _time_fleet(n_nodes: int):
    """(seconds per window, arrivals actually processed per window)."""
    eng = _build_async_fleet(n_nodes)
    for _ in range(4):
        eng.run_window()                     # compile likely buckets + warm
    warm = len(eng.history)
    t0 = time.perf_counter()
    for _ in range(TIMED_WINDOWS):
        eng.run_window()
    dt = (time.perf_counter() - t0) / TIMED_WINDOWS
    arrivals = sum(r.n_processed for r in eng.history[warm:]) / TIMED_WINDOWS
    return dt, arrivals


def _time_event_loop(n_nodes: int, arrivals: int) -> float:
    """Seconds for the sequential event loop to process `arrivals`
    (measured over whole simulated rounds of n_nodes arrivals and scaled
    per-arrival — each `run()` call processes rounds×n_nodes arrivals)."""
    from repro import api
    plan, pop, state = _build_event_loop(n_nodes, rounds=1)
    api.execute(plan, pop, state)    # compile + warm (n_nodes arrivals)
    rounds = max(1, round(arrivals / n_nodes))
    t0 = time.perf_counter()
    for _ in range(rounds):
        api.execute(plan, pop, state)        # one round = n_nodes arrivals
    dt = time.perf_counter() - t0
    return dt / (rounds * n_nodes) * arrivals


def run() -> None:
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    records = []
    for n in SWEEP:
        fleet_s, arrivals = _time_fleet(n)
        loop_s = _time_event_loop(n, int(round(arrivals * TIMED_WINDOWS))) \
            / TIMED_WINDOWS
        speedup = loop_s / fleet_s
        emit(f"async_window_n{n}", fleet_s * 1e6,
             f"loop_s={loop_s:.4f};arrivals_per_window={arrivals:.1f};"
             f"speedup={speedup:.1f}x")
        records.append({
            "ts": stamp, "bench": "async_scale", "n_nodes": n,
            "fleet_s_per_window": fleet_s, "loop_s_per_window": loop_s,
            "arrivals_per_window": arrivals, "speedup": speedup,
        })
    append_trajectory(RESULTS_PATH, records)


def smoke() -> None:
    """2-window async fleet run on synthetic data — the CI liveness check."""
    eng = _build_async_fleet(16)
    for _ in range(2):
        r = eng.run_window()
        print(f"window={r.window} arrivals={r.n_processed} "
              f"acc={r.accuracy:.3f} t={r.t:.2f}s version={r.version}")
    assert len(eng.history) == 2
    assert sum(r.n_processed for r in eng.history) >= 2


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2-window 16-node async fleet run (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()
