"""Beyond-paper ablations: non-IID data (Dirichlet) and staleness-adaptive α.

The paper evaluates IID partitions and fixed α=0.5 only; real IIoT fleets are
non-IID and heterogeneous, so we measure how the framework holds up.
"""
from __future__ import annotations

from repro import api

from .common import Timer, emit, prepare_mode


def _run(iid: bool, staleness_adaptive: bool, alpha: float = 0.5):
    plan, pop = prepare_mode("aldpfl", n_malicious=0, detect=False,
                             iid=iid, staleness_adaptive=staleness_adaptive,
                             alpha=alpha, heterogeneity=1.0)
    with Timer() as t:
        rep = api.run(plan, population=pop)
    return rep, t


def run() -> None:
    for iid in (True, False):
        rep, t = _run(iid, False)
        emit(f"ablation_{'iid' if iid else 'noniid'}",
             t.us / len(rep.records),
             f"accuracy={rep.final_accuracy:.3f}")
    for adaptive in (False, True):
        rep, t = _run(True, adaptive)
        tag = "adaptive" if adaptive else "fixed"
        emit(f"ablation_staleness_{tag}", t.us / len(rep.records),
             f"accuracy={rep.final_accuracy:.3f}")
    for alpha in (0.1, 0.3, 0.5, 0.7, 0.9):
        rep, t = _run(True, False, alpha=alpha)
        emit(f"ablation_alpha{alpha}", t.us / len(rep.records),
             f"accuracy={rep.final_accuracy:.3f}")


if __name__ == "__main__":
    run()
