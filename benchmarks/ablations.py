"""Beyond-paper ablations: non-IID data (Dirichlet) and staleness-adaptive α.

The paper evaluates IID partitions and fixed α=0.5 only; real IIoT fleets are
non-IID and heterogeneous, so we measure how the framework holds up.
"""
from __future__ import annotations

import jax

from .common import HW, N_NODES, ROUNDS, Timer, emit

from repro.core import FedConfig, FederatedTrainer
from repro.data import make_federated_image_data
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


def _trainer(iid: bool, staleness_adaptive: bool, alpha: float = 0.5):
    node_data, test, cloud, _ = make_federated_image_data(
        0, n_nodes=N_NODES, n_malicious=0, n_train=1500, n_test=400,
        n_cloud_test=300, hw=HW, iid=iid, dirichlet_alpha=0.3)
    cfg = FedConfig(mode="aldpfl", n_nodes=N_NODES, rounds=ROUNDS,
                    local_steps=12, batch_size=32, lr=0.1, alpha=alpha,
                    detect=False, sigma=0.05,
                    staleness_adaptive=staleness_adaptive,
                    heterogeneity=1.0)
    return FederatedTrainer(init_cnn(jax.random.PRNGKey(0), in_hw=HW),
                            cnn_loss, cnn_accuracy, node_data, test, cloud,
                            cfg)


def run() -> None:
    for iid in (True, False):
        tr = _trainer(iid, False)
        with Timer() as t:
            hist = tr.run()
        emit(f"ablation_{'iid' if iid else 'noniid'}", t.us / len(hist),
             f"accuracy={hist[-1].accuracy:.3f}")
    for adaptive in (False, True):
        tr = _trainer(True, adaptive)
        with Timer() as t:
            hist = tr.run()
        tag = "adaptive" if adaptive else "fixed"
        emit(f"ablation_staleness_{tag}", t.us / len(hist),
             f"accuracy={hist[-1].accuracy:.3f}")
    for alpha in (0.1, 0.3, 0.5, 0.7, 0.9):
        tr = _trainer(True, False, alpha=alpha)
        with Timer() as t:
            hist = tr.run()
        emit(f"ablation_alpha{alpha}", t.us / len(hist),
             f"accuracy={hist[-1].accuracy:.3f}")


if __name__ == "__main__":
    run()
