"""Attack matrix: the adversary zoo × defense × schedule resilience grid.

Runs every `api.AttackMix` adversary (label_flip, sybil, backdoor,
adaptive, ddos) against every defense posture (none, the paper's
percentile detector, trust/uncertainty-weighted aggregation) under both
schedules (sync cohort rounds, async arrival windows), and reports the
attack success rate each cell achieves:

  * label_flip / sybil / adaptive — `attacks.flip_success_rate`: the
    fraction of true flip-source test samples the final model labels as
    the flip destination (paper Fig. 8's special-task metric);
  * backdoor — `attacks.backdoor_success_rate`: the fraction of
    non-target test samples stamped with the pixel trigger that flip to
    the trigger label;
  * ddos — the shared-uplink communication-time slowdown vs a clean run
    of the same spec (flash traffic degrades the wire, not the labels).

Rows land in ``results/attack_matrix.json`` through the api's
schema-stamped serializer and are pinned by ``tools/bench_check.py``.

  PYTHONPATH=src python -m benchmarks.attack_matrix          # full grid
  PYTHONPATH=src python -m benchmarks.attack_matrix --smoke  # tiny CI run
"""
from __future__ import annotations

import argparse
import os
import time

from repro import api
from repro.core.attacks import backdoor_success_rate, flip_success_rate
from repro.models.mlp import mlp_forward

from .common import append_trajectory, emit

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "attack_matrix.json")

ATTACKS = ("label_flip", "sybil", "backdoor", "adaptive", "ddos")
DEFENSES = ("none", "percentile", "trust_weighted")
SCHEDULES = ("sync", "async")

N_NODES = 10
# a contested cohort: under plain-mean aggregation on IID shards a small
# malicious minority is diluted to ASR noise, so the grid staffs half the
# fleet — the regime where defenses visibly separate
MALICIOUS_FRAC = 0.5
FLIP_SRC, FLIP_DST = 1, 7
TRIGGER_LABEL = 0
HW = (8, 8)
SHARED_UPLINK_BPS = 1.5e6       # congested enough that flood flows bite


def _defense(name: str) -> api.DefenseSpec:
    if name == "none":
        return api.DefenseSpec(detect=False)
    return api.DefenseSpec(detect=True, kind=(
        "trust_weighted" if name == "trust_weighted" else "percentile"))


def _spec(attack: str, defense: str, schedule: str, *, rounds: int,
          samples: int, malicious_frac: float = MALICIOUS_FRAC,
          seed: int = 0) -> api.ExperimentSpec:
    # ddos needs a simulated shared uplink for its flood flows to contend
    # on; its clean baseline (malicious_frac=0) runs the same wire so the
    # slowdown isolates the attack
    network = (api.NetworkSpec(codec="dense_f32", latency_s=0.01,
                               shared_uplink_bps=SHARED_UPLINK_BPS)
               if attack == "ddos" else api.NetworkSpec())
    return api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=N_NODES, samples_per_node=samples, n_test=256,
            n_cloud_test=128, hw=HW,
            attack=api.AttackMix(malicious_frac=malicious_frac, kind=attack,
                                 flip_src=FLIP_SRC, flip_dst=FLIP_DST,
                                 trigger_label=TRIGGER_LABEL),
            profile=api.NodeHeterogeneity(heterogeneity=0.5)),
        schedule=api.SchedulePolicy(kind=schedule),
        defense=_defense(defense),
        network=network,
        train=api.TrainSpec(local_steps=8, batch_size=16, lr=0.2),
        rounds=rounds, seed=seed)


def _asr(attack: str, rep: api.RunReport, pop, clean_comm: float) -> float:
    x, y = pop.test_data
    if attack == "backdoor":
        return backdoor_success_rate(mlp_forward, rep.final_params, x, y,
                                     TRIGGER_LABEL)
    if attack == "ddos":
        comm = sum(r.comm_time for r in rep.records)
        return comm / clean_comm - 1.0 if clean_comm > 0 else 0.0
    return flip_success_rate(mlp_forward, rep.final_params, x, y,
                             FLIP_SRC, FLIP_DST)


def run_cell(attack: str, defense: str, schedule: str, *, rounds: int,
             samples: int, clean_comm: float) -> dict:
    spec = _spec(attack, defense, schedule, rounds=rounds, samples=samples)
    pop = api.materialize(spec)
    rep = api.run(api.compile_plan(spec), population=pop)
    asr = _asr(attack, rep, pop, clean_comm)
    row = {
        "bench": "attack_matrix", "attack": attack, "defense": defense,
        "schedule": schedule, "n_nodes": N_NODES,
        "malicious_frac": MALICIOUS_FRAC, "rounds": rounds,
        "final_accuracy": rep.final_accuracy, "asr": float(asr),
        "n_rejected": sum(r.n_rejected for r in rep.records),
        "comm_time": sum(r.comm_time for r in rep.records),
        "comm_bytes": sum(r.comm_bytes for r in rep.records),
    }
    emit(f"attack_{attack}_{defense}_{schedule}", 0.0,
         f"acc={row['final_accuracy']:.3f};asr={asr:.3f};"
         f"rej={row['n_rejected']}")
    return row


def clean_comm_baseline(schedule: str, *, rounds: int, samples: int
                        ) -> float:
    """Total comm_time of an attack-free run on the ddos cells' congested
    shared uplink — the denominator of the ddos slowdown metric."""
    spec = _spec("ddos", "none", schedule, rounds=rounds, samples=samples,
                 malicious_frac=0.0)
    rep = api.run(api.compile_plan(spec))
    return sum(r.comm_time for r in rep.records)


def run_grid(*, rounds: int, samples: int) -> list:
    rows = []
    for schedule in SCHEDULES:
        clean_comm = clean_comm_baseline(schedule, rounds=rounds,
                                         samples=samples)
        emit(f"attack_clean_{schedule}", 0.0, f"comm={clean_comm:.3f}s")
        for attack in ATTACKS:
            for defense in DEFENSES:
                rows.append(run_cell(attack, defense, schedule,
                                     rounds=rounds, samples=samples,
                                     clean_comm=clean_comm))
    return rows


def check_defense_wins(rows) -> None:
    """The PR's acceptance bar: trust-weighted aggregation must measurably
    beat no-defense on the flip-style attacks, per schedule."""
    by = {(r["attack"], r["defense"], r["schedule"]): r for r in rows}
    for attack in ("label_flip", "sybil"):
        for schedule in SCHEDULES:
            none = by[(attack, "none", schedule)]["asr"]
            trust = by[(attack, "trust_weighted", schedule)]["asr"]
            assert trust < none, (
                f"{attack}/{schedule}: trust_weighted ASR {trust:.3f} not "
                f"below no-defense ASR {none:.3f}")


def run() -> None:
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    rows = run_grid(rounds=10, samples=100)
    check_defense_wins(rows)
    for r in rows:
        r["ts"] = stamp
    append_trajectory(RESULTS_PATH, rows)


def smoke() -> None:
    """One attack per mechanism class on a tiny budget — asserts the grid
    plumbing end-to-end without touching results/."""
    clean = clean_comm_baseline("async", rounds=2, samples=24)
    cells = [("label_flip", "trust_weighted", "sync"),
             ("sybil", "percentile", "async"),
             ("ddos", "none", "async")]
    for attack, defense, schedule in cells:
        row = run_cell(attack, defense, schedule, rounds=2, samples=24,
                       clean_comm=clean)
        assert 0.0 <= row["final_accuracy"] <= 1.0
    assert row["asr"] > 0.0, "ddos flood must slow the shared uplink"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="three representative cells, no results write")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run()
