"""Paper Fig. 8: label-flipping robustness vs malicious proportion p.

General task = overall accuracy; special task = accuracy on the attacked
class (digit '1' analogue: class 1 flipped to 7).
"""
from __future__ import annotations

import jax
import numpy as np

from .common import HW, Timer, build_trainer, emit


def run() -> None:
    from repro.models.cnn import per_class_accuracy
    for p in (10, 20, 30):
        n_mal = max(1, round(p / 100 * 10))
        for detect in (True, False):
            tr = build_trainer("aldpfl", n_malicious=n_mal, detect=detect)
            with Timer() as t:
                hist = tr.run()
            x_te, y_te = tr.test_data
            special = float(per_class_accuracy(tr.params, x_te, y_te, 1))
            tag = "with" if detect else "without"
            emit(f"fig8a_general_p{p}_{tag}", t.us / len(hist),
                 f"accuracy={hist[-1].accuracy:.3f}")
            emit(f"fig8b_special_p{p}_{tag}", t.us / len(hist),
                 f"class1_acc={special:.3f}")


if __name__ == "__main__":
    run()
