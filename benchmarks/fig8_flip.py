"""Paper Fig. 8: label-flipping robustness vs malicious proportion p.

General task = overall accuracy; special task = accuracy on the attacked
class (digit '1' analogue: class 1 flipped to 7).  Per-class accuracy needs
the final params and the test set, so this bench runs the compiled plan
over an explicitly materialized population and reads
``report.final_params``.
"""
from __future__ import annotations

from repro import api

from .common import Timer, emit, prepare_mode


def run() -> None:
    from repro.models.cnn import per_class_accuracy
    for p in (10, 20, 30):
        n_mal = max(1, round(p / 100 * 10))
        for detect in (True, False):
            plan, pop = prepare_mode("aldpfl", n_malicious=n_mal,
                                     detect=detect)
            with Timer() as t:
                rep = api.run(plan, population=pop)
            x_te, y_te = pop.test_data
            special = float(per_class_accuracy(rep.final_params, x_te,
                                               y_te, 1))
            tag = "with" if detect else "without"
            emit(f"fig8a_general_p{p}_{tag}", t.us / len(rep.records),
                 f"accuracy={rep.final_accuracy:.3f}")
            emit(f"fig8b_special_p{p}_{tag}", t.us / len(rep.records),
                 f"class1_acc={special:.3f}")


if __name__ == "__main__":
    run()
