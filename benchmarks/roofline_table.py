"""Roofline table: summarises every dry-run JSON in results/dryrun into the
§Roofline rows (per arch × shape × mesh: three terms, dominant, ratios)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline_table", 0.0, "no dry-run results found — run "
             "`python -m repro.launch.dryrun_all` first")
        return
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        tag = f"{rec.get('arch')}.{rec.get('shape')}.{rec.get('mesh')}"
        if rec.get("status") != "ok":
            emit(f"roofline_{tag}", 0.0, f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        emit(f"roofline_{tag}", rec["timings"]["compile_s"] * 1e6,
             f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
             f"memory_lb_s={r.get('memory_lb_s', 0):.4g};"
             f"collective_s={r['collective_s']:.4g};dominant={r['dominant']};"
             f"useful_ratio={r['useful_flops_ratio']}")


if __name__ == "__main__":
    run()
