"""Paper §5.5: gradient-leakage (DLG) attack vs the ALDP defence.

Reconstruction MSE and ASR as the noise multiplier σ grows (σ=0 is the
undefended baseline the malicious cloud exploits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Timer, emit

from repro.core.aldp import add_gaussian_noise
from repro.core.attacks import (attack_success_rate, dlg_attack,
                                reconstruction_mse)


def run() -> None:
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (64, 10)) * 0.2

    def loss(params, x, y_soft):
        return jnp.mean((x @ params - y_soft) ** 2)

    x_true = jax.random.normal(jax.random.PRNGKey(1), (2, 64)) * 0.5
    y_true = jax.nn.one_hot(jnp.array([3, 7]), 10)
    g = jax.grad(loss)(W, x_true, y_true)

    for sigma in (0.0, 0.01, 0.1, 0.5):
        g_obs = g if sigma == 0 else add_gaussian_noise(
            g, jax.random.PRNGKey(2), sigma, 1.0)
        with Timer() as t:
            x_rec, hist = dlg_attack(loss, W, g_obs, (2, 64), 10,
                                     jax.random.PRNGKey(3), steps=250, lr=0.1)
        mse = float(reconstruction_mse(x_true, x_rec))
        asr = float(attack_success_rate(x_true, x_rec, mse_threshold=0.05))
        emit(f"leakage_dlg_sigma{sigma}", t.us / 250,
             f"mse={mse:.4f};asr={asr:.2f}")


if __name__ == "__main__":
    run()
