"""Paper Fig. 6: malicious-node-detection threshold sweep s ∈ {50..90}.

(a) ASR — fraction of malicious-node updates that get aggregated;
(b) global accuracy at each threshold.

Each sweep point runs with the obs event stream on and cross-checks the
figure inputs against the per-node detection audit log: the per-round
rejection counts summed from ``detect.verdict`` instants must equal the
counts in the run's own records.  Fig. 6 is thereby reconstructable from
the trace alone — the audit log carries accuracy, threshold, and verdict
for every cloud evaluation, not just the aggregate.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

from repro import api

from .common import N_NODES, Timer, emit, spec_for_mode


def rejected_from_trace(path: str) -> int:
    """Total rejections summed straight from the detection audit log."""
    n = 0
    with open(path) as fh:
        for line in fh:
            d = json.loads(line)
            if (d.get("kind") == "instant"
                    and d.get("name") == "detect.verdict"):
                n += bool(d["tags"]["rejected"])
    return n


def run() -> None:
    for s in (50, 60, 70, 80, 90):
        spec = spec_for_mode("aldpfl", n_malicious=3, detect=True,
                             detect_s=float(s))
        with tempfile.TemporaryDirectory() as td:
            ev = os.path.join(td, f"fig6_s{s}_events.jsonl")
            spec = dataclasses.replace(
                spec, obs=api.ObsSpec(enabled=True, events_jsonl=ev))
            plan = api.compile_plan(spec)
            pop = api.materialize(spec)
            with Timer() as t:
                rep = api.run(plan, population=pop)
            audit_rejected = rejected_from_trace(ev)
        total = len(rep.records) * N_NODES
        rejected = sum(r.n_rejected for r in rep.records)
        if audit_rejected != rejected:
            raise AssertionError(
                f"s={s}: audit log says {audit_rejected} rejections, "
                f"records say {rejected} — trace no longer reconstructs "
                f"Fig. 6")
        # proxy ASR: malicious updates not rejected / malicious updates sent
        sent_malicious = len(rep.records) * 3
        asr = max(0.0, (sent_malicious - rejected) / sent_malicious)
        emit(f"fig6a_asr_s{s}", t.us / max(total, 1), f"asr={asr:.3f}")
        emit(f"fig6b_acc_s{s}", t.us / max(total, 1),
             f"accuracy={rep.final_accuracy:.3f}")


if __name__ == "__main__":
    run()
