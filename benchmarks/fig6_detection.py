"""Paper Fig. 6: malicious-node-detection threshold sweep s ∈ {50..90}.

(a) ASR — fraction of malicious-node updates that get aggregated;
(b) global accuracy at each threshold.
"""
from __future__ import annotations

from repro import api

from .common import N_NODES, Timer, emit, prepare_mode


def run() -> None:
    for s in (50, 60, 70, 80, 90):
        plan, pop = prepare_mode("aldpfl", n_malicious=3, detect=True,
                                 detect_s=float(s))
        with Timer() as t:
            rep = api.run(plan, population=pop)
        total = len(rep.records) * N_NODES
        rejected = sum(r.n_rejected for r in rep.records)
        # proxy ASR: malicious updates not rejected / malicious updates sent
        sent_malicious = len(rep.records) * 3
        asr = max(0.0, (sent_malicious - rejected) / sent_malicious)
        emit(f"fig6a_asr_s{s}", t.us / max(total, 1), f"asr={asr:.3f}")
        emit(f"fig6b_acc_s{s}", t.us / max(total, 1),
             f"accuracy={rep.final_accuracy:.3f}")


if __name__ == "__main__":
    run()
