"""Paper Fig. 6: malicious-node-detection threshold sweep s ∈ {50..90}.

(a) ASR — fraction of malicious-node updates that get aggregated;
(b) global accuracy at each threshold.
"""
from __future__ import annotations

from .common import Timer, build_trainer, emit


def run() -> None:
    for s in (50, 60, 70, 80, 90):
        tr = build_trainer("aldpfl", n_malicious=3, detect=True,
                           detect_s=float(s))
        with Timer() as t:
            hist = tr.run()
        total = len(hist) * tr.cfg.n_nodes
        rejected = sum(r.n_rejected for r in hist)
        # proxy ASR: malicious updates not rejected / malicious updates sent
        sent_malicious = len(hist) * 3
        asr = max(0.0, (sent_malicious - rejected) / sent_malicious)
        emit(f"fig6a_asr_s{s}", t.us / max(total, 1), f"asr={asr:.3f}")
        emit(f"fig6b_acc_s{s}", t.us / max(total, 1),
             f"accuracy={hist[-1].accuracy:.3f}")


if __name__ == "__main__":
    run()
