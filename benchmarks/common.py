"""Shared helpers for the benchmark harness (CPU-sized paper reproductions)."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.core import FedConfig, FederatedTrainer           # noqa: E402
from repro.data import make_federated_image_data             # noqa: E402
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn  # noqa: E402

HW = (14, 14)          # reduced MNIST-shaped images (CPU budget)
N_NODES = 10
ROUNDS = 4
LOCAL_STEPS = 12


def build_trainer(mode: str, *, n_malicious: int = 3, detect: bool = True,
                  detect_s: float = 80.0, rounds: int = ROUNDS,
                  sparsify: float = 1.0, seed: int = 0,
                  sigma: float | None = 0.05) -> FederatedTrainer:
    """sigma=0.05 default (workable SNR); pass sigma=None for the paper's
    ε=8 calibration — the sigma-tradeoff bench sweeps both."""
    node_data, test, cloud, _ = make_federated_image_data(
        seed, n_nodes=N_NODES, n_malicious=n_malicious, n_train=1500,
        n_test=400, n_cloud_test=300, hw=HW)
    cfg = FedConfig(mode=mode, n_nodes=N_NODES, rounds=rounds,
                    local_steps=LOCAL_STEPS, batch_size=32, lr=0.1,
                    detect=detect, detect_s=detect_s, sparsify_ratio=sparsify,
                    sigma=sigma, seed=seed)
    params = init_cnn(jax.random.PRNGKey(seed), in_hw=HW)
    return FederatedTrainer(params, cnn_loss, cnn_accuracy, node_data, test,
                            cloud, cfg)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def append_trajectory(path: str, records) -> None:
    """Append benchmark records to a JSON trajectory file (one shared
    format across fleet_scale/async_scale/fig7_compare)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    traj = []
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
    traj.extend(records)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.dt * 1e6
