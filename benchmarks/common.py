"""Shared helpers for the benchmark harness (CPU-sized paper reproductions).

Benchmarks describe experiments declaratively through `repro.api`
(`spec_for_mode` -> `compile_plan` -> `run`) and write every trajectory
record through the api's schema-stamped serializer
(`api.append_json_records`), so ``results/*.json`` share one versioned
format with `RunReport`.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api                                        # noqa: E402

HW = (14, 14)          # reduced MNIST-shaped images (CPU budget)
N_NODES = 10
ROUNDS = 4
LOCAL_STEPS = 12

_SCHEDULE = {"sfl": "sync", "afl": "async",
             "sldpfl": "sync", "aldpfl": "async"}


def spec_for_mode(mode: str, *, n_malicious: int = 3, detect: bool = True,
                  detect_s: float = 80.0, rounds: int = ROUNDS,
                  sparsify: float = 1.0, seed: int = 0,
                  sigma: float | None = 0.05,
                  alpha: float = 0.5, staleness_adaptive: bool = False,
                  heterogeneity: float = 0.5, iid: bool = True,
                  topology: str = "single") -> api.ExperimentSpec:
    """The benchmark CNN population as a declarative spec.

    sigma=0.05 default (workable SNR); pass sigma=None for the paper's
    ε=8 calibration — the sigma-tradeoff bench sweeps both.  The no-noise
    modes (sfl/afl) run with σ=0 regardless of the sigma argument.
    """
    kind = _SCHEDULE[mode]
    return api.ExperimentSpec(
        fleet=api.FleetSpec(
            n_nodes=N_NODES,
            profile=api.NodeHeterogeneity(heterogeneity=heterogeneity),
            attack=api.AttackMix(malicious_frac=n_malicious / N_NODES),
            model="cnn", hw=HW, samples_per_node=1500 // N_NODES,
            n_test=400, n_cloud_test=300, iid=iid, dirichlet_alpha=0.3),
        schedule=api.SchedulePolicy(
            kind=kind, alpha=alpha,
            staleness_adaptive=(staleness_adaptive if kind == "async"
                                else False)),
        privacy=api.PrivacySpec(
            sigma=(0.0 if mode in ("sfl", "afl") else sigma)),
        compression=api.CompressionSpec(sparsify_ratio=sparsify),
        defense=api.DefenseSpec(detect=detect, detect_s=detect_s),
        topology=api.Topology(kind=topology),
        train=api.TrainSpec(local_steps=LOCAL_STEPS, batch_size=32, lr=0.1),
        rounds=rounds, seed=seed)


def prepare_mode(mode: str, **kw):
    """(plan, population) for one of the paper's four schemes — compiled
    and materialized up front so callers time only `api.run` (matching
    the pre-redesign benches, which built the trainer outside the
    Timer)."""
    spec = spec_for_mode(mode, **kw)
    plan = api.compile_plan(spec)
    return plan, api.materialize(spec)


def run_mode(mode: str, **kw) -> api.RunReport:
    """spec -> plan -> run for one of the paper's four schemes."""
    plan, pop = prepare_mode(mode, **kw)
    return api.run(plan, population=pop)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def append_trajectory(path: str, records) -> None:
    """Append benchmark records to a JSON trajectory file through the
    api's schema-stamped writer (one shared, versioned format across
    fleet_scale/async_scale/fig7_compare)."""
    api.append_json_records(path, records)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.dt * 1e6
