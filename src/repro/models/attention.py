"""GQA attention: blocked (q-chunked) softmax for long sequences, KV-cache decode.

The pure-jnp path never materialises the full (Sq, Sk) score matrix for the
whole sequence at once — it scans over query chunks, which keeps peak memory
at ``B * H * chunk * Sk`` per layer and lowers cleanly under pjit on any
backend. The Pallas flash-attention kernel (repro.kernels.flash_attention) is
an opt-in drop-in for real TPU runs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain_batch
from .layers import init_linear, linear_fwd

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   dtype: str = "float32") -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_linear(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_linear(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_linear(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def qkv(p: dict, x: jnp.ndarray, n_heads: int, n_kv_heads: int, head_dim: int
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    q = linear_fwd(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear_fwd(p["wk"], x).reshape(B, S, n_kv_heads, head_dim)
    v = linear_fwd(p["wv"], x).reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Blocked multi-query attention core
# ---------------------------------------------------------------------------

def _attend_chunk(qc: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  qpos: jnp.ndarray, kpos: jnp.ndarray,
                  causal: bool, window: int) -> jnp.ndarray:
    """qc (B, C, H, D); k,v (B, Sk, KV, D); qpos (C,), kpos (Sk,)."""
    B, C, H, D = qc.shape
    KV = k.shape[2]
    G = H // KV
    qg = qc.reshape(B, C, KV, G, D)
    scores = jnp.einsum("bckgd,bskd->bkgcs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(D))
    mask = jnp.ones((C, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
    out = jnp.einsum("bkgcs,bskd->bckgd", probs, v)
    return out.reshape(B, C, H, D)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0, q_offset: int = 0,
              chunk: int = 512, causal_skip: bool = True) -> jnp.ndarray:
    """Full attention over (possibly long) sequences, q-chunked.

    q (B, Sq, H, D); k, v (B, Sk, KV, D) with H % KV == 0. Returns (B, Sq, H, D).

    When ``causal_skip`` (and the shapes allow it), q-chunks run as an
    UNROLLED loop where chunk i only reads keys [0 : (i+1)·chunk] — a static
    slice per chunk, so fully-masked key blocks are never computed. This
    halves attention flops vs the scan path, which must use the full key
    length every iteration (lax.scan cannot carry dynamic shapes). The scan
    path remains for windowed / offset cases.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    kpos = jnp.arange(Sk)
    if Sq <= chunk:
        qpos = q_offset + jnp.arange(Sq)
        return _attend_chunk(q, k, v, qpos, kpos, causal, window)
    n = -(-Sq // chunk)
    pad = n * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    # keep batch sharded across the loop boundary (XLA propagation can drop
    # the batch sharding of loop-carried operands — see sharding/ctx.py)
    k = constrain_batch(k, 0)
    v = constrain_batch(v, 0)

    if causal and causal_skip and window == 0 and q_offset == 0 and Sq == Sk:
        # causal block skipping: chunk i attends keys [0:(i+1)·chunk] only.
        # Cap the unroll at 16 blocks so the HLO stays compact.
        chunk_u = chunk
        while -(-Sq // chunk_u) > 16:
            chunk_u *= 2
        n_u = -(-Sq // chunk_u)
        pad_u = n_u * chunk_u - Sq
        qp = jnp.pad(q, ((0, 0), (0, pad_u), (0, 0), (0, 0))) if pad_u else q
        qp4 = qp.reshape(B, n_u, chunk_u, H, D)
        outs = []
        for i in range(n_u):
            hi = min((i + 1) * chunk_u, Sk)
            qpos = i * chunk_u + jnp.arange(chunk_u)
            o = _attend_chunk(qp4[:, i], k[:, :hi], v[:, :hi], qpos,
                              kpos[:hi], True, 0)
            outs.append(constrain_batch(o, 0))
        out = jnp.concatenate(outs, axis=1)
        return out[:, :Sq]

    qp = qp.reshape(B, n, chunk, H, D).transpose(1, 0, 2, 3, 4)
    qp = constrain_batch(qp, 1)

    def body(carry, inp):
        i, qc = inp
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        o = constrain_batch(_attend_chunk(qc, k, v, qpos, kpos, causal, window), 0)
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qp))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, D)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# KV cache (supports ring-buffer sliding window)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
        # number of tokens written so far (scalar int32)
        "idx": jnp.zeros((), dtype=jnp.int32),
    }


def cache_write(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray) -> dict:
    """Append S_new tokens; ring-buffer wraps when the cache is full."""
    C = cache["k"].shape[1]
    S_new = k_new.shape[1]
    start = jnp.mod(cache["idx"], C)
    idxs = jnp.mod(start + jnp.arange(S_new), C)
    k = cache["k"].at[:, idxs].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, idxs].set(v_new.astype(cache["v"].dtype))
    return {"k": k, "v": v, "idx": cache["idx"] + S_new}


def decode_attend(q: jnp.ndarray, cache: dict, *, window: int = 0) -> jnp.ndarray:
    """One-token attention against the cache. q (B, 1, H, D) -> (B, 1, H, D).

    All cached entries are in the past, so no ordering mask is needed beyond
    validity; sliding windows are enforced by the ring buffer size itself
    (cache_len == window) plus the validity mask.
    """
    B, one, H, D = q.shape
    k, v, idx = cache["k"], cache["v"], cache["idx"]
    C = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(D))
    valid = jnp.arange(C) < jnp.minimum(idx, C)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v)
    return out.reshape(B, 1, H, D)
