"""Tiny MLP edge model for fleet-scale runs.

The paper's CNN (`models/cnn.py`) is the faithful edge model; at thousand-node
fleet scale a vmapped CNN forward over every node dominates the round, so the
scale benchmarks and scenario sweeps use this 2-layer MLP on flattened
images instead — same (params, batch) contract as the CNN, orders of
magnitude cheaper per node.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def init_mlp(key, in_dim: int, hidden: int = 32, n_classes: int = 10) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": {"w": jax.random.normal(k1, (in_dim, hidden)) / np.sqrt(in_dim),
                "b": jnp.zeros((hidden,))},
        "fc2": {"w": jax.random.normal(k2, (hidden, n_classes)) / np.sqrt(hidden),
                "b": jnp.zeros((n_classes,))},
    }


def mlp_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, ...) — trailing dims are flattened — -> logits (B, n_classes)."""
    h = x.reshape(x.shape[0], -1)
    h = jnp.tanh(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def mlp_loss(params: dict, batch: dict) -> Tuple[jnp.ndarray, dict]:
    logits = mlp_forward(params, batch["x"])
    logp = jax.nn.log_softmax(logits)
    y = batch["y"].astype(jnp.int32)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((logits.argmax(-1) == y).astype(jnp.float32))
    return loss, {"accuracy": acc}


def mlp_accuracy(params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = mlp_forward(params, x)
    return jnp.mean((logits.argmax(-1) == y.astype(jnp.int32))
                    .astype(jnp.float32))
