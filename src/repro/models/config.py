"""Model configuration dataclasses for the FedEdge-JAX model zoo.

Every architecture in ``repro.configs`` instantiates a :class:`ModelConfig`.
The config is a frozen dataclass so it can be closed over by jitted functions
and hashed as a static argument.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts sub-config (per-layer FFN replacement)."""

    n_experts: int
    top_k: int
    d_expert: int                 # hidden dim of each expert FFN
    n_shared: int = 0             # always-on shared experts (Kimi/Llama4 style)
    capacity_factor: float = 1.25
    min_capacity: int = 4         # floor on per-expert capacity: tiny-T calls
                                  # (decode: T = B) otherwise drop tokens the
                                  # full-sequence forward keeps
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """State-space (Mamba) sub-config."""

    kind: str = "mamba1"          # "mamba1" | "mamba2"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 only
    n_groups: int = 1             # mamba2 B/C groups
    chunk: int = 128              # chunked-scan block length
    scan_dtype: str = "float32"   # within-chunk scan element dtype
                                  # ("bfloat16" halves scan HBM traffic at
                                  # ~1e-2 relative error — opt-in)


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    family:
      dense  — decoder-only transformer
      moe    — decoder-only transformer with MoE FFN
      ssm    — attention-free Mamba stack
      hybrid — Mamba2 stack with a shared attention block every ``attn_every``
      vlm    — decoder-only transformer consuming [patch_embeds; tokens]
      audio  — encoder-decoder transformer consuming precomputed audio frames
    """

    name: str = "model"
    family: str = "dense"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 256
    head_dim: int = 0             # 0 => d_model // n_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_mode: str = "standard"   # "standard" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (2, 1, 1)   # fractions of head_dim/2 (t,h,w)
    sliding_window: int = 0       # 0 = full attention
    attn_chunk: int = 512         # q-chunk length for blocked softmax

    # norms
    norm: str = "rmsnorm"         # "rmsnorm" | "layernorm" | "nonparam_ln"
    norm_eps: float = 1e-5

    # MLP
    mlp: str = "swiglu"           # "swiglu" | "gelu"

    # family sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0           # hybrid: shared attn block period (0 = never)

    # audio (encoder-decoder)
    encoder_layers: int = 0
    n_audio_frames: int = 1500

    # vlm
    n_patches: int = 0            # patch embeddings prepended to the sequence
    patch_grid: Tuple[int, int] = (16, 16)

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = False

    # distribution
    seq_parallel: bool = False    # pin the residual stream's seq dim to
                                  # "model" between blocks: XLA then lowers
                                  # the TP activation syncs as
                                  # reduce-scatter/all-gather instead of
                                  # full all-reduces (Megatran-SP analogue;
                                  # refuted on XLA-CPU, see EXPERIMENTS.md)

    # kernels
    use_flash: bool = False       # route self-attention through the Pallas
                                  # flash kernel (interpret on CPU, native on
                                  # TPU); default off so dry-runs lower on
                                  # the CPU backend

    # scan grouping for hybrid (layers per scanned group between shared-attn calls)
    def derived_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.derived_head_dim()
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        if self.mlp == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn + mlp)
        elif self.family == "moe":
            assert self.moe is not None
            m = self.moe
            expert = 3 * d * m.d_expert if self.mlp == "swiglu" else 2 * d * m.d_expert
            per_layer = attn + m.n_experts * expert + m.n_shared * expert + d * m.n_experts
            total += self.n_layers * per_layer
        elif self.family == "ssm":
            di = self.d_inner
            ns = self.ssm.d_state
            per = d * 2 * di + di * self.ssm.d_conv + di * (2 * ns + 2) + di * d
            total += self.n_layers * per
        elif self.family == "hybrid":
            di = self.d_inner
            ns = self.ssm.d_state
            per = d * 2 * di + di * self.ssm.d_conv + di + di * d + 2 * self.ssm.n_groups * ns * d
            total += self.n_layers * per + (attn + mlp)  # one shared attn block
        elif self.family == "audio":
            total += (self.n_layers + self.encoder_layers) * (attn + mlp)
            total += self.n_layers * attn  # cross-attention
        return total

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        m = self.moe
        expert = 3 * d * m.d_expert if self.mlp == "swiglu" else 2 * d * m.d_expert
        hd = self.derived_head_dim()
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        per_layer = attn + (m.top_k + m.n_shared) * expert + d * m.n_experts
        total = 2 * self.vocab * d + self.n_layers * per_layer
        return total
