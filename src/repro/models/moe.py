"""Mixture-of-Experts FFN with capacity-based dispatch (expert parallel).

Dispatch strategy (pjit-friendly, no shard_map so it composes with the
nodes-vmap federated step):

  1. top-k routing over softmax(router logits);
  2. position-in-expert via a sort-based rank computation (O(T·k log) memory,
     never materialising a (T, E, C) one-hot);
  3. scatter tokens into an (E, C, d) buffer (`mode="drop"` implements
     capacity overflow dropping);
  4. grouped expert einsum 'ecd,edf->ecf' — the expert dim is sharded on the
     "model" mesh axis via the weight shardings, so XLA SPMD turns the
     buffer reshard into all-to-all-class collectives (expert parallelism);
  5. gather back + combine with the top-k gate weights.

A load-balance auxiliary loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding import ctx as shard_ctx
from .config import ModelConfig, MoEConfig
from .layers import init_linear, init_mlp, linear_fwd, mlp_fwd


def init_moe(key, cfg: ModelConfig, dtype: str = "float32") -> dict:
    m = cfg.moe
    d = cfg.d_model
    k_router, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    E, f = m.n_experts, m.d_expert

    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b)) / jnp.sqrt(a)).astype(jnp.dtype(dtype))

    p = {
        "router": init_linear(k_router, d, E, dtype=dtype, scale=0.02),
        "w_gate": ew(ke[0], d, f),
        "w_up": ew(ke[1], d, f),
        "w_down": ew(ke[2], f, d),
    }
    if m.n_shared:
        p["shared"] = init_mlp(k_s, d, f * m.n_shared, kind=cfg.mlp, dtype=dtype)
    return p


def _positions_in_expert(flat_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Rank of each assignment within its expert (sort-based, O(T·k))."""
    Tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((Tk,), jnp.int32).at[order].set(ranks_sorted)


def moe_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xf = x.reshape(T, d)

    logits = linear_fwd(p["router"], xf).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                          # (T, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    gate = gate.astype(x.dtype)

    # Switch load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    C = max(m.min_capacity, int(T * K / E * m.capacity_factor))
    flat_e = idx.reshape(T * K)
    pos = _positions_in_expert(flat_e, E)                         # (T*K,)

    xrep = jnp.repeat(xf, K, axis=0)                              # (T*K, d)
    buf = jnp.zeros((E, C, d), dtype=x.dtype).at[flat_e, pos].add(
        xrep, mode="drop")

    # Pin the scatter output d-sharded FIRST: its backward (a gather from the
    # buf cotangent) then runs shard-locally instead of all-reducing a full
    # (T·K, d) f32 buffer over "model" (§Perf kimi iteration D). The E-shard
    # reshard below is a separate all-to-all-class move.
    buf = shard_ctx.constrain_axis(buf, 2, "model")
    # grouped expert FFN (expert dim sharded on "model" via weight sharding)
    buf = shard_ctx.constrain_axis(buf, 0, "model")
    h_g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h_g) * h_u,
                       p["w_down"].astype(x.dtype))
    # reshard expert-major -> d-sharded BEFORE the combine gather: the
    # reshard is an all-to-all-class move of the (E,C,d) buffer; the gather
    # then runs shard-locally. Without this, XLA lowers the combine as a
    # full (T·K, d) all-reduce over "model" — measured ~36% of the round's
    # collective bytes on kimi-k2 (EXPERIMENTS.md §Perf iteration B).
    y_buf = shard_ctx.constrain_axis(y_buf, 2, "model")

    # gather back; dropped tokens contribute 0. (Constraining out_rep's d to
    # "model" here was tried and REFUTED: no collective change, 2x XLA bytes
    # — see EXPERIMENTS.md §Perf kimi iteration C.)
    keep = (pos < C).astype(x.dtype)
    out_rep = y_buf[flat_e, jnp.minimum(pos, C - 1)] * keep[:, None]
    out = (out_rep.reshape(T, K, d) * gate[..., None]).sum(axis=1)
    out = out.reshape(B, S, d)

    if "shared" in p:
        out = out + mlp_fwd(cfg.mlp, p["shared"], x)
    return out, aux
