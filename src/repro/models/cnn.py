"""The paper's edge model: CNN with 2 convolutional layers + 1 FC layer.

Used for the MNIST/CIFAR-style federated experiments (paper §6.1: "a simple
deep learning model (i.e., CNN with 2 convolutional layers followed by 1
fully connected layer)"). Pure JAX; params are a pytree so every core
mechanism (ALDP, detection, async mixing) applies unchanged.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def init_cnn(key, in_hw: Tuple[int, int] = (28, 28), in_ch: int = 1,
             n_classes: int = 10, c1: int = 16, c2: int = 32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    h, w = in_hw
    # two stride-2 3x3 convs (SAME) halve each spatial dim twice
    fh, fw = -(-h // 4), -(-w // 4)
    return {
        "conv1": {"w": jax.random.normal(k1, (3, 3, in_ch, c1)) * (1.0 / np.sqrt(9 * in_ch)),
                  "b": jnp.zeros((c1,))},
        "conv2": {"w": jax.random.normal(k2, (3, 3, c1, c2)) * (1.0 / np.sqrt(9 * c1)),
                  "b": jnp.zeros((c2,))},
        "fc": {"w": jax.random.normal(k3, (fh * fw * c2, n_classes)) * (1.0 / np.sqrt(fh * fw * c2)),
               "b": jnp.zeros((n_classes,))},
    }


def cnn_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, H, W, C) -> logits (B, n_classes)."""
    def conv(p, h, stride):
        out = jax.lax.conv_general_dilated(
            h, p["w"].astype(h.dtype), window_strides=(stride, stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return out + p["b"].astype(h.dtype)

    h = jax.nn.relu(conv(params["conv1"], x, 2))
    h = jax.nn.relu(conv(params["conv2"], h, 2))
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc"]["w"].astype(h.dtype) + params["fc"]["b"].astype(h.dtype)


def cnn_loss(params: dict, batch: dict) -> Tuple[jnp.ndarray, dict]:
    logits = cnn_forward(params, batch["x"])
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, {"accuracy": acc}


def cnn_accuracy(params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (cnn_forward(params, x).argmax(-1) == y).mean()


def per_class_accuracy(params: dict, x: jnp.ndarray, y: jnp.ndarray,
                       cls: int) -> jnp.ndarray:
    """Accuracy restricted to one class (the paper's 'special task')."""
    pred = cnn_forward(params, x).argmax(-1)
    sel = (y == cls)
    return jnp.where(sel, pred == y, 0).sum() / jnp.maximum(sel.sum(), 1)
