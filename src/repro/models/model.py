"""Unified model builder for all six architecture families.

Public API (all functional, params are pytrees):

  init_params(cfg, key)                      -> params
  forward(params, cfg, batch)                -> (logits, aux_loss)
  loss_fn(params, cfg, batch)                -> (loss, metrics)
  init_cache(cfg, batch, cache_len, dtype)   -> cache
  prefill(params, cfg, batch, cache)         -> (logits, cache)
  decode_step(params, cfg, tokens, cache)    -> (logits, cache)

`batch` is a dict: tokens (B,S) int32, targets (B,S) int32 (optional for
inference), plus family extras: patches (B,P,d) for vlm, frames (B,F,d) for
audio. Layer stacks are scanned (stacked params) for compact HLO; blocks are
rematerialised in training when cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import ctx
from . import attention as attn
from . import ssm
from .config import ModelConfig
from .layers import (apply_rope, embed_fwd, init_embedding, init_mlp,
                     init_norm, linear_fwd, mlp_fwd, mrope_angles, norm_fwd,
                     rope_angles, unembed_fwd)
from .moe import init_moe, moe_fwd


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_transformer_block(key, cfg: ModelConfig, kind: str) -> dict:
    """kind: 'dense' | 'moe' | 'enc' | 'dec_cross'."""
    hd = cfg.derived_head_dim()
    keys = jax.random.split(key, 6)
    p = {
        "norm1": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        "attn": attn.init_attention(keys[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd, cfg.qkv_bias,
                                    cfg.param_dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if kind == "moe":
        p["moe"] = init_moe(keys[1], cfg, cfg.param_dtype)
    else:
        p["mlp"] = init_mlp(keys[1], cfg.d_model, cfg.d_ff, cfg.mlp,
                            cfg.param_dtype)
    if kind == "dec_cross":
        p["norm_x"] = init_norm(cfg.norm, cfg.d_model, cfg.param_dtype)
        p["cross"] = attn.init_attention(keys[2], cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, hd, cfg.qkv_bias,
                                         cfg.param_dtype)
    return p


def _init_mamba_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    init = ssm.init_mamba1 if cfg.ssm.kind == "mamba1" else ssm.init_mamba2
    return {"norm": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mixer": init(k1, cfg, cfg.param_dtype)}


def _ffn(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "moe" in p:
        return moe_fwd(p["moe"], cfg, x)
    return mlp_fwd(cfg.mlp, p["mlp"], x), jnp.zeros((), jnp.float32)


def _transformer_block_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                           angles: Optional[jnp.ndarray], *, causal: bool,
                           window: int, enc_out: Optional[jnp.ndarray] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    hd = cfg.derived_head_dim()
    h = norm_fwd(cfg.norm, p["norm1"], x, cfg.norm_eps)
    q, k, v = attn.qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, hd)
    if angles is not None:
        q, k = apply_rope(q, angles), apply_rope(k, angles)
    if cfg.use_flash and causal:
        from ..kernels.flash_attention import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            window=window).transpose(0, 2, 1, 3)
    else:
        o = attn.attention(q, k, v, causal=causal, window=window,
                           chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    x = x + linear_fwd(p["attn"]["wo"], o.reshape(B, S, -1))
    if enc_out is not None:
        h = norm_fwd(cfg.norm, p["norm_x"], x, cfg.norm_eps)
        q2, _, _ = attn.qkv(p["cross"], h, cfg.n_heads, cfg.n_kv_heads, hd)
        _, k2, v2 = attn.qkv(p["cross"], enc_out, cfg.n_heads, cfg.n_kv_heads, hd)
        o2 = attn.attention(q2, k2, v2, causal=False, window=0,
                            chunk=cfg.attn_chunk)
        x = x + linear_fwd(p["cross"]["wo"], o2.reshape(B, S, -1))
    h = norm_fwd(cfg.norm, p["norm2"], x, cfg.norm_eps)
    y, aux = _ffn(p, cfg, h)
    return x + y, aux


def _mamba_block_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                     state: Optional[dict] = None) -> Tuple[jnp.ndarray, dict]:
    h = norm_fwd(cfg.norm, p["norm"], x, cfg.norm_eps)
    fwd = ssm.mamba1_fwd if cfg.ssm.kind == "mamba1" else ssm.mamba2_fwd
    y, new_state = fwd(p["mixer"], cfg, h, state)
    return x + y, new_state


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def _stacked_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[1], cfg.vocab, cfg.d_model,
                                           cfg.param_dtype)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = _stacked_init(
            lambda k: _init_transformer_block(k, cfg, "dense"), keys[2], cfg.n_layers)
    elif fam == "moe":
        params["blocks"] = _stacked_init(
            lambda k: _init_transformer_block(k, cfg, "moe"), keys[2], cfg.n_layers)
    elif fam == "ssm":
        params["blocks"] = _stacked_init(
            lambda k: _init_mamba_block(k, cfg), keys[2], cfg.n_layers)
    elif fam == "hybrid":
        params["blocks"] = _stacked_init(
            lambda k: _init_mamba_block(k, cfg), keys[2], cfg.n_layers)
        params["shared_attn"] = _init_transformer_block(keys[3], cfg, "dense")
    elif fam == "audio":
        params["blocks"] = _stacked_init(
            lambda k: _init_transformer_block(k, cfg, "dec_cross"), keys[2],
            cfg.n_layers)
        params["encoder"] = {
            "blocks": _stacked_init(
                lambda k: _init_transformer_block(k, cfg, "enc"), keys[4],
                cfg.encoder_layers),
            "final_norm": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        }
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# Position / rope helpers
# ---------------------------------------------------------------------------

def _angles_for(cfg: ModelConfig, positions: jnp.ndarray) -> Optional[jnp.ndarray]:
    if cfg.rope_mode == "none":
        return None
    hd = cfg.derived_head_dim()
    if cfg.rope_mode == "mrope":
        # positions (B, S) text-style -> identical t/h/w sections
        p3 = jnp.stack([positions, positions, positions])
        return mrope_angles(p3, hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, hd, cfg.rope_theta)


def _vlm_angles(cfg: ModelConfig, B: int, P: int, S_text: int) -> jnp.ndarray:
    """M-RoPE ids: patches at t=0 on an (gh, gw) grid, then text linear."""
    gh, gw = cfg.patch_grid
    hd = cfg.derived_head_dim()
    rows = jnp.arange(P) // gw
    cols = jnp.arange(P) % gw
    t_p = jnp.zeros((P,), jnp.int32)
    base = int(max(cfg.patch_grid))
    t_t = base + jnp.arange(S_text)
    pos_t = jnp.concatenate([t_p, t_t])
    pos_h = jnp.concatenate([rows, t_t])
    pos_w = jnp.concatenate([cols, t_t])
    p3 = jnp.stack([pos_t, pos_h, pos_w])[:, None, :].repeat(B, axis=1)
    return mrope_angles(p3, hd, cfg.rope_theta, cfg.mrope_sections)


# ---------------------------------------------------------------------------
# Forward (teacher-forcing / training)
# ---------------------------------------------------------------------------

def _scan_blocks(blocks, body, x, aux0=None, seq_parallel: bool = False):
    aux0 = jnp.zeros((), jnp.float32) if aux0 is None else aux0

    def pin(h):
        h = ctx.constrain_batch(h, 0)
        if seq_parallel:
            h = ctx.constrain_axis(h, 1, "model")
        return h

    def f(carry, p_layer):
        x, aux = carry
        x, a = body(p_layer, x)
        return (pin(x), aux + a), None

    (x, aux), _ = jax.lax.scan(f, (pin(x), aux0), blocks)
    return x, aux


def forward(params: dict, cfg: ModelConfig, batch: dict
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = embed_fwd(params["embed"], tokens, cdt)
    fam = cfg.family
    window = cfg.sliding_window
    enc_out = None
    angles = None

    if fam == "vlm":
        patches = batch["patches"].astype(cdt)
        P = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
        angles = _vlm_angles(cfg, B, P, S_text)
    elif fam == "audio":
        frames = batch["frames"].astype(cdt)
        Fa = frames.shape[1]
        enc_angles = _angles_for(cfg, jnp.arange(Fa)[None].repeat(B, 0))
        enc_body = lambda p, h: _transformer_block_fwd(
            p, cfg, h, enc_angles, causal=False, window=0)
        if cfg.remat:
            enc_body = jax.checkpoint(enc_body)
        enc_out, _ = _scan_blocks(params["encoder"]["blocks"], enc_body, frames)
        enc_out = norm_fwd(cfg.norm, params["encoder"]["final_norm"], enc_out,
                           cfg.norm_eps)
        angles = _angles_for(cfg, jnp.arange(S_text)[None].repeat(B, 0))
    elif fam in ("dense", "moe"):
        angles = _angles_for(cfg, jnp.arange(S_text)[None].repeat(B, 0))

    if fam in ("dense", "moe", "vlm", "audio"):
        body = lambda p, h: _transformer_block_fwd(
            p, cfg, h, angles, causal=True, window=window, enc_out=enc_out)
        if cfg.remat:
            body = jax.checkpoint(body)
        x, aux = _scan_blocks(params["blocks"], body, x,
                              seq_parallel=cfg.seq_parallel)
    elif fam == "ssm":
        body = lambda p, h: (_mamba_block_fwd(p, cfg, h)[0], jnp.zeros((), jnp.float32))
        if cfg.remat:
            body = jax.checkpoint(body)
        x, aux = _scan_blocks(params["blocks"], body, x)
    elif fam == "hybrid":
        x, aux = _hybrid_forward(params, cfg, x)
    else:
        raise ValueError(fam)

    if fam == "vlm":
        x = x[:, -S_text:]
    x = norm_fwd(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_fwd(head, x)
    return logits, aux


def _hybrid_groups(cfg: ModelConfig):
    """[(start, size), ...] with shared attention after every full group."""
    per = cfg.attn_every if cfg.attn_every else cfg.n_layers
    groups = []
    i = 0
    while i < cfg.n_layers:
        size = min(per, cfg.n_layers - i)
        groups.append((i, size))
        i += size
    return groups


def _slice_stack(stack, start: int, size: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size, axis=0),
                        stack)


def _hybrid_forward(params: dict, cfg: ModelConfig, x: jnp.ndarray):
    B, S = x.shape[:2]
    angles = _angles_for(cfg, jnp.arange(S)[None].repeat(B, 0))
    body = lambda p, h: (_mamba_block_fwd(p, cfg, h)[0], jnp.zeros((), jnp.float32))
    if cfg.remat:
        body = jax.checkpoint(body)
    aux = jnp.zeros((), jnp.float32)
    for gi, (start, size) in enumerate(_hybrid_groups(cfg)):
        blocks = _slice_stack(params["blocks"], start, size)
        x, a = _scan_blocks(blocks, body, x)
        aux = aux + a
        if cfg.attn_every and (start + size) % cfg.attn_every == 0:
            x, a2 = _transformer_block_fwd(params["shared_attn"], cfg, x,
                                           angles, causal=True,
                                           window=cfg.sliding_window)
            aux = aux + a2
    return x, aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(params: dict, cfg: ModelConfig, batch: dict
            ) -> Tuple[jnp.ndarray, dict]:
    logits, aux = forward(params, cfg, batch)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    ce = nll.sum() / denom
    aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    loss = ce + aux_w * aux / max(cfg.n_layers, 1)
    acc = (logits.argmax(-1) == targets)
    if mask is not None:
        acc = (acc * mask).sum() / denom
    else:
        acc = acc.mean()
    return loss, {"ce": ce, "aux": aux, "accuracy": acc}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.derived_head_dim()
    fam = cfg.family
    C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}

    def kv_stack(n, length):
        return {
            "k": jnp.zeros((n, batch, length, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((n, batch, length, cfg.n_kv_heads, hd), dtype),
            "idx": jnp.zeros((n,), jnp.int32),
        }

    if fam in ("dense", "moe", "vlm"):
        cache["kv"] = kv_stack(cfg.n_layers, C)
    elif fam == "audio":
        cache["kv"] = kv_stack(cfg.n_layers, C)
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.n_audio_frames,
                            cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.n_audio_frames,
                            cfg.n_kv_heads, hd), dtype),
        }
    elif fam == "ssm":
        st = ssm.init_mamba1_state(cfg, batch)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)
    elif fam == "hybrid":
        st = ssm.init_mamba2_state(cfg, batch)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)
        n_attn = sum(1 for (s, z) in _hybrid_groups(cfg)
                     if cfg.attn_every and (s + z) % cfg.attn_every == 0)
        cache["attn"] = kv_stack(max(n_attn, 1), C)
    return cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _attn_block_with_cache(p, cfg: ModelConfig, x, angles, cache_layer,
                           enc_out=None, cross_cache=None, decode=False):
    """Runs one transformer block, reading/writing the layer KV cache."""
    hd = cfg.derived_head_dim()
    B, S = x.shape[:2]
    h = norm_fwd(cfg.norm, p["norm1"], x, cfg.norm_eps)
    q, k, v = attn.qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads, hd)
    if angles is not None:
        q, k = apply_rope(q, angles), apply_rope(k, angles)
    cache_layer = attn.cache_write(cache_layer, k, v)
    if decode:
        o = attn.decode_attend(q, cache_layer, window=cfg.sliding_window)
    else:
        o = attn.attention(q, k, v, causal=True, window=cfg.sliding_window,
                           chunk=cfg.attn_chunk)
    x = x + linear_fwd(p["attn"]["wo"], o.reshape(B, S, -1))
    if cross_cache is not None:
        h = norm_fwd(cfg.norm, p["norm_x"], x, cfg.norm_eps)
        q2 = linear_fwd(p["cross"]["wq"], h).reshape(B, S, cfg.n_heads, hd)
        kc, vc = cross_cache["k"], cross_cache["v"]
        o2 = attn.decode_attend(
            q2, {"k": kc, "v": vc,
                 "idx": jnp.asarray(kc.shape[1], jnp.int32)}) if decode else \
            attn.attention(q2, kc.astype(x.dtype), vc.astype(x.dtype),
                           causal=False, chunk=cfg.attn_chunk)
        x = x + linear_fwd(p["cross"]["wo"], o2.reshape(B, S, -1))
    h = norm_fwd(cfg.norm, p["norm2"], x, cfg.norm_eps)
    y, _ = _ffn(p, cfg, h)
    return x + y, cache_layer


def _encode_audio(params, cfg: ModelConfig, frames):
    B, Fa = frames.shape[:2]
    enc_angles = _angles_for(cfg, jnp.arange(Fa)[None].repeat(B, 0))
    enc_body = lambda p, h: _transformer_block_fwd(
        p, cfg, h, enc_angles, causal=False, window=0)
    enc_out, _ = _scan_blocks(params["encoder"]["blocks"], enc_body, frames)
    return norm_fwd(cfg.norm, params["encoder"]["final_norm"], enc_out,
                    cfg.norm_eps)


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict
            ) -> Tuple[jnp.ndarray, dict]:
    """Consume the prompt, fill caches, return last-position logits."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = embed_fwd(params["embed"], tokens, cdt)
    fam = cfg.family
    angles = None

    if fam == "vlm":
        patches = batch["patches"].astype(cdt)
        P = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
        angles = _vlm_angles(cfg, B, P, S_text)
    elif fam in ("dense", "moe"):
        angles = _angles_for(cfg, jnp.arange(S_text)[None].repeat(B, 0))
    elif fam == "audio":
        enc_out = _encode_audio(params, cfg, batch["frames"].astype(cdt))
        hd = cfg.derived_head_dim()
        def cross_kv(p_layer):
            _, k2, v2 = attn.qkv(p_layer["cross"], enc_out, cfg.n_heads,
                                 cfg.n_kv_heads, hd)
            return k2, v2
        ks, vs = jax.lax.map(cross_kv, params["blocks"])
        cache["cross"] = {"k": ks.astype(cache["cross"]["k"].dtype),
                          "v": vs.astype(cache["cross"]["v"].dtype)}
        angles = _angles_for(cfg, jnp.arange(S_text)[None].repeat(B, 0))

    S_total = x.shape[1]

    if fam in ("dense", "moe", "vlm", "audio"):
        if fam == "audio":
            def body(h, xs):
                p_layer, kv_layer, cr = xs
                h, kv_layer = _attn_block_with_cache(
                    p_layer, cfg, h, angles, kv_layer,
                    cross_cache=cr, decode=False)
                return h, kv_layer
            x, new_kv = jax.lax.scan(
                body, x, (params["blocks"], cache["kv"], cache["cross"]))
        else:
            def body2(h, xs):
                p_layer, kv_layer = xs
                h, kv_layer = _attn_block_with_cache(
                    p_layer, cfg, h, angles, kv_layer, decode=False)
                return h, kv_layer
            x, new_kv = jax.lax.scan(body2, x, (params["blocks"], cache["kv"]))
        cache["kv"] = new_kv
    elif fam == "ssm":
        def body(h, xs):
            p_layer, st = xs
            h, st = _mamba_block_fwd(p_layer, cfg, h, st)
            return h, st
        x, new_st = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        cache["ssm"] = new_st
    elif fam == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, cache)

    cache["pos"] = cache["pos"] + S_total
    x = norm_fwd(cfg.norm, params["final_norm"], x[:, -1:], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_fwd(head, x), cache


def _hybrid_prefill(params, cfg: ModelConfig, x, cache):
    B, S = x.shape[:2]
    angles = _angles_for(cfg, jnp.arange(S)[None].repeat(B, 0))

    def body(h, xs):
        p_layer, st = xs
        h, st = _mamba_block_fwd(p_layer, cfg, h, st)
        return h, st

    new_ssm = []
    attn_caches = cache["attn"]
    new_attn = []
    ai = 0
    for (start, size) in _hybrid_groups(cfg):
        blocks = _slice_stack(params["blocks"], start, size)
        states = _slice_stack(cache["ssm"], start, size)
        x, st = jax.lax.scan(body, x, (blocks, states))
        new_ssm.append(st)
        if cfg.attn_every and (start + size) % cfg.attn_every == 0:
            kv_layer = jax.tree.map(lambda a: a[ai], attn_caches)
            x, kv_layer = _attn_block_with_cache(
                params["shared_attn"], cfg, x, angles, kv_layer, decode=False)
            new_attn.append(kv_layer)
            ai += 1
    cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm)
    if new_attn:
        cache["attn"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_attn)
    return x, cache


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------

def decode_step(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    """tokens (B, 1) -> (logits (B, 1, V), cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_fwd(params["embed"], tokens, cdt)
    B = x.shape[0]
    fam = cfg.family
    pos = cache["pos"][None].repeat(B, 0)[:, None]                # (B,1)
    if fam == "vlm":
        # text rope position: patches occupy grid positions, text restarts at
        # max(patch_grid) (M-RoPE); cache["pos"] counts patches + text.
        pos = pos - cfg.n_patches + int(max(cfg.patch_grid))
    angles = _angles_for(cfg, pos)

    if fam in ("dense", "moe", "vlm", "audio"):
        cross = cache.get("cross")

        if fam == "audio":
            def body(h, xs):
                p_layer, kv_layer, cr = xs
                h, kv_layer = _attn_block_with_cache(
                    p_layer, cfg, h, angles, kv_layer, cross_cache=cr,
                    decode=True)
                return h, kv_layer
            x, new_kv = jax.lax.scan(body, x,
                                     (params["blocks"], cache["kv"], cross))
        else:
            def body(h, xs):
                p_layer, kv_layer = xs
                h, kv_layer = _attn_block_with_cache(
                    p_layer, cfg, h, angles, kv_layer, decode=True)
                return h, kv_layer
            x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
        cache["kv"] = new_kv
    elif fam == "ssm":
        def body(h, xs):
            p_layer, st = xs
            h, st = _mamba_decode_block(p_layer, cfg, h, st)
            return h, st
        x, new_st = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        cache["ssm"] = new_st
    elif fam == "hybrid":
        x, cache = _hybrid_decode(params, cfg, x, cache, angles)

    cache["pos"] = cache["pos"] + 1
    x = norm_fwd(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_fwd(head, x), cache


def _mamba_decode_block(p, cfg: ModelConfig, x, state):
    h = norm_fwd(cfg.norm, p["norm"], x, cfg.norm_eps)
    dec = ssm.mamba1_decode if cfg.ssm.kind == "mamba1" else ssm.mamba2_decode
    y, new_state = dec(p["mixer"], cfg, h, state)
    return x + y, new_state


def _hybrid_decode(params, cfg: ModelConfig, x, cache, angles):
    def body(h, xs):
        p_layer, st = xs
        h, st = _mamba_decode_block(p_layer, cfg, h, st)
        return h, st

    new_ssm = []
    new_attn = []
    ai = 0
    for (start, size) in _hybrid_groups(cfg):
        blocks = _slice_stack(params["blocks"], start, size)
        states = _slice_stack(cache["ssm"], start, size)
        x, st = jax.lax.scan(body, x, (blocks, states))
        new_ssm.append(st)
        if cfg.attn_every and (start + size) % cfg.attn_every == 0:
            kv_layer = jax.tree.map(lambda a: a[ai], cache["attn"])
            x, kv_layer = _attn_block_with_cache(
                params["shared_attn"], cfg, x, angles, kv_layer, decode=True)
            new_attn.append(kv_layer)
            ai += 1
    cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm)
    if new_attn:
        cache["attn"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_attn)
    return x, cache
