"""Model zoo: unified functional model builder over six architecture families."""
from .config import ModelConfig, MoEConfig, SSMConfig          # noqa: F401
from .model import (decode_step, forward, init_cache, init_params,  # noqa: F401
                    loss_fn, prefill)
