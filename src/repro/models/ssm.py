"""State-space sequence mixers: Mamba1 (selective scan) and Mamba2 (SSD).

Both use a *chunked* scan: the sequence is split into chunks of
``cfg.ssm.chunk``; an outer ``lax.scan`` carries the SSM state between chunks
and the within-chunk recurrence is computed with an associative scan (Mamba1)
or the SSD matmul form (Mamba2). This never materialises the full
(L, d_inner, d_state) tensor, which is what makes 500k-token contexts and
TPU-sized batches lower with bounded memory.

Decode paths maintain a conv ring state and the SSM state — O(1) per token.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.ctx import constrain_batch
from .config import ModelConfig
from .layers import init_linear, linear_fwd, norm_fwd


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                          init_state: jnp.ndarray | None = None) -> jnp.ndarray:
    """x (B, L, D); w (K, D); b (D). Causal depthwise conv along L."""
    K = w.shape[0]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _chunk(x: jnp.ndarray, c: int) -> Tuple[jnp.ndarray, int]:
    """(B, L, ...) -> (n, B, c, ...) with zero padding; returns (chunked, L)."""
    B, L = x.shape[:2]
    n = -(-L // c)
    pad = n * c - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    x = x.reshape((B, n, c) + x.shape[2:])
    return jnp.moveaxis(x, 1, 0), L


def _unchunk(y: jnp.ndarray, L: int) -> jnp.ndarray:
    """(n, B, c, ...) -> (B, L, ...)."""
    y = jnp.moveaxis(y, 0, 1)
    B, n, c = y.shape[:3]
    return y.reshape((B, n * c) + y.shape[3:])[:, :L]


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba-7b): per-(channel,state) selective scan
# ---------------------------------------------------------------------------

def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba1(key, cfg: ModelConfig, dtype: str = "float32") -> dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm.d_state
    K = cfg.ssm.d_conv
    r = dt_rank(cfg)
    keys = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    dt_init = jax.random.uniform(keys[4], (di,), minval=math.log(1e-3), maxval=math.log(1e-1))
    return {
        "in_proj": init_linear(keys[0], d, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(keys[1], (K, di)) / math.sqrt(K)).astype(jnp.dtype(dtype)),
        "conv_b": jnp.zeros((di,), dtype=jnp.dtype(dtype)),
        "x_proj": init_linear(keys[2], di, r + 2 * N, dtype=dtype),
        "dt_proj": {"w": (jax.random.normal(keys[3], (r, di)) * r ** -0.5).astype(jnp.dtype(dtype)),
                    "b": dt_init.astype(jnp.dtype(dtype))},
        "A_log": jnp.log(A).astype(jnp.dtype(dtype)),
        "D": jnp.ones((di,), dtype=jnp.dtype(dtype)),
        "out_proj": init_linear(keys[5], di, d, dtype=dtype),
    }


def _m1_scan_chunk(h0, la, bx):
    """Within-chunk recurrence via associative scan.

    la (B, c, D, N) log decay; bx (B, c, D, N) input term.
    h_t = exp(la_t) * h_{t-1} + bx_t. Returns (h_all (B,c,D,N), h_last).
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, b2 + jnp.exp(a2) * b1

    a_cum, b_cum = jax.lax.associative_scan(combine, (la, bx), axis=1)
    h_all = b_cum + jnp.exp(a_cum) * h0[:, None]
    return h_all, h_all[:, -1]


def mamba1_fwd(p: dict, cfg: ModelConfig, u: jnp.ndarray,
               init_state: dict | None = None):
    """u (B, L, d_model) -> (y (B, L, d_model), final_state)."""
    B, L, _ = u.shape
    di, N = cfg.d_inner, cfg.ssm.d_state
    r = dt_rank(cfg)
    c = cfg.ssm.chunk

    xz = linear_fwd(p["in_proj"], u)
    x_raw, z = jnp.split(xz, 2, axis=-1)
    conv_init = init_state["conv"] if init_state is not None else None
    x = causal_depthwise_conv(x_raw, p["conv_w"], p["conv_b"], conv_init)
    x = jax.nn.silu(x)

    dbc = linear_fwd(p["x_proj"], x)
    dt, Bm, Cm = dbc[..., :r], dbc[..., r:r + N], dbc[..., r + N:]
    dt = jax.nn.softplus(dt @ p["dt_proj"]["w"].astype(dt.dtype)
                         + p["dt_proj"]["b"].astype(dt.dtype))          # (B,L,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                          # (di,N)

    xs, _ = _chunk(x, c)
    dts, _ = _chunk(dt, c)
    Bs, _ = _chunk(Bm, c)
    Cs, _ = _chunk(Cm, c)

    h0 = (init_state["h"] if init_state is not None
          else jnp.zeros((B, di, N), dtype=jnp.float32))

    sdt = jnp.dtype(cfg.ssm.scan_dtype)

    def body(h, inp):
        xc, dtc, Bc, Cc = inp
        dtf = dtc.astype(jnp.float32)
        la = (dtf[..., None] * A).astype(sdt)                    # (B,c,di,N)
        bx = ((dtf * xc.astype(jnp.float32))[..., None]
              * Bc.astype(jnp.float32)[:, :, None, :]).astype(sdt)
        h_all, h_last = _m1_scan_chunk(h.astype(sdt), la, bx)
        yc = jnp.einsum("bcdn,bcn->bcd", h_all, Cc.astype(sdt))
        return constrain_batch(h_last.astype(jnp.float32), 0), yc.astype(u.dtype)

    xs = constrain_batch(xs, 1)
    dts = constrain_batch(dts, 1)
    Bs = constrain_batch(Bs, 1)
    Cs = constrain_batch(Cs, 1)
    h_last, ys = jax.lax.scan(body, constrain_batch(h0, 0), (xs, dts, Bs, Cs))
    y = _unchunk(ys, L) + x * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = linear_fwd(p["out_proj"], y)
    if conv_init is not None:
        x_hist = jnp.concatenate([conv_init.astype(x_raw.dtype), x_raw], axis=1)
    else:
        x_hist = jnp.pad(x_raw, ((0, 0), (cfg.ssm.d_conv - 1, 0), (0, 0)))
    state = {"h": h_last, "conv": x_hist[:, -(cfg.ssm.d_conv - 1):]}
    return out, state


def mamba1_decode(p: dict, cfg: ModelConfig, u: jnp.ndarray, state: dict):
    """u (B, 1, d_model) one token; state {'h': (B,di,N), 'conv': (B,K-1,di)}."""
    di, N = cfg.d_inner, cfg.ssm.d_state
    r = dt_rank(cfg)
    xz = linear_fwd(p["in_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)                              # (B,1,di)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), x], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bkd,kd->bd", conv_in, w)[:, None] + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    dbc = linear_fwd(p["x_proj"], xc)
    dt, Bm, Cm = dbc[..., :r], dbc[..., r:r + N], dbc[..., r + N:]
    dt = jax.nn.softplus(dt @ p["dt_proj"]["w"].astype(dt.dtype)
                         + p["dt_proj"]["b"].astype(dt.dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                            # (B,di)
    a = jnp.exp(dtf[..., None] * A)                               # (B,di,N)
    bx = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = a * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))[:, None].astype(u.dtype)
    y = y + xc * p["D"].astype(xc.dtype)
    y = y * jax.nn.silu(z)
    out = linear_fwd(p["out_proj"], y)
    return out, {"h": h, "conv": conv_in[:, 1:]}


def init_mamba1_state(cfg: ModelConfig, batch: int) -> dict:
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.ssm.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, cfg.d_inner), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba2 (zamba2): scalar-per-head decay, SSD chunked matmul form
# ---------------------------------------------------------------------------

def m2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    di = cfg.d_inner
    P = cfg.ssm.head_dim
    H = di // P
    return di, P, H, cfg.ssm.d_state


def init_mamba2(key, cfg: ModelConfig, dtype: str = "float32") -> dict:
    d = cfg.d_model
    di, P, H, N = m2_dims(cfg)
    G = cfg.ssm.n_groups
    K = cfg.ssm.d_conv
    conv_dim = di + 2 * G * N
    keys = jax.random.split(key, 4)
    dt_init = jax.random.uniform(keys[2], (H,), minval=math.log(1e-3), maxval=math.log(1e-1))
    return {
        "in_proj": init_linear(keys[0], d, 2 * di + 2 * G * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(keys[1], (K, conv_dim)) / math.sqrt(K)).astype(jnp.dtype(dtype)),
        "conv_b": jnp.zeros((conv_dim,), dtype=jnp.dtype(dtype)),
        "A_log": jnp.zeros((H,), dtype=jnp.dtype(dtype)),
        "D": jnp.ones((H,), dtype=jnp.dtype(dtype)),
        "dt_bias": dt_init.astype(jnp.dtype(dtype)),
        "norm_scale": jnp.ones((di,), dtype=jnp.dtype(dtype)),
        "out_proj": init_linear(keys[3], di, d, dtype=dtype),
    }


def _m2_split(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, P, H, N = m2_dims(cfg)
    G = cfg.ssm.n_groups
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xbc, dt


def mamba2_fwd(p: dict, cfg: ModelConfig, u: jnp.ndarray,
               init_state: dict | None = None):
    """u (B, L, d_model) -> (y, final_state). SSD chunked algorithm."""
    Bsz, L, _ = u.shape
    di, P, H, N = m2_dims(cfg)
    G = cfg.ssm.n_groups
    c = cfg.ssm.chunk

    zxbcdt = linear_fwd(p["in_proj"], u)
    z, xbc_raw, dt = _m2_split(cfg, zxbcdt)
    conv_init = init_state["conv"] if init_state is not None else None
    xbc = jax.nn.silu(causal_depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"], conv_init))
    x = xbc[..., :di].reshape(Bsz, L, H, P)
    Bm = xbc[..., di:di + G * N].reshape(Bsz, L, G, N)
    Cm = xbc[..., di + G * N:].reshape(Bsz, L, G, N)
    # broadcast groups to heads
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)                              # (B,L,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))      # (B,L,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)

    xs, _ = _chunk(x, c)
    dts, _ = _chunk(dt, c)
    Bs, _ = _chunk(Bh, c)
    Cs, _ = _chunk(Ch, c)

    h0 = (init_state["h"] if init_state is not None
          else jnp.zeros((Bsz, H, P, N), dtype=jnp.float32))

    tri = jnp.tril(jnp.ones((c, c), dtype=bool))

    def body(h, inp):
        xc, dtc, Bc, Cc = inp                                     # (B,c,H,P),(B,c,H),(B,c,H,N)
        dtf = dtc.astype(jnp.float32)
        la = dtf * A                                              # (B,c,H) log-decay per step
        Lcum = jnp.cumsum(la, axis=1)                             # (B,c,H)
        # intra-chunk (diagonal) term
        decay = jnp.exp(Lcum[:, :, None] - Lcum[:, None, :])      # (B,c,c,H) t,s
        scores = jnp.einsum("bthn,bshn->btsh", Cc.astype(jnp.float32),
                            Bc.astype(jnp.float32))
        M = jnp.where(tri[None, :, :, None], decay * scores, 0.0)
        dx = dtf[..., None] * xc.astype(jnp.float32)              # (B,c,H,P)
        y_diag = jnp.einsum("btsh,bshp->bthp", M, dx)
        # inter-chunk: contribution of carried state
        y_prev = jnp.einsum("bthn,bhpn->bthp", Cc.astype(jnp.float32) *
                            jnp.exp(Lcum)[..., None], h)
        # state update
        tail = jnp.exp(Lcum[:, -1:, :] - Lcum)                    # (B,c,H)
        h_new = jnp.exp(Lcum[:, -1])[..., None, None] * h + \
            jnp.einsum("bshn,bshp->bhpn", Bc.astype(jnp.float32) * tail[..., None], dx)
        return constrain_batch(h_new, 0), (y_diag + y_prev).astype(u.dtype)

    xs = constrain_batch(xs, 1)
    dts = constrain_batch(dts, 1)
    Bs = constrain_batch(Bs, 1)
    Cs = constrain_batch(Cs, 1)
    h_last, ys = jax.lax.scan(body, constrain_batch(h0, 0), (xs, dts, Bs, Cs))
    y = _unchunk(ys, L)                                           # (B,L,H,P)
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, L, di)
    # gated RMSNorm
    y = y * jax.nn.silu(z)
    y = norm_fwd("rmsnorm", {"scale": p["norm_scale"]}, y, cfg.norm_eps)
    out = linear_fwd(p["out_proj"], y)
    # conv state tail (pre-activation xbc)
    if conv_init is not None:
        xbc_hist = jnp.concatenate([conv_init.astype(xbc_raw.dtype), xbc_raw], axis=1)
    else:
        xbc_hist = jnp.pad(xbc_raw, ((0, 0), (cfg.ssm.d_conv - 1, 0), (0, 0)))
    state = {"h": h_last, "conv": xbc_hist[:, -(cfg.ssm.d_conv - 1):]}
    return out, state


def mamba2_decode(p: dict, cfg: ModelConfig, u: jnp.ndarray, state: dict):
    """One-token decode. u (B,1,d); state {'h': (B,H,P,N), 'conv': (B,K-1,conv_dim)}."""
    Bsz = u.shape[0]
    di, P, H, N = m2_dims(cfg)
    G = cfg.ssm.n_groups
    zxbcdt = linear_fwd(p["in_proj"], u)
    z, xbc, dt = _m2_split(cfg, zxbcdt)
    conv_in = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    w = p["conv_w"].astype(xbc.dtype)
    xbc = jnp.einsum("bkd,kd->bd", conv_in, w)[:, None] + p["conv_b"].astype(xbc.dtype)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :di].reshape(Bsz, H, P)
    Bm = xbc[..., di:di + G * N].reshape(Bsz, G, N)
    Cm = xbc[..., di + G * N:].reshape(Bsz, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)          # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"].astype(dt.dtype)).astype(jnp.float32)  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                           # (B,H)
    dx = dt[..., None] * x.astype(jnp.float32)                    # (B,H,P)
    h = a[..., None, None] * state["h"] + jnp.einsum("bhn,bhp->bhpn", Bh, dx)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = norm_fwd("rmsnorm", {"scale": p["norm_scale"]}, y, cfg.norm_eps)
    out = linear_fwd(p["out_proj"], y)
    return out, {"h": h, "conv": conv_in[:, 1:]}


def init_mamba2_state(cfg: ModelConfig, batch: int) -> dict:
    di, P, H, N = m2_dims(cfg)
    G = cfg.ssm.n_groups
    conv_dim = di + 2 * G * N
    return {"h": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, conv_dim), jnp.float32)}
