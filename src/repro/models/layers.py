"""Primitive layers: linear, norms, rotary embeddings, MLPs.

All layers are functional: ``init_*`` returns a param pytree (nested dict of
jnp arrays), ``*_fwd`` applies it. Params are created in ``param_dtype`` and
cast to ``compute_dtype`` inside forward functions by the caller.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype: str = "float32", scale: Optional[float] = None) -> dict:
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(_dtype(dtype))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=_dtype(dtype))
    return p


def linear_fwd(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype: str = "float32") -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype=_dtype(dtype))}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype=_dtype(dtype)),
                "bias": jnp.zeros((d,), dtype=_dtype(dtype))}
    if kind == "nonparam_ln":   # OLMo-style non-parametric LayerNorm
        return {}
    raise ValueError(f"unknown norm kind {kind!r}")


def norm_fwd(kind: str, p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def mrope_angles(positions3: jnp.ndarray, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]) -> jnp.ndarray:
    """M-RoPE: positions3 (3, B, S) (t, h, w ids) -> (B, S, head_dim//2).

    The half-dim is split into contiguous sections rotated by the t/h/w
    position ids respectively (Qwen2-VL §2.1).
    """
    half = head_dim // 2
    tot = sum(sections)
    sizes = [half * s // tot for s in sections]
    sizes[0] += half - sum(sizes)
    inv = rope_freqs(head_dim, theta)
    ang_t = positions3[0][..., None].astype(jnp.float32) * inv
    ang_h = positions3[1][..., None].astype(jnp.float32) * inv
    ang_w = positions3[2][..., None].astype(jnp.float32) * inv
    s0, s1, s2 = sizes
    return jnp.concatenate(
        [ang_t[..., :s0], ang_h[..., s0:s0 + s1], ang_w[..., s0 + s1:]], axis=-1)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, D), angles (B, S, D//2) or (S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, kind: str = "swiglu",
             dtype: str = "float32") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": init_linear(k1, d, d_ff, dtype=dtype),
            "w_up": init_linear(k2, d, d_ff, dtype=dtype),
            "w_down": init_linear(k3, d_ff, d, dtype=dtype),
        }
    return {
        "w_up": init_linear(k1, d, d_ff, dtype=dtype),
        "w_down": init_linear(k2, d_ff, d, dtype=dtype),
    }


def mlp_fwd(kind: str, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "swiglu":
        g = linear_fwd(p["w_gate"], x)
        u = linear_fwd(p["w_up"], x)
        return linear_fwd(p["w_down"], jax.nn.silu(g) * u)
    h = jax.nn.gelu(linear_fwd(p["w_up"], x))
    return linear_fwd(p["w_down"], h)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype: str = "float32") -> dict:
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"w": w.astype(_dtype(dtype))}


def embed_fwd(p: dict, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(p["w"], tokens, axis=0).astype(compute_dtype)


def unembed_fwd(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype).T
