from .rules import (batch_pspec, cache_pspecs, fed_batch_pspec,   # noqa: F401
                    param_pspecs, shardings_for)
