"""Mesh context for in-model sharding constraints.

XLA's sharding propagation can drop the batch sharding of while-loop carried
tensors (observed: the q-chunk attention scan replicated (B, ...) operands
across the whole mesh, inflating per-device flops ~200×). The launchers
install the active mesh + logical axis mapping here; model code pins batch
dims at scan boundaries with :func:`constrain`. Outside a mesh context (unit
tests, single-device runs) every call is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _get() -> Tuple[Optional[Mesh], Tuple[str, ...]]:
    return (getattr(_state, "mesh", None), getattr(_state, "dp", ()))


@contextlib.contextmanager
def mesh_context(mesh: Mesh, dp_axes: Sequence[str]):
    """Install `mesh` and the data-parallel axis names (("data",) or
    ("pod","data")) for the duration of a lowering/call."""
    old = _get()
    _state.mesh, _state.dp = mesh, tuple(a for a in dp_axes if a in mesh.shape)
    try:
        yield
    finally:
        _state.mesh, _state.dp = old


@contextlib.contextmanager
def suspended():
    """Disable *data-parallel* constraints inside a scope — used by the fed
    step's nodes-vmap, where the node axis is handled by
    vmap(spmd_axis_name=...) and an inner P(dp, ...) constraint would
    conflict. Model-axis constraints (constrain_axis) stay active: the
    "model" axis is never a vmap spmd axis."""
    old_dp = getattr(_state, "dp", ())
    _state.dp = ()
    try:
        yield
    finally:
        _state.dp = old_dp


def constrain_axis(x, dim: int, axis: str = "model"):
    """Pin dimension `dim` of x to mesh axis `axis` (replicate other dims as
    far as the partitioner wants). No-op outside a mesh context or when the
    dim does not divide. Used to steer reshards (e.g. the MoE combine) toward
    all-to-all-class layouts instead of full-buffer all-reduces."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None or axis not in mesh.shape:
        return x
    n = mesh.shape[axis]
    if not hasattr(x, "ndim") or x.ndim <= dim or x.shape[dim] % n != 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_batch(x, batch_dim: int = 0):
    """Pin dimension `batch_dim` of x (or of every leaf of a pytree) to the
    data-parallel axes; other dims left to the partitioner."""
    mesh, dp = _get()
    if mesh is None or not dp:
        return x
    import numpy as np
    n = int(np.prod([mesh.shape[a] for a in dp]))

    def one(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim <= batch_dim:
            return leaf
        if leaf.shape[batch_dim] % n != 0:
            return leaf
        spec = [None] * leaf.ndim
        spec[batch_dim] = dp
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*spec)))

    return jax.tree.map(one, x)
