"""PartitionSpec rules: FSDP over ("pod","data"), tensor/expert over "model".

Rules are name-based over the param-tree paths produced by
``repro.models.init_params``. Every rule respects divisibility: a dim is only
sharded on an axis whose size divides it (XLA supports uneven shards but even
shards keep memory_analysis honest); otherwise we fall back to the next
candidate axis or replicate.

Stacked (scanned) block params carry a leading layer dim which is never
sharded.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes) -> Optional[object]:
    """Return `axes` if dim divides evenly over them, else None."""
    return axes if axes is not None and dim % _axis_size(mesh, axes) == 0 else None


def _key_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _leaf_spec(mesh: Mesh, key: str, shape: Tuple[int, ...], fsdp,
               stacked: bool) -> P:
    """Choose a PartitionSpec for one parameter leaf."""
    lead = ("_L",) if stacked else ()          # placeholder, stripped below
    nd = len(shape) - len(lead)
    dims = shape[len(lead):]

    def spec(*entries):
        return P(*( (None,) * len(lead) + entries ))

    model = _fit(mesh, dims[-1] if nd else 1, "model")

    if nd == 0 or re.search(r"norm|bias|/b$|A_log|^D$|/D$|dt_bias|idx", key):
        return spec(*(None,) * nd)

    if re.search(r"(embed|unembed)/w$", key):
        return spec(_fit(mesh, dims[0], "model"), _fit(mesh, dims[1], fsdp))

    if re.search(r"router/w$", key):
        return spec(_fit(mesh, dims[0], fsdp), None)

    if re.search(r"(w_gate|w_up)$", key) and nd == 3:   # experts (E, d, f)
        return spec(_fit(mesh, dims[0], "model"), _fit(mesh, dims[1], fsdp), None)
    if re.search(r"w_down$", key) and nd == 3:          # experts (E, f, d)
        return spec(_fit(mesh, dims[0], "model"), None, _fit(mesh, dims[2], fsdp))

    if re.search(r"(wq|wk|wv|w_gate|w_up|in_proj|x_proj|dt_proj)/w$", key) and nd == 2:
        return spec(_fit(mesh, dims[0], fsdp), _fit(mesh, dims[1], "model"))
    if re.search(r"(wo|w_down|out_proj)/w$", key) and nd == 2:
        return spec(_fit(mesh, dims[0], "model"), _fit(mesh, dims[1], fsdp))
    if re.search(r"conv_w$", key):
        return spec(None, _fit(mesh, dims[1], "model"))
    if re.search(r"A_log|norm_scale", key):
        return spec(*(None,) * nd)
    if re.search(r"conv1|conv2|fc", key):               # paper CNN: replicate
        return spec(*(None,) * nd)

    # default: shard last dim on model, first on fsdp when divisible
    if nd >= 2:
        return spec(_fit(mesh, dims[0], fsdp),
                    *(None,) * (nd - 2), model)
    return spec(_fit(mesh, dims[0], "model"))


_STACKED_RE = re.compile(r"^(blocks|encoder/blocks)/")


def param_pspecs(mesh: Mesh, params_shape, fsdp=("data",)):
    """Pytree of PartitionSpec matching a params(-shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        key = _key_str(path)
        stacked = bool(_STACKED_RE.match(key))
        specs.append(_leaf_spec(mesh, key, tuple(leaf.shape), fsdp, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch_shape, dp=("data",)):
    """Plain step: leading batch dim over the data(+pod) axes."""
    dp_axes = tuple(a for a in (dp if not isinstance(dp, str) else (dp,))
                    if a in mesh.shape)

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if leaf.shape[0] % _axis_size(mesh, dp_axes) == 0:
            return P(dp_axes, *(None,) * (nd - 1))
        return P(*(None,) * nd)

    return jax.tree.map(one, batch_shape)


def fed_batch_pspec(mesh: Mesh, batch_shape, node_axes=("pod", "data")):
    """Fed step: leading NODE dim over (pod, data)."""
    axes = tuple(a for a in node_axes if a in mesh.shape)

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(axes, *(None,) * (nd - 1))

    return jax.tree.map(one, batch_shape)


def cache_pspecs(mesh: Mesh, cache_shape, dp=("data",)):
    """KV/SSM caches: batch dim over data(+pod); kv-heads on model when they
    divide, otherwise the cache length; ssm states shard d_inner on model."""
    dp_axes = tuple(a for a in (dp if not isinstance(dp, str) else (dp,))
                    if a in mesh.shape)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = []
    for path, leaf in flat:
        key = _key_str(path)
        shp = tuple(leaf.shape)
        nd = len(shp)
        if nd == 0 or key.endswith("idx") or key == "pos":
            specs.append(P(*(None,) * nd))
            continue
        if re.search(r"(kv|cross|attn)/(k|v)$", key):
            # (L, B, C, KV, hd)
            b = dp_axes if shp[1] % _axis_size(mesh, dp_axes) == 0 else None
            kv_m = _fit(mesh, shp[3], "model")
            c_m = _fit(mesh, shp[2], "model") if kv_m is None else None
            specs.append(P(None, b, c_m, kv_m, None))
        elif re.search(r"ssm/h$", key):
            # mamba1 (L,B,di,N) / mamba2 (L,B,H,P,N)
            b = dp_axes if shp[1] % _axis_size(mesh, dp_axes) == 0 else None
            m = _fit(mesh, shp[2], "model")
            specs.append(P(None, b, m, *(None,) * (nd - 3)))
        elif re.search(r"ssm/conv$", key):
            # (L,B,K-1,conv_dim)
            b = dp_axes if shp[1] % _axis_size(mesh, dp_axes) == 0 else None
            m = _fit(mesh, shp[3], "model")
            specs.append(P(None, b, None, m))
        else:
            specs.append(P(*(None,) * nd))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings_for(mesh: Mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
