"""Pure-JAX optimizers (no optax in the container).

Each optimizer has ``init(params) -> state`` and
``update(params, grads, state) -> (params, state)``; states are pytrees so
they shard/checkpoint like params. SGD is the paper's FedSGD (stateless —
which is also what makes trillion-param FSDP training fit, see DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SGD:
    lr: float = 1e-2

    def init(self, params):
        return ()

    def update(self, params, grads, state) -> Tuple[object, object]:
        new = jax.tree.map(
            lambda p, g: (p - self.lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
        return new, state


@dataclass(frozen=True)
class Momentum:
    lr: float = 1e-2
    beta: float = 0.9

    def init(self, params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(self, params, grads, state):
        m = jax.tree.map(lambda m_, g: self.beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        new = jax.tree.map(lambda p, m_: (p - self.lr * m_).astype(p.dtype),
                           params, m)
        return new, {"m": m}


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = self.lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            if self.weight_decay:
                step = step + self.lr * self.weight_decay * p.astype(jnp.float32)
            return (p - step).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v, "t": t}


def make_optimizer(name: str, lr: float, **kw):
    name = name.lower()
    if name == "sgd":
        return SGD(lr=lr)
    if name == "momentum":
        return Momentum(lr=lr, **kw)
    if name == "adamw":
        return AdamW(lr=lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
