from .optimizers import SGD, AdamW, Momentum, make_optimizer   # noqa: F401
