"""`repro.net`: byte-accurate wire codecs + virtual-time link simulation.

Three layers (see ISSUE/README "Network simulation"):

  * `codecs`  — encode a DGC-sparsified, ALDP-noised update to an actual
    byte payload (``dense_f32`` / ``sparse_coo`` / ``sparse_bitpack`` with
    a quantized-value variant), exact decode round-trips, and the
    node-batched `batched_encoded_bytes` accounting path
    (`kernels.wire_bytes` Pallas pass or vectorized jnp fallback);
  * `link`    — per-node bandwidth/latency/jitter/packet-loss drawn from
    declarative `LinkProfile` distributions plus optional shared-uplink
    contention, producing per-upload transfer times in virtual seconds;
  * `bridge`  — `NetSim`, the object the fleet engines hold: pre-flight
    `draw` feeds the engines' node clocks, post-flight `commit` streams
    exact encoded bytes into a `NetTrace` that replaces the analytic
    comm accounting in `RunReport`.

Enabled per experiment through `api.NetworkSpec`; with the spec at its
defaults nothing here runs and the engines keep their analytic model.
"""
from .bridge import (NetSim, NetTrace, UploadDraw,  # noqa: F401
                     netsim_from_network)
from .codecs import (CODEC_NAMES, Codec, DenseF32, SparseBitpack,  # noqa: F401
                     SparseCoo, WireMessage, analytic_upload_bytes,
                     batched_encoded_bytes, count_nnz, get_codec,
                     index_bits)
from .link import (LinkProfile, draw_transfer,  # noqa: F401
                   draw_transfer_batch, materialize_bandwidth)
