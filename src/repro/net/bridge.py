"""Engine bridge: per-upload transfer times + the NetTrace byte stream.

`NetSim` is what the fleet engines hold when a `NetworkSpec` enables the
network subsystem.  The handshake per round/window is two-phase, matching
the engines' host/device split:

  1. ``draw(nodes)`` — *before* the device program runs: sample each
     upload's virtual transfer time (codec nominal payload size + the
     `LinkProfile`'s stochastic jitter/loss/contention) so the times can
     feed the jitted clock updates / arrival composition;
  2. ``commit(draw, nnz)`` — *after* the program returns the measured
     per-upload nonzero counts: resolve exact encoded byte counts through
     the codec and append them to the `NetTrace`.

The transfer simulation uses the codec's *nominal* payload size (the
analytic nonzero count for the configured sparsity — static per run,
needed pre-flight); the byte *accounting* is exact per upload.  The two
differ only by DGC quantile tie-breaking, a sub-percent effect on
per-upload times and zero effect on reported bytes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..obs import SECONDS_EDGES, get_tracer
from .codecs import Codec, get_codec
from .link import LinkProfile, draw_transfer_batch, materialize_bandwidth


@dataclass
class UploadDraw:
    """One batch of pre-flight transfer draws (a window/round's uploads)."""
    nodes: np.ndarray           # (U,) int node ids
    seqs: np.ndarray            # (U,) int per-node upload sequence numbers
    transfer_s: np.ndarray      # (U,) float64 virtual transfer times
    overhead_bytes: np.ndarray  # (U,) float64 retransmitted bytes
    retransmits: np.ndarray     # (U,) int retransmitted packets


@dataclass
class NetTrace:
    """The accounting stream: exact encoded bytes per committed upload.

    Per-upload columns stay host-side numpy lists (cheap at simulation
    scale); `summary` reduces them to the totals `RunReport` carries.
    """
    codec: str
    nodes: List[int] = field(default_factory=list)
    seqs: List[int] = field(default_factory=list)
    nnz: List[int] = field(default_factory=list)
    encoded_bytes: List[int] = field(default_factory=list)
    wire_bytes: List[float] = field(default_factory=list)
    transfer_s: List[float] = field(default_factory=list)
    retransmits: List[int] = field(default_factory=list)

    @property
    def n_uploads(self) -> int:
        return len(self.nodes)

    @property
    def total_encoded_bytes(self) -> float:
        return float(np.sum(self.encoded_bytes)) if self.nodes else 0.0

    def summary(self) -> Dict:
        return {
            "codec": self.codec,
            "n_uploads": self.n_uploads,
            "encoded_bytes": self.total_encoded_bytes,
            "wire_bytes": (float(np.sum(self.wire_bytes))
                           if self.nodes else 0.0),
            "transfer_s": (float(np.sum(self.transfer_s))
                           if self.nodes else 0.0),
            "retransmits": int(np.sum(self.retransmits))
            if self.nodes else 0,
        }


class NetSim:
    """Per-fleet network simulator: codec + materialized links + trace.

    Args:
      codec: a `codecs.Codec` (or registry name).
      link: the declarative `LinkProfile`.
      bandwidth_bps: (N,) per-node base uplink rates (the fleet's
        `NodeProfile.bandwidth_bps`) — `link.bandwidth_sigma` scales them
        lognormally per node at construction.
      n_params: model size (codec byte formulas need the index width).
      sparsify_ratio: the DGC keep fraction — sets the nominal nonzero
        count the pre-flight transfer draws assume.
      seed: root of the counter-based per-upload PRNG chain.
      tracer: an `obs.Tracer` for per-upload link events/metrics; defaults
        to the process-global tracer (a no-op unless a run installed one).
    """

    def __init__(self, codec, link: LinkProfile, bandwidth_bps: np.ndarray,
                 n_params: int, sparsify_ratio: float = 1.0, seed: int = 0,
                 tracer=None):
        self.codec: Codec = (get_codec(codec) if isinstance(codec, str)
                             else codec)
        link.validate()
        self.link = link
        self.seed = int(seed)
        self.n_params = int(n_params)
        self.eff_bandwidth_bps = materialize_bandwidth(
            bandwidth_bps, link.bandwidth_sigma, seed)
        self.nominal_nnz = (int(n_params) if sparsify_ratio >= 1.0
                            else int(n_params * sparsify_ratio))
        self.nominal_payload_bytes = int(
            np.asarray(self.codec.nbytes(self.nominal_nnz, self.n_params)))
        self._counters = np.zeros(self.eff_bandwidth_bps.shape[0], np.int64)
        self.trace = NetTrace(codec=self.codec.describe())
        self._tracer = tracer
        # optional per-node rate multiplier in (0, 1], set per round/window
        # by repro.sim traffic traces (diurnal load, flash crowds); None is
        # the stationary default and bit-identical to the pre-sim behaviour
        self.rate_scale: Optional[np.ndarray] = None

    @property
    def tracer(self):
        return self._tracer if self._tracer is not None else get_tracer()

    # -- phase 1: pre-flight transfer times ---------------------------------
    def draw(self, nodes: np.ndarray,
             extra_concurrency: int = 0) -> UploadDraw:
        """Sample transfer times for one batch of concurrent uploads and
        advance each node's upload counter.  Concurrency for the shared-
        uplink cap is the batch size plus ``extra_concurrency`` — flood
        uploads contending for the shared uplink without being real model
        uploads (the DDoS flash-traffic attack injects its flows here).

        Stochastic links are drawn through the batched counter-based
        (seed, node, seq) hash stream in `link.draw_transfer_batch` — one
        vectorized expression per batch, bit-identical to drawing each
        upload alone (the determinism contract, property-tested)."""
        nodes = np.asarray(nodes, np.int64)   # unique per batch (one window/
        u = nodes.size                        # cohort row set per draw)
        conc = u + max(0, int(extra_concurrency))
        seqs = self._counters[nodes].copy()
        np.add.at(self._counters, nodes, 1)
        link = self.link
        eff_bw = self.eff_bandwidth_bps[nodes]
        if self.rate_scale is not None:
            # traffic-trace throttle (a pure function of virtual time, so
            # checkpoint restores recompute the identical scale)
            eff_bw = eff_bw * np.asarray(self.rate_scale,
                                         np.float64)[nodes]
        if link.loss_prob == 0.0 and link.jitter_s == 0.0:
            bw = eff_bw
            if link.shared_uplink_bps > 0.0:
                bw = np.minimum(bw, link.shared_uplink_bps / max(1, conc))
            transfer = (link.latency_s
                        + float(self.nominal_payload_bytes) / bw)
            return UploadDraw(nodes=nodes, seqs=seqs, transfer_s=transfer,
                              overhead_bytes=np.zeros(u),
                              retransmits=np.zeros(u, np.int64))
        transfer, overhead, retrans = draw_transfer_batch(
            link, self.nominal_payload_bytes, eff_bw,
            self.seed, nodes, seqs, concurrency=conc)
        return UploadDraw(nodes=nodes, seqs=seqs, transfer_s=transfer,
                          overhead_bytes=overhead, retransmits=retrans)

    # -- phase 2: exact byte accounting -------------------------------------
    def commit(self, draw: UploadDraw, nnz: np.ndarray,
               ctx: Optional[Dict] = None) -> np.ndarray:
        """Resolve the batch's exact encoded bytes from the measured
        nonzero counts and append every upload to the trace.  Returns the
        (U,) encoded byte counts.  ``ctx`` tags (e.g. ``{"round": r}`` /
        ``{"window": w}`` from the engines) are merged into each
        ``net.upload`` instant so trace consumers can key byte accounting
        by record without correlating streams."""
        nnz = np.asarray(nnz, np.int64)
        if nnz.shape != draw.nodes.shape:
            raise ValueError(f"commit: nnz shape {nnz.shape} != draw batch "
                             f"{draw.nodes.shape}")
        enc = np.asarray(self.codec.nbytes(nnz, self.n_params), np.int64)
        t = self.trace
        t.nodes.extend(int(x) for x in draw.nodes)
        t.seqs.extend(int(x) for x in draw.seqs)
        t.nnz.extend(int(x) for x in nnz)
        t.encoded_bytes.extend(int(x) for x in enc)
        t.wire_bytes.extend(float(e + o) for e, o in
                            zip(enc, draw.overhead_bytes))
        t.transfer_s.extend(float(x) for x in draw.transfer_s)
        t.retransmits.extend(int(x) for x in draw.retransmits)
        tr = self.tracer
        if tr.enabled:
            extra = ctx or {}
            for i in range(draw.nodes.size):
                tr.instant("net.upload", node=int(draw.nodes[i]),
                           seq=int(draw.seqs[i]), nnz=int(nnz[i]),
                           encoded_bytes=int(enc[i]),
                           transfer_s=float(draw.transfer_s[i]),
                           retransmits=int(draw.retransmits[i]), **extra)
            m = tr.metrics
            m.counter("net.uploads").inc(draw.nodes.size)
            m.counter("net.encoded_bytes").inc(float(np.sum(enc)))
            m.counter("net.retransmits").inc(
                float(np.sum(draw.retransmits)))
            h = m.histogram("net.transfer_s", SECONDS_EDGES)
            for x in draw.transfer_s:
                h.observe(float(x))
        return enc

    def summary(self) -> Dict:
        return self.trace.summary()

    # -- checkpoint/resume (repro.sim) --------------------------------------
    _TRACE_COLUMNS = ("nodes", "seqs", "nnz", "encoded_bytes", "wire_bytes",
                      "transfer_s", "retransmits")

    def export_sim_state(self):
        """(counters array, trace columns): everything a bit-exact resume
        needs beyond the constructor arguments — the per-node upload
        counters drive the (seed, node, seq) PRNG stream, and the trace
        columns rebuild the byte accounting (JSON floats round-trip
        exactly, so restored summaries match to the bit)."""
        columns = {name: list(getattr(self.trace, name))
                   for name in self._TRACE_COLUMNS}
        return self._counters.copy(), columns

    def restore_sim_state(self, counters, columns=None) -> None:
        counters = np.asarray(counters, np.int64)
        if counters.shape != self._counters.shape:
            raise ValueError(
                f"NetSim.restore_sim_state: counter shape {counters.shape} "
                f"!= fleet shape {self._counters.shape}")
        self._counters[:] = counters
        if columns is not None:
            for name in self._TRACE_COLUMNS:
                col = getattr(self.trace, name)
                col[:] = columns.get(name, [])


def netsim_from_network(network, bandwidth_bps: np.ndarray, n_params: int,
                        sparsify_ratio: float, seed: int, tracer=None
                        ) -> Optional["NetSim"]:
    """Build a `NetSim` from an `api.NetworkSpec`-shaped object (anything
    with the codec/value_bits/link fields), or None when the spec keeps
    the analytic behaviour (``codec == "analytic"``)."""
    if network is None or network.codec == "analytic":
        return None
    codec = get_codec(network.codec, value_bits=network.value_bits)
    link = LinkProfile(
        bandwidth_sigma=network.bandwidth_sigma,
        latency_s=network.latency_s, jitter_s=network.jitter_s,
        loss_prob=network.loss_prob, mtu_bytes=network.mtu_bytes,
        shared_uplink_bps=network.shared_uplink_bps)
    return NetSim(codec, link, bandwidth_bps, n_params,
                  sparsify_ratio=sparsify_ratio, seed=seed, tracer=tracer)
