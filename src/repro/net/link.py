"""Virtual-time link model: what an upload's bytes cost to move.

A `LinkProfile` declares per-node link behaviour as distributions rather
than scalars: a lognormal per-node bandwidth scale on top of the fleet's
`NodeProfile` uplink rates, a fixed propagation latency, exponential
per-upload jitter, an MTU-packetized loss/retransmit model, and an
optional shared-uplink contention cap.  `materialize_bandwidth` resolves
the per-node rates once per run; `draw_transfer` samples one upload's
transfer time.

Determinism: every stochastic draw is keyed by ``(seed, node, upload
sequence number)`` through a counter-based `numpy` `SeedSequence` — the
k-th upload of node i costs the same virtual time no matter how arrivals
bucket into windows or rounds (property-tested in
tests/test_net_properties.py).  The one exception is shared-uplink
contention, which by construction depends on how many uploads share the
window.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class LinkProfile:
    """Declarative per-upload link behaviour (all defaults = an ideal
    link: transfer time is exactly payload_bytes / node_bandwidth)."""
    bandwidth_sigma: float = 0.0    # lognormal sigma of per-node uplink scale
    latency_s: float = 0.0          # fixed propagation latency per upload
    jitter_s: float = 0.0           # exponential jitter scale per upload
    loss_prob: float = 0.0          # per-packet loss probability
    mtu_bytes: int = 1500           # packet size for the loss model
    shared_uplink_bps: float = 0.0  # >0 => uplink capacity shared by every
                                    # concurrent upload in a window/round

    def validate(self) -> None:
        if self.bandwidth_sigma < 0:
            raise ValueError(f"bandwidth_sigma must be >= 0, got "
                             f"{self.bandwidth_sigma}")
        if self.latency_s < 0 or self.jitter_s < 0:
            raise ValueError("latency_s and jitter_s must be >= 0")
        if not 0.0 <= self.loss_prob < 1.0:
            raise ValueError(f"loss_prob must be in [0, 1), got "
                             f"{self.loss_prob}")
        if self.mtu_bytes < 1:
            raise ValueError(f"mtu_bytes must be >= 1, got {self.mtu_bytes}")
        if self.shared_uplink_bps < 0:
            raise ValueError(f"shared_uplink_bps must be >= 0, got "
                             f"{self.shared_uplink_bps}")


def materialize_bandwidth(base_bps: np.ndarray, sigma: float,
                          seed: int) -> np.ndarray:
    """Per-node effective uplink rates: the fleet profile's bandwidths
    scaled by a lognormal factor exp(N(0, sigma)) — sigma=0 returns the
    profile rates untouched (byte-for-byte the analytic model's)."""
    base = np.asarray(base_bps, np.float64)
    if sigma <= 0:
        return base.copy()
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0xB]))
    return base * np.exp(rng.normal(0.0, sigma, base.shape[0]))


def _upload_rng(seed: int, node: int, seq: int) -> np.random.Generator:
    """The (seed, node, upload#) counter-based stream — deterministic and
    independent of batching."""
    return np.random.default_rng(
        np.random.SeedSequence([int(seed), int(node), int(seq)]))


def draw_transfer(link: LinkProfile, payload_bytes: float, node_bw_bps: float,
                  seed: int, node: int, seq: int,
                  concurrency: int = 1) -> Tuple[float, float, int]:
    """One upload's (transfer_s, wire_overhead_bytes, retransmits).

    transfer = latency + jitter + wire_bytes / effective_bandwidth, where
    wire_bytes = payload + retransmits·MTU (each of the payload's
    ceil(bytes/MTU) packets is resent until it survives loss_prob, the
    retransmit count drawn negative-binomially in one shot) and the
    effective bandwidth is the node uplink, capped at
    shared_uplink_bps / concurrency when a shared uplink is declared.
    """
    retrans = 0
    jitter = 0.0
    if link.loss_prob > 0.0 or link.jitter_s > 0.0:
        rng = _upload_rng(seed, node, seq)
        if link.loss_prob > 0.0:
            packets = max(1, -(-int(payload_bytes) // link.mtu_bytes))
            retrans = int(rng.negative_binomial(packets,
                                                1.0 - link.loss_prob))
        if link.jitter_s > 0.0:
            jitter = float(rng.exponential(link.jitter_s))
    overhead = float(retrans * link.mtu_bytes)
    bw = float(node_bw_bps)
    if link.shared_uplink_bps > 0.0:
        bw = min(bw, link.shared_uplink_bps / max(1, concurrency))
    transfer = (link.latency_s + jitter
                + (float(payload_bytes) + overhead) / bw)
    return transfer, overhead, retrans
