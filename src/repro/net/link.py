"""Virtual-time link model: what an upload's bytes cost to move.

A `LinkProfile` declares per-node link behaviour as distributions rather
than scalars: a lognormal per-node bandwidth scale on top of the fleet's
`NodeProfile` uplink rates, a fixed propagation latency, exponential
per-upload jitter, an MTU-packetized loss/retransmit model, and an
optional shared-uplink contention cap.  `materialize_bandwidth` resolves
the per-node rates once per run; `draw_transfer` samples one upload's
transfer time.

Determinism: every stochastic draw is keyed by ``(seed, node, upload
sequence number)`` through a counter-based SplitMix64 hash stream — the
k-th upload of node i costs the same virtual time no matter how arrivals
bucket into windows or rounds, and a batch of draws is computed fully
vectorized with bit-identical results to the one-at-a-time path
(both property-tested in tests/test_net_properties.py).  The one
exception is shared-uplink contention, which by construction depends on
how many uploads share the window.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class LinkProfile:
    """Declarative per-upload link behaviour (all defaults = an ideal
    link: transfer time is exactly payload_bytes / node_bandwidth)."""
    bandwidth_sigma: float = 0.0    # lognormal sigma of per-node uplink scale
    latency_s: float = 0.0          # fixed propagation latency per upload
    jitter_s: float = 0.0           # exponential jitter scale per upload
    loss_prob: float = 0.0          # per-packet loss probability
    mtu_bytes: int = 1500           # packet size for the loss model
    shared_uplink_bps: float = 0.0  # >0 => uplink capacity shared by every
                                    # concurrent upload in a window/round

    def validate(self) -> None:
        if self.bandwidth_sigma < 0:
            raise ValueError(f"bandwidth_sigma must be >= 0, got "
                             f"{self.bandwidth_sigma}")
        if self.latency_s < 0 or self.jitter_s < 0:
            raise ValueError("latency_s and jitter_s must be >= 0")
        if not 0.0 <= self.loss_prob < 1.0:
            raise ValueError(f"loss_prob must be in [0, 1), got "
                             f"{self.loss_prob}")
        if self.mtu_bytes < 1:
            raise ValueError(f"mtu_bytes must be >= 1, got {self.mtu_bytes}")
        if self.shared_uplink_bps < 0:
            raise ValueError(f"shared_uplink_bps must be >= 0, got "
                             f"{self.shared_uplink_bps}")


def materialize_bandwidth(base_bps: np.ndarray, sigma: float,
                          seed: int) -> np.ndarray:
    """Per-node effective uplink rates: the fleet profile's bandwidths
    scaled by a lognormal factor exp(N(0, sigma)) — sigma=0 returns the
    profile rates untouched (byte-for-byte the analytic model's).

    Rates are validated strictly positive and finite: a zero/negative/NaN
    uplink would otherwise divide through `draw_transfer_batch` into
    inf/NaN transfer times and silently poison the async arrival clocks.
    """
    base = np.asarray(base_bps, np.float64)
    _require_positive_bw(base, "node bandwidth")
    if sigma <= 0:
        return base.copy()
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0xB]))
    out = base * np.exp(rng.normal(0.0, sigma, base.shape[0]))
    _require_positive_bw(out, "materialized bandwidth")
    return out


def _require_positive_bw(bw: np.ndarray, what: str) -> None:
    if bw.size and not (np.isfinite(bw).all() and (bw > 0).all()):
        bad = bw[~(np.isfinite(bw) & (bw > 0))]
        raise ValueError(
            f"{what} must be finite and > 0 (transfer time divides by it); "
            f"got {bad[:4].tolist()}{'...' if bad.size > 4 else ''}")


# -- the counter-based per-upload uniform stream ----------------------------
#
# SplitMix64: a stateless hash from (stream key, draw index) to a uniform
# in (0, 1).  Keying each upload's stream on (seed, node, seq) makes every
# draw independent of batching — draw one upload or ten thousand at once
# and the k-th upload of node i sees the same bits — which is exactly the
# determinism contract `NetSim.draw` needs, and unlike `SeedSequence`
# streams it vectorizes to one numpy expression over (uploads, draws).

_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_GAMMA2 = np.uint64((0x9E3779B97F4A7C15 ** 2) & (2 ** 64 - 1))
# cap on uploads*packets per vectorized geometric-draw block (memory bound)
_CHUNK_DRAWS = 1 << 22


def _mix64(z: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, elementwise on uint64 arrays (wrapping)."""
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


def _unit(h: np.ndarray) -> np.ndarray:
    """uint64 hash -> float64 uniform strictly inside (0, 1) (53 bits,
    half-ulp offset keeps log() finite)."""
    return ((h >> np.uint64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


def _stream_key(seed: int, nodes: np.ndarray, seqs: np.ndarray) -> np.ndarray:
    """(U,) uint64 per-upload stream keys from (seed, node, seq): each
    component is mixed before combining so structured inputs (consecutive
    node ids, counter seqs) land on unrelated streams."""
    k = _mix64(np.asarray(seqs, np.uint64) + _GAMMA)
    k = _mix64(k ^ _mix64(np.asarray(nodes, np.uint64) + _GAMMA2))
    return _mix64(k ^ np.uint64(int(seed) & (2 ** 64 - 1)))


def draw_transfer_batch(link: LinkProfile, payload_bytes: float,
                        node_bw_bps: np.ndarray, seed: int,
                        nodes: np.ndarray, seqs: np.ndarray,
                        concurrency: int = 1
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """A batch of uploads' (transfer_s, wire_overhead_bytes, retransmits),
    each (U,), fully vectorized.

    Per upload: transfer = latency + jitter + wire_bytes / effective_bw,
    where wire_bytes = payload + retransmits·MTU — each of the payload's
    ceil(bytes/MTU) packets is resent until it survives loss_prob, the
    per-packet retransmit count drawn geometrically by inverse CDF
    (floor(log u / log loss_prob), so the packet sum is the same
    negative-binomial law the scalar path always modelled) — and the
    effective bandwidth is the node uplink, capped at
    shared_uplink_bps / concurrency when a shared uplink is declared.

    Draw i of upload (seed, node, seq) is hash(key, i): index 0 is the
    jitter draw, indices 1..packets the per-packet loss draws, so results
    are independent of batch composition.  The packet axis is chunked to
    bound peak memory at ~`_CHUNK_DRAWS` doubles.
    """
    nodes = np.asarray(nodes, np.int64)
    seqs = np.asarray(seqs, np.int64)
    u = nodes.size
    retrans = np.zeros(u, np.int64)
    jitter = np.zeros(u, np.float64)
    if u and (link.loss_prob > 0.0 or link.jitter_s > 0.0):
        key = _stream_key(seed, nodes, seqs)
        if link.jitter_s > 0.0:
            jitter = -link.jitter_s * np.log(_unit(_mix64(key)))
        if link.loss_prob > 0.0:
            packets = max(1, -(-int(payload_bytes) // link.mtu_bytes))
            inv_log_loss = 1.0 / np.log(link.loss_prob)
            step = max(1, _CHUNK_DRAWS // u)
            for lo in range(1, packets + 1, step):
                idx = np.arange(lo, min(lo + step, packets + 1),
                                dtype=np.uint64)
                us = _unit(_mix64(key[:, None] + idx[None, :] * _GAMMA))
                retrans += np.floor(
                    np.log(us) * inv_log_loss).astype(np.int64).sum(axis=1)
    overhead = retrans * float(link.mtu_bytes)
    bw = np.asarray(node_bw_bps, np.float64).copy()
    _require_positive_bw(bw, "node bandwidth")
    if link.shared_uplink_bps > 0.0:
        bw = np.minimum(bw, link.shared_uplink_bps / max(1, concurrency))
    transfer = (link.latency_s + jitter
                + (float(payload_bytes) + overhead) / bw)
    return transfer, overhead, retrans


def draw_transfer(link: LinkProfile, payload_bytes: float, node_bw_bps: float,
                  seed: int, node: int, seq: int,
                  concurrency: int = 1) -> Tuple[float, float, int]:
    """One upload's (transfer_s, wire_overhead_bytes, retransmits) — the
    size-1 case of `draw_transfer_batch` (same stream, same bits)."""
    transfer, overhead, retrans = draw_transfer_batch(
        link, payload_bytes, np.asarray([node_bw_bps], np.float64), seed,
        np.asarray([node]), np.asarray([seq]), concurrency=concurrency)
    return float(transfer[0]), float(overhead[0]), int(retrans[0])
