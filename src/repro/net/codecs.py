"""Wire codecs: a sparsified/noised update as an actual byte stream.

The paper's headline claim is communication efficiency, yet until this
subsystem the repo only *estimated* upload cost with an analytic
(values + indices) formula.  A `Codec` closes that gap: `encode` turns a
flat update vector into a real byte payload (so byte counts are measured,
not assumed), `decode` inverts it (exactly for the sparse codecs, within
a provable quantization bound for the quantized variant), and `nbytes`
predicts the payload size from the nonzero count alone — the fast path
the engines use for per-upload accounting without materializing buffers.

Registry (`get_codec`):

  * ``dense_f32``       — every value as little-endian f32 (the upload a
                          no-compression run puts on the wire);
  * ``sparse_coo``      — u32 count header + u32 index / f32 value pairs;
  * ``sparse_bitpack``  — u32 count header + indices bit-packed to
                          ceil(log2(P)) bits each + values as f32, or
                          quantized to ``value_bits`` ∈ {8, 16} via
                          symmetric scale quantization (f32 scale header,
                          |error| ≤ scale/2 per element).

Node-batched accounting (`batched_encoded_bytes`) counts nonzeros across
a stacked (K, P) cohort — one fused Pallas pass (`kernels.wire_bytes`,
mirroring `kernels/sparsify.py`) or a vectorized jnp fallback — and maps
the counts through `Codec.nbytes`.

`analytic_upload_bytes` is the pre-`repro.net` estimate, kept as the
single shared fallback `fleet.stages.bytes_per_node` and
`core.accumulator.upload_bytes` both delegate to.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Dict, Tuple, Union

import numpy as np

CODEC_NAMES = ("dense_f32", "sparse_coo", "sparse_bitpack")


# ---------------------------------------------------------------------------
# the analytic fallback (pre-net comm accounting, single source)
# ---------------------------------------------------------------------------

def analytic_upload_bytes(n_params: int, ratio: float,
                          bytes_per_value: int = 4,
                          bytes_per_index: int = 4) -> int:
    """The analytic upload-size estimate: dense f32 values, or
    (value, index) pairs for a sparsified upload.

    This is the pre-`repro.net` formula both legacy call sites
    (`fleet.stages.bytes_per_node`, `core.accumulator.upload_bytes`)
    delegate to — one source, pinned by tests/test_net.py.
    """
    if ratio >= 1.0:
        return int(n_params) * bytes_per_value
    return int(n_params * ratio) * (bytes_per_value + bytes_per_index)


def index_bits(n_params: int) -> int:
    """Bits needed to address a coordinate in [0, n_params)."""
    if n_params < 1:
        raise ValueError(f"n_params must be >= 1, got {n_params}")
    return max(1, int(n_params - 1).bit_length())


# ---------------------------------------------------------------------------
# bit packing (little-endian bit order throughout)
# ---------------------------------------------------------------------------

def _pack_bits(values: np.ndarray, bits: int) -> bytes:
    """Pack each value into ``bits`` little-endian bits; result is the
    minimal whole-byte buffer (the byte count the wire actually carries)."""
    if values.size == 0:
        return b""
    v = values.astype(np.uint64)
    shifts = np.arange(bits, dtype=np.uint64)
    mat = ((v[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return np.packbits(mat.reshape(-1), bitorder="little").tobytes()


def _unpack_bits(buf: bytes, bits: int, count: int) -> np.ndarray:
    if count == 0:
        return np.zeros(0, np.int64)
    raw = np.unpackbits(np.frombuffer(buf, np.uint8), bitorder="little")
    mat = raw[:count * bits].reshape(count, bits).astype(np.uint64)
    shifts = np.arange(bits, dtype=np.uint64)
    return (mat << shifts).sum(axis=1).astype(np.int64)


# ---------------------------------------------------------------------------
# messages + codec base
# ---------------------------------------------------------------------------

@dataclass
class WireMessage:
    """One encoded upload: the actual payload plus decode metadata."""
    codec: str
    n_params: int
    payload: bytes
    meta: Dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return len(self.payload)


class Codec:
    """encode/decode + closed-form payload size from the nonzero count."""

    name = "base"

    def encode(self, u: np.ndarray) -> WireMessage:
        raise NotImplementedError

    def decode(self, msg: WireMessage) -> np.ndarray:
        raise NotImplementedError

    def nbytes(self, nnz: Union[int, np.ndarray], n_params: int):
        """Payload bytes for an upload with ``nnz`` nonzeros (vectorized
        over ``nnz`` arrays). Must equal ``len(encode(u).payload)``."""
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class DenseF32(Codec):
    """Every coordinate as little-endian f32 — the no-compression wire."""

    name = "dense_f32"

    def encode(self, u: np.ndarray) -> WireMessage:
        u = np.asarray(u, np.float32).reshape(-1)
        return WireMessage(self.name, u.size, u.astype("<f4").tobytes())

    def decode(self, msg: WireMessage) -> np.ndarray:
        return np.frombuffer(msg.payload, "<f4").astype(np.float32)

    def nbytes(self, nnz, n_params: int):
        return np.asarray(nnz, np.int64) * 0 + 4 * int(n_params)


class SparseCoo(Codec):
    """u32 count header + (u32 index, f32 value) pairs."""

    name = "sparse_coo"

    def encode(self, u: np.ndarray) -> WireMessage:
        u = np.asarray(u, np.float32).reshape(-1)
        idx = np.flatnonzero(u)
        payload = (struct.pack("<I", idx.size)
                   + idx.astype("<u4").tobytes()
                   + u[idx].astype("<f4").tobytes())
        return WireMessage(self.name, u.size, payload)

    def decode(self, msg: WireMessage) -> np.ndarray:
        (nnz,) = struct.unpack_from("<I", msg.payload, 0)
        idx = np.frombuffer(msg.payload, "<u4", count=nnz, offset=4)
        vals = np.frombuffer(msg.payload, "<f4", count=nnz,
                             offset=4 + 4 * nnz)
        out = np.zeros(msg.n_params, np.float32)
        out[idx.astype(np.int64)] = vals
        return out

    def nbytes(self, nnz, n_params: int):
        return 4 + 8 * np.asarray(nnz, np.int64)


class SparseBitpack(Codec):
    """u32 count header + bit-packed indices (ceil(log2(P)) bits each) +
    values as f32 (exact) or symmetric-scale-quantized ints
    (``value_bits`` ∈ {8, 16}; f32 scale header; |error| ≤ scale/2)."""

    VALUE_BITS = (8, 16, 32)

    def __init__(self, value_bits: int = 32):
        if value_bits not in self.VALUE_BITS:
            raise ValueError(f"sparse_bitpack value_bits must be one of "
                             f"{self.VALUE_BITS}, got {value_bits}")
        self.value_bits = int(value_bits)

    name = "sparse_bitpack"

    def describe(self) -> str:
        return (self.name if self.value_bits == 32
                else f"{self.name}_q{self.value_bits}")

    def encode(self, u: np.ndarray) -> WireMessage:
        u = np.asarray(u, np.float32).reshape(-1)
        idx = np.flatnonzero(u)
        vals = u[idx]
        bits = index_bits(u.size)
        payload = struct.pack("<I", idx.size)
        meta: Dict = {"nnz": int(idx.size)}
        if self.value_bits == 32:
            payload += _pack_bits(idx, bits) + vals.astype("<f4").tobytes()
        else:
            qmax = (1 << (self.value_bits - 1)) - 1
            m = float(np.abs(vals).max()) if vals.size else 0.0
            scale = m / qmax if m > 0 else 1.0
            q = np.clip(np.round(vals.astype(np.float64) / scale),
                        -qmax, qmax)
            dt = "<i1" if self.value_bits == 8 else "<i2"
            payload += (struct.pack("<f", scale) + _pack_bits(idx, bits)
                        + q.astype(dt).tobytes())
            meta["scale"] = scale
        return WireMessage(self.describe(), u.size, payload, meta)

    def decode(self, msg: WireMessage) -> np.ndarray:
        (nnz,) = struct.unpack_from("<I", msg.payload, 0)
        off = 4
        scale = 1.0
        if self.value_bits < 32:
            (scale,) = struct.unpack_from("<f", msg.payload, off)
            off += 4
        bits = index_bits(msg.n_params)
        n_idx_bytes = (nnz * bits + 7) // 8
        idx = _unpack_bits(msg.payload[off:off + n_idx_bytes], bits, nnz)
        off += n_idx_bytes
        if self.value_bits == 32:
            vals = np.frombuffer(msg.payload, "<f4", count=nnz, offset=off)
        else:
            dt = "<i1" if self.value_bits == 8 else "<i2"
            q = np.frombuffer(msg.payload, dt, count=nnz, offset=off)
            vals = (q.astype(np.float64) * scale).astype(np.float32)
        out = np.zeros(msg.n_params, np.float32)
        out[idx] = vals
        return out

    def nbytes(self, nnz, n_params: int):
        nnz = np.asarray(nnz, np.int64)
        bits = index_bits(n_params)
        out = 4 + (nnz * bits + 7) // 8 + nnz * (self.value_bits // 8)
        if self.value_bits < 32:
            out = out + 4                   # the f32 quantization scale
        return out


def get_codec(name: str, value_bits: int = 32) -> Codec:
    """Codec registry lookup. ``value_bits`` selects the quantized-value
    variant of ``sparse_bitpack`` (ignored-but-checked elsewhere)."""
    if name == "dense_f32":
        codec: Codec = DenseF32()
    elif name == "sparse_coo":
        codec = SparseCoo()
    elif name == "sparse_bitpack":
        return SparseBitpack(value_bits)
    else:
        raise ValueError(f"unknown codec {name!r}; have {CODEC_NAMES}")
    if value_bits != 32:
        raise ValueError(f"value_bits={value_bits} is a sparse_bitpack "
                         f"variant; codec {name!r} stores f32 values")
    return codec


# ---------------------------------------------------------------------------
# node-batched accounting: stacked cohort -> per-node encoded bytes
# ---------------------------------------------------------------------------

def count_nnz(flat, backend: str = "reference"):
    """Per-node nonzero counts of a stacked (K, P) cohort of flat updates
    — the quantity sparse codecs encode.  ``backend="pallas"`` runs the
    fused `kernels.wire_bytes.nnz_fleet` pass; the reference path is a
    vectorized jnp reduction.  Returns (K,) int32 (a jax array)."""
    if backend == "pallas":
        from ..kernels.wire_bytes import nnz_fleet
        return nnz_fleet(flat)
    import jax.numpy as jnp
    return jnp.sum(flat != 0, axis=-1).astype(jnp.int32)


def batched_encoded_bytes(flat, codec: Codec,
                          backend: str = "reference") -> np.ndarray:
    """Encoded payload size of every row of a stacked (K, P) cohort,
    without materializing any payload: fused nonzero count -> closed-form
    `Codec.nbytes`.  Agrees exactly with ``len(codec.encode(row).payload)``
    per row (tested in tests/test_net.py)."""
    flat = np.asarray(flat) if not hasattr(flat, "shape") else flat
    nnz = np.asarray(count_nnz(flat, backend))
    return np.asarray(codec.nbytes(nnz, int(flat.shape[-1])), np.int64)
