"""Vectorized node-fleet simulation engine.

Runs an entire cohort's federated round — local SGD, DGC sparsify, ALDP
perturbation, cloud-side detection, Eq. (6) mixing — as one device dispatch,
instead of the sequential trainer's K-dispatch Python loop. See
`engine.FleetEngine` (the batched round), `state` (stacked pytree state and
gather/scatter), and `scenarios` (declarative node populations).
"""
from .engine import (AvailabilityTrace, ClientSampler, FleetConfig,  # noqa: F401
                     FleetEngine, FleetRoundRecord, FullParticipation,
                     NodeProfile, UniformSampler, detect_masked)
from .scenarios import SCENARIOS, Scenario, build_engine, get_scenario  # noqa: F401
from .state import (FleetData, FleetState, broadcast_tree,  # noqa: F401
                    chain_node_keys, gather_nodes, init_fleet_state,
                    parallel_node_keys, scatter_nodes, stack_trees,
                    unstack_tree)
