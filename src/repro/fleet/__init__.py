"""Vectorized node-fleet simulation engine.

Runs an entire cohort's federated round — local SGD, DGC sparsify, ALDP
perturbation, cloud-side detection, Eq. (6) mixing — as one device dispatch,
instead of the sequential trainer's K-dispatch Python loop. See
`engine.FleetEngine` (the batched synchronous round),
`async_engine.AsyncFleetEngine` (the batched virtual-time event scheduler
for the paper's asynchronous schemes), `stages` (the shared backend-
pluggable pipeline stages), `state` (stacked pytree state and
gather/scatter), and `scenarios` (declarative node populations).
"""
from .async_engine import (AsyncFleetConfig, AsyncFleetEngine,  # noqa: F401
                           AsyncWindowRecord, make_window_folds)
from .engine import (AvailabilityTrace, ClientSampler, FleetConfig,  # noqa: F401
                     FleetEngine, FleetRoundRecord, FullParticipation,
                     NodeProfile, UniformSampler, detect_masked)
from .mesh import FleetMesh  # noqa: F401
from .scenarios import (SCENARIOS, Scenario, build_async_engine,  # noqa: F401
                        build_engine, get_scenario)
from .state import (FleetData, FleetState, broadcast_tree,  # noqa: F401
                    chain_node_keys, chain_node_keys_masked, gather_nodes,
                    init_async_fleet_state, init_fleet_state, pad_keys,
                    pad_node_axis, parallel_node_keys, scatter_nodes,
                    stack_trees, unstack_tree)
