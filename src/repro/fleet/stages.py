"""Shared cohort-batched pipeline stages for the sync and async engines.

`FleetEngine.run_round` (synchronous barrier) and
`AsyncFleetEngine.run_window` (virtual-time arrival windows) run the same
upload pipeline over a stacked cohort:

  local SGD -> delta -> [DGC accumulate+sparsify] -> [ALDP clip+noise]
            -> rebuild node models -> cloud-side accuracy

Only the aggregation differs (barrier masked-mean vs staleness-aware
arrival-order mixing), so the stages live here as module-level functions
parameterized by `FleetConfig` with a pluggable backend: "reference"
(pure-jnp `accumulator`/`aldp`, bit-compatible with the sequential
trainer) or "pallas" (the node-batched fused `sparsify`/`ldp_noise`
kernels).

Every stage here is *shard-oblivious*: all math is per-node along the
leading axis with no cross-node reduction, so the mesh-sharded engines
(`fleet.mesh.FleetMesh`) call the very same functions inside `shard_map`
on each device's node/cohort block — only detection thresholds and
aggregation (which do cross nodes) pick up collectives, and those live in
the engines' sharded round/window builders, not here. `detect_masked`
below is the one cross-node stage: sharded callers hand it the
`all_gather`-ed accuracy set.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import accumulator as accum
from ..core import aldp, detection


# ---------------------------------------------------------------------------
# stage: node-local minibatch SGD
# ---------------------------------------------------------------------------

def make_local_train(loss_fn, local_steps: int, lr: float, batch_size: int):
    """Single-node local SGD body; identical math/key-use to the sequential
    trainer's `_local_train_impl` (bounds from `size`, not the padded shard
    length). The sync engine vmaps it with the global params broadcast
    (`in_axes=(None, ...)`); the async engine with per-node dispatched
    params (`in_axes=(0, ...)`)."""

    def local_train(params, x, y, size, key):
        def body(p, k):
            idx = jax.random.randint(k, (batch_size,), 0, size)
            batch = {"x": x[idx], "y": y[idx]}
            g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
            return jax.tree.map(lambda a, b: a - lr * b, p, g), None

        keys = jax.random.split(key, local_steps)
        p, _ = jax.lax.scan(body, params, keys)
        return p

    return local_train


# ---------------------------------------------------------------------------
# stage: upload pipeline (DGC sparsify -> ALDP), cohort-batched
# ---------------------------------------------------------------------------

def upload_pipeline(cfg, deltas, residuals_c, k2s, need_nnz: bool = False):
    """[DGC accumulate+sparsify] -> [ALDP clip+noise] over a stacked cohort.

    `cfg` needs `.sparsify_ratio`, `.sigma`, `.clip_s`, `.backend`.
    Returns (uploaded deltas, updated cohort residuals, per-node nonzero
    counts or None).  ``need_nnz`` gates the count so analytic runs (no
    `repro.net` attached) pay nothing for it.

    The counts are taken *post-sparsify, pre-noise*: they are the sparse
    coordinate set the node uploads — in the deployed system ALDP noise is
    added only to the transmitted (kept) values, so the wire message stays
    sparse.  Note the simulation-side caveat: this reference pipeline
    (inherited from the seed implementation, parity-pinned) applies the
    noise to *every* coordinate of the delta, so the update the cloud
    aggregates is denser than the priced wire message — the byte counts
    model the intended wire, not the reference pipeline's dense-noise
    artifact."""
    if cfg.backend == "pallas":
        return _upload_pipeline_fused(cfg, deltas, residuals_c, k2s,
                                      need_nnz)
    if cfg.sparsify_ratio < 1.0:
        deltas, residuals_c, _ = jax.vmap(
            lambda r, d: accum.accumulate_and_sparsify(
                r, d, cfg.sparsify_ratio))(residuals_c, deltas)
    nnz = count_upload_nnz(deltas, cfg.backend) if need_nnz else None
    if cfg.sigma > 0.0:
        deltas = jax.vmap(
            lambda d, k: aldp.aldp_perturb(d, k, cfg.sigma,
                                           cfg.clip_s)[0])(deltas, k2s)
    return deltas, residuals_c, nnz


def _upload_pipeline_fused(cfg, deltas, residuals_c, k2s, need_nnz: bool):
    """The pallas backend's upload pipeline: one fused megakernel launch
    (`kernels.upload_fused`) over the flattened cohort instead of the
    per-stage dispatch chain.  The two whole-tensor reductions the kernel
    cannot fuse past (per-leaf DGC quantile threshold; post-sparsify L2
    clip norm) run here as a single jnp pre-pass over `combined`."""
    from ..kernels import upload_fused as uf

    do_sparsify = cfg.sparsify_ratio < 1.0
    apply_ldp = cfg.sigma > 0.0
    if not (do_sparsify or apply_ldp):
        # nothing to compute per element: skip the identity kernel (and,
        # without nnz, the flatten too)
        nnz = count_upload_nnz(deltas, "pallas") if need_nnz else None
        return deltas, residuals_c, nnz
    layout = cohort_layout(deltas)
    flat_d = layout.flatten(deltas)
    thresholds = flat_r = comb = None
    if do_sparsify:
        flat_r = layout.flatten(residuals_c)
        comb = flat_d + flat_r
        thresholds = jnp.stack(
            [jax.vmap(lambda v: accum.leaf_threshold(
                v, cfg.sparsify_ratio))(comb[:, off:off + size])
             for off, size in zip(layout.offsets, layout.sizes)], axis=1)
    seeds = scales = None
    if apply_ldp:
        if do_sparsify:
            thr_elem = uf.spread_thresholds(thresholds, layout.offsets,
                                            layout.total)
            sp = jnp.where(jnp.abs(comb) >= thr_elem, comb, 0.0)
        else:
            sp = flat_d
        norms = jnp.sqrt(jnp.sum(jnp.square(sp), axis=1))
        scales = 1.0 / jnp.maximum(1.0, norms / cfg.clip_s)
        seeds = node_noise_seeds(k2s)
    up, newr, nnz = uf.upload_fused_fleet(
        flat_d, flat_r, thresholds, seeds, scales, cfg.sigma, cfg.clip_s,
        boundaries=layout.offsets, need_nnz=need_nnz)
    deltas = layout.unflatten(up)
    if do_sparsify:
        residuals_c = layout.unflatten_like(newr, residuals_c)
    return deltas, residuals_c, nnz


def node_noise_seeds(k2s) -> jnp.ndarray:
    """Node-distinct int32 noise seeds folded from the per-node PRNG keys —
    shared by the fused and unfused pallas ALDP paths."""
    raw = k2s
    if jnp.issubdtype(k2s.dtype, jax.dtypes.prng_key):   # new-style typed keys
        raw = jax.random.key_data(k2s)
    return (raw[:, 0] ^ raw[:, -1]).astype(jnp.int32)


def count_upload_nnz(deltas, backend: str = "reference") -> jnp.ndarray:
    """Per-node nonzero count of a stacked upload tree — the wire quantity
    `repro.net`'s sparse codecs price.  The pallas path shares
    `net.codecs.count_nnz`'s fused `kernels.wire_bytes.nnz_fleet` kernel
    over the flattened cohort; the reference path reduces per leaf (no
    flatten/concat materialization)."""
    if backend == "pallas":
        from ..net.codecs import count_nnz
        flat = cohort_layout(deltas).flatten(deltas)
        return count_nnz(flat, backend="pallas")
    c = jax.tree.leaves(deltas)[0].shape[0]
    return sum(jnp.sum(d.reshape(c, -1) != 0, axis=1).astype(jnp.int32)
               for d in jax.tree.leaves(deltas))


def rebuild_and_evaluate(acc_fn, start_params, deltas, cloud_x, cloud_y):
    """Rebuild every node's uploaded model ω_new = ω_start + Δ and score it
    on the cloud testing dataset (§5.4). `start_params` is either the global
    model (sync: leaves without node axis, broadcast) or the stacked
    dispatched params (async: leading node axis)."""
    broadcast = (jax.tree.leaves(deltas)[0].ndim
                 > jax.tree.leaves(start_params)[0].ndim)
    if broadcast:       # start_params has no node axis: broadcast it
        omegas = jax.tree.map(lambda g, d: g[None].astype(d.dtype) + d,
                              start_params, deltas)
    else:
        omegas = jax.tree.map(lambda g, d: g.astype(d.dtype) + d,
                              start_params, deltas)
    accs = jax.vmap(lambda p: acc_fn(p, cloud_x, cloud_y))(omegas)
    return omegas, accs


# ---------------------------------------------------------------------------
# stage: masked detection (Alg. 2 over a partially-valid cohort)
# ---------------------------------------------------------------------------

def detect_masked(accs: jnp.ndarray, valid: jnp.ndarray, s: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 2 with padded slots excluded: threshold is the top-s percentile
    of the *valid* accuracies; reduces to `detection.detect` when all slots
    are valid."""
    masked = jnp.where(valid, accs.astype(jnp.float32), jnp.nan)
    thr = jnp.nanpercentile(masked, s)
    mask = (accs > thr) & valid
    mask = jnp.where(mask.any(), mask, (accs >= thr) & valid)
    return mask, thr


# ---------------------------------------------------------------------------
# stage: the adversary zoo's delta-level attacks
#
# Data-level attacks (label_flip, backdoor, the sybils' shared shard) are
# baked into the shards by `data.federated`; what remains engine-side is
# per-node row scaling of the uploaded deltas — sybil boosting and the
# adaptive attacker's detection-aware throttle — plus the DDoS flood count
# the host feeds to `NetSim.draw`.  All of it is elementwise along the
# leading node axis (no cross-node reduction), so the stage runs unchanged
# inside the mesh engines' shard_map: shard-oblivious by construction.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttackPlan:
    """Engine-side view of an `api.AttackMix` + the materialized malicious
    ids: which rows are adversarial and how their uploads misbehave."""
    kind: str                       # label_flip|sybil|backdoor|adaptive|ddos
    malicious: np.ndarray           # (N,) bool host-side membership
    sybil_boost: float = 3.0
    adapt_poison_scale: float = 0.5
    ddos_uploads: int = 4

    @classmethod
    def from_spec(cls, attack, n_nodes: int, malicious_ids) -> "AttackPlan":
        mal = np.zeros(int(n_nodes), bool)
        mal[np.asarray(list(malicious_ids), int)] = True
        return cls(kind=attack.kind, malicious=mal,
                   sybil_boost=float(attack.sybil_boost),
                   adapt_poison_scale=float(attack.adapt_poison_scale),
                   ddos_uploads=int(attack.ddos_uploads))

    @property
    def n_malicious(self) -> int:
        return int(self.malicious.sum())

    @property
    def needs_throttle(self) -> bool:
        """Does this attack carry device-side state (`FleetState.throttle`)?"""
        return self.kind == "adaptive"

    @property
    def flood_uploads(self) -> int:
        """Extra concurrent flows the host injects into `NetSim.draw`'s
        shared-uplink contention each round/window (the DDoS attack)."""
        return (self.n_malicious * self.ddos_uploads
                if self.kind == "ddos" else 0)

    def mask(self, n_total: int = None) -> jnp.ndarray:
        """(n_total,) bool device mask, padded False (mesh pad rows are
        honest dummies)."""
        m = self.malicious
        if n_total is not None and n_total > m.shape[0]:
            m = np.concatenate([m, np.zeros(n_total - m.shape[0], bool)])
        return jnp.asarray(m)


def scale_node_rows(tree, scale: jnp.ndarray):
    """Multiply every leaf's node rows by the (C,) per-node scale."""
    return jax.tree.map(
        lambda x: (x * scale.reshape((-1,) + (1,) * (x.ndim - 1))
                   .astype(x.dtype)), tree)


def make_delta_attack(plan):
    """The pluggable delta-level attack stage, or None when the attack
    does not touch uploads.  Returns stage(deltas, mal_c, throttle_c) —
    ``mal_c`` the cohort's malicious mask, ``throttle_c`` the adaptive
    attacker's per-node poison scale (ignored by sybil)."""
    if plan is None or plan.kind not in ("sybil", "adaptive"):
        return None
    if plan.kind == "sybil":
        boost = float(plan.sybil_boost)

        def stage(deltas, mal_c, throttle_c=None):
            return scale_node_rows(
                deltas, jnp.where(mal_c, boost, 1.0).astype(jnp.float32))
    else:
        def stage(deltas, mal_c, throttle_c):
            return scale_node_rows(
                deltas, jnp.where(mal_c, throttle_c, 1.0)
                .astype(jnp.float32))
    return stage


def adaptive_throttle_update(throttle: jnp.ndarray, rejected: jnp.ndarray,
                             seen: jnp.ndarray, scale: float) -> jnp.ndarray:
    """The detection-aware attacker's control law, per participating node:
    caught ⇒ back the poison off (× ``scale``); accepted ⇒ creep back up
    (× 1.1, capped at full strength).  Non-participants keep their state.
    Applied to malicious rows only (honest rows carry throttle 1.0 and are
    never scaled)."""
    upd = jnp.where(rejected, throttle * float(scale),
                    jnp.minimum(1.0, throttle * 1.1))
    return jnp.where(seen, upd, throttle)


# ---------------------------------------------------------------------------
# cohort flat layout (cached) + the pallas-backed cohort upload pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CohortLayout:
    """Static flat layout of a stacked cohort tree: leaf order, shapes,
    dtypes and start offsets in the concatenated (C, P) f32 view.  Built
    once per (treedef, shapes, dtypes) via `cohort_layout` — the flatten /
    unflatten closures and leaf boundaries used to be rebuilt on every
    trace by each pipeline stage separately; now every pallas stage (the
    fused pipeline, the unfused ALDP chain, nnz counting, the window fold)
    shares one cached layout."""
    treedef: object
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[np.dtype, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]        # start of each leaf in the flat axis
    total: int                      # P — flattened per-node element count

    def flatten(self, tree) -> jnp.ndarray:
        """Stacked tree with leading cohort axis -> (C, P) f32."""
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32)
             for l in jax.tree.leaves(tree)], axis=1)

    def flatten_one(self, tree) -> jnp.ndarray:
        """Unbatched tree (no cohort axis) -> (P,) f32, same leaf order."""
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                for l in jax.tree.leaves(tree)])

    def unflatten(self, flat: jnp.ndarray):
        out = [flat[:, o:o + s].reshape((flat.shape[0],) + shape).astype(dt)
               for shape, dt, s, o in zip(self.shapes, self.dtypes,
                                          self.sizes, self.offsets)]
        return jax.tree.unflatten(self.treedef, out)

    def unflatten_one(self, flat: jnp.ndarray):
        out = [flat[o:o + s].reshape(shape).astype(dt)
               for shape, dt, s, o in zip(self.shapes, self.dtypes,
                                          self.sizes, self.offsets)]
        return jax.tree.unflatten(self.treedef, out)

    def unflatten_like(self, flat: jnp.ndarray, tree):
        """Unflatten casting to `tree`'s leaf dtypes (e.g. residual trees,
        whose dtypes may differ from the deltas this layout was built on)."""
        leaves = jax.tree.leaves(tree)
        out = [flat[:, o:o + s].reshape((flat.shape[0],) + shape)
               .astype(l.dtype)
               for shape, l, s, o in zip(self.shapes, leaves, self.sizes,
                                         self.offsets)]
        return jax.tree.unflatten(self.treedef, out)


@functools.lru_cache(maxsize=128)
def _cohort_layout(treedef, shapes, dtypes) -> CohortLayout:
    sizes = tuple(int(np.prod(s)) for s in shapes)
    offsets = tuple(int(o) for o in np.concatenate(
        [[0], np.cumsum(sizes)[:-1]]))
    return CohortLayout(treedef, shapes, dtypes, sizes, offsets,
                        int(sum(sizes)))


def cohort_layout(tree) -> CohortLayout:
    """Cached `CohortLayout` for a stacked tree (leading cohort axis)."""
    leaves, treedef = jax.tree.flatten(tree)
    return _cohort_layout(treedef,
                          tuple(tuple(l.shape[1:]) for l in leaves),
                          tuple(np.dtype(l.dtype) for l in leaves))


def flatten_cohort(tree):
    """Stacked tree with leading cohort axis -> ((C, P) flat, unflatten)."""
    layout = cohort_layout(tree)
    return layout.flatten(tree), layout.unflatten


def sparsify_pallas_cohort(deltas, residuals, ratio: float):
    """Per-leaf DGC split via the node-batched `sparsify_fleet` kernel —
    same per-leaf quantile threshold rule as `accum.accumulate_and_sparsify`,
    but one kernel launch per leaf for the whole cohort."""
    from ..kernels.sparsify import sparsify_fleet

    def one_leaf(d, r):
        c = d.shape[0]
        df = d.reshape(c, -1).astype(jnp.float32)
        rf = r.reshape(c, -1).astype(jnp.float32)
        comb = df + rf
        thr = jax.vmap(lambda v: accum.leaf_threshold(v, ratio))(comb)
        up, newr = sparsify_fleet(df, rf, thr)
        return up.reshape(d.shape).astype(d.dtype), newr.reshape(r.shape)

    pairs = jax.tree.map(one_leaf, deltas, residuals)
    up = jax.tree.map(lambda p: p[0], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    newr = jax.tree.map(lambda p: p[1], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    return up, newr


def aldp_pallas_cohort(deltas, k2s, sigma: float, clip_s: float):
    """Cohort ALDP via the node-batched `ldp_perturb_fleet` kernel: whole-
    delta clip scale per node, in-kernel Gaussian noise (node-distinct
    seeds folded from the per-node PRNG keys).  Kept as the unfused
    comparator for `kernels.upload_fused` (benchmarks + property tests);
    the engines' pallas backend runs the fused pipeline."""
    from ..kernels.ldp_noise import ldp_perturb_fleet

    layout = cohort_layout(deltas)
    flat = layout.flatten(deltas)
    norms = jnp.sqrt(jnp.sum(jnp.square(flat), axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms / clip_s)
    out = ldp_perturb_fleet(flat, node_noise_seeds(k2s), scales, sigma,
                            clip_s)
    return layout.unflatten(out)


# ---------------------------------------------------------------------------
# construction + wire-format accounting shared by both engines
# ---------------------------------------------------------------------------

DEFAULT_BANDWIDTH_BPS = 12.5e6      # 100 Mbit/s edge uplink


def init_engine_common(init_params, node_data, test_data, cloud_test,
                       profile):
    """The setup both engines share: coerce per-node shards to `FleetData`,
    move eval sets to device, default the system profile, count params.

    Returns (data, n_nodes, test_data, cloud_test, profile, n_params)."""
    from .engine import NodeProfile       # deferred: engine imports stages
    from .state import FleetData

    data = (node_data if isinstance(node_data, FleetData)
            else FleetData.from_node_data(node_data))
    n_nodes = data.n_nodes
    test = (jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))
    cloud = (jnp.asarray(cloud_test[0]), jnp.asarray(cloud_test[1]))
    profile = profile or NodeProfile(
        compute_s=np.ones(n_nodes),
        bandwidth_bps=np.full(n_nodes, DEFAULT_BANDWIDTH_BPS))
    n_params = sum(x.size for x in jax.tree.leaves(init_params))
    return data, n_nodes, test, cloud, profile, n_params


def bytes_per_node(n_params: int, sparsify_ratio: float) -> float:
    """Analytic upload size per node: dense f32 values, or (value, index)
    pairs for a sparsified upload — the shared `repro.net` fallback
    (`accumulator.upload_bytes` delegates to the same helper, pinned by
    tests/test_net.py).  Byte-accurate accounting lives in `repro.net`."""
    from ..net.codecs import analytic_upload_bytes
    return analytic_upload_bytes(n_params, sparsify_ratio)
