"""Declarative fleet scenarios: enumerate node populations, don't hand-wire.

A `Scenario` is a named preset over the `repro.api` experiment spec: it
describes a whole population — size, adversary fraction, straggler tail,
availability/churn, cohort sampling, privacy/communication knobs — and
`to_spec()` emits the corresponding `api.ExperimentSpec`.  The engine
builders are thin wrappers over the api pipeline
(``compile_plan`` -> ``materialize`` -> ``make_engine``); benchmarks,
examples and tests pick scenarios by name from `SCENARIOS` instead of
re-assembling experiments by hand.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from .async_engine import AsyncFleetEngine
from .engine import ClientSampler, FleetEngine
from .mesh import FleetMesh


@dataclass(frozen=True)
class Scenario:
    """One node population + training regime, fully declarative."""
    name: str
    n_nodes: int = 10
    # population composition
    malicious_frac: float = 0.0         # adversary fraction (see attack_kind)
    attack_kind: str = "label_flip"     # api.AttackMix zoo kind
    placement: str = "random"           # malicious-node placement
    straggler_frac: float = 0.0         # nodes with `straggler_slowdown`x compute
    straggler_slowdown: float = 10.0
    availability: float = 1.0           # per-round P(node is reachable)
    cohort_frac: float = 1.0            # uniform-C sampling fraction (<1)
    heterogeneity: float = 0.5          # lognormal sigma of compute speeds
    base_compute_s: float = 1.0
    bandwidth_bps: float = 12.5e6
    # training / privacy / communication
    model: str = "mlp"                  # mlp | cnn
    hw: Tuple[int, int] = (8, 8)
    local_steps: int = 5
    batch_size: int = 16
    lr: float = 0.1
    alpha: float = 0.5
    sigma: float = 0.0
    clip_s: float = 1.0
    detect: bool = False
    detect_s: float = 80.0
    defense_kind: str = "percentile"    # percentile | trust_weighted
    sparsify_ratio: float = 1.0
    # async scheduling (consumed by build_async_engine only)
    staleness_adaptive: bool = False
    async_window: Optional[float] = None  # None => parity-safe auto window
    async_mixing: str = "sequential"      # sequential | buffered
    # data sizing
    samples_per_node: int = 60
    n_test: int = 256
    n_cloud_test: int = 128

    def with_nodes(self, n_nodes: int) -> "Scenario":
        return dataclasses.replace(self, n_nodes=n_nodes)

    def to_spec(self, kind: Optional[str] = None, rounds: int = 10,
                seed: int = 0, backend: str = "reference",
                mesh_devices: Optional[int] = None):
        """Emit the `api.ExperimentSpec` this scenario denotes.

        ``kind`` is the schedule ("sync" | "async" | "buffered"); None
        picks "sync", or the scenario's own async mixing when it declares
        async knobs.  ``mesh_devices`` selects a mesh topology.
        """
        from ..api import spec as s
        from ..api.window import AutoWindow, FixedWindow

        if kind is None:
            declares_async = (self.async_mixing != "sequential"
                              or self.async_window is not None
                              or self.staleness_adaptive)
            kind = self.async_kind() if declares_async else "sync"
        window = (FixedWindow(self.async_window)
                  if kind != "sync" and self.async_window is not None
                  else AutoWindow())
        topology = (s.Topology(kind="mesh", devices=mesh_devices,
                               backend=backend)
                    if mesh_devices is not None
                    else s.Topology(kind="single", backend=backend))
        return s.ExperimentSpec(
            fleet=s.FleetSpec(
                n_nodes=self.n_nodes,
                profile=s.NodeHeterogeneity(
                    base_compute_s=self.base_compute_s,
                    heterogeneity=self.heterogeneity,
                    bandwidth_bps=self.bandwidth_bps,
                    straggler_frac=self.straggler_frac,
                    straggler_slowdown=self.straggler_slowdown),
                attack=s.AttackMix(malicious_frac=self.malicious_frac,
                                   kind=self.attack_kind,
                                   placement=self.placement),
                availability=self.availability,
                cohort_frac=self.cohort_frac,
                model=self.model, hw=self.hw,
                samples_per_node=self.samples_per_node,
                n_test=self.n_test, n_cloud_test=self.n_cloud_test),
            schedule=s.SchedulePolicy(
                kind=kind, alpha=self.alpha,
                staleness_adaptive=(self.staleness_adaptive
                                    if kind != "sync" else False),
                window=window),
            privacy=s.PrivacySpec(sigma=self.sigma, clip_s=self.clip_s),
            compression=s.CompressionSpec(
                sparsify_ratio=self.sparsify_ratio),
            defense=s.DefenseSpec(detect=self.detect,
                                  detect_s=self.detect_s,
                                  kind=self.defense_kind),
            topology=topology,
            train=s.TrainSpec(local_steps=self.local_steps,
                              batch_size=self.batch_size, lr=self.lr),
            rounds=rounds, seed=seed)

    def async_kind(self) -> str:
        """The async schedule kind this scenario declares."""
        return "buffered" if self.async_mixing == "buffered" else "async"


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario("honest"),
    Scenario("label_flip_20", malicious_frac=0.2, detect=True),
    Scenario("stragglers", straggler_frac=0.2, straggler_slowdown=20.0),
    Scenario("churn", availability=0.7),
    Scenario("sampled_cohort", n_nodes=50, cohort_frac=0.2),
    Scenario("private_sparse", sigma=0.05, sparsify_ratio=0.1, detect=True),
    # adversary-zoo populations (api.AttackMix kinds + trust defense)
    Scenario("sybil_trust", malicious_frac=0.2, attack_kind="sybil",
             detect=True, defense_kind="trust_weighted"),
    Scenario("backdoor_20", malicious_frac=0.2, attack_kind="backdoor",
             detect=True),
    # asynchronous populations (run via build_async_engine)
    Scenario("async_stragglers", straggler_frac=0.2, straggler_slowdown=20.0,
             staleness_adaptive=True),
    Scenario("async_churn", availability=0.7),
    Scenario("async_label_flip", malicious_frac=0.2, detect=True),
    Scenario("async_adaptive_trust", malicious_frac=0.2,
             attack_kind="adaptive", detect=True,
             defense_kind="trust_weighted", staleness_adaptive=True),
    Scenario("async_buffered", async_mixing="buffered", async_window=2.0),
]}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have "
                       f"{sorted(SCENARIOS)}") from None


def _build(sc: Scenario, kind: str, seed: int, sampler, backend, mesh):
    """Scenario -> spec -> plan -> engine, with sampler/mesh overrides."""
    from .. import api

    spec = sc.to_spec(kind=kind, seed=seed, backend=backend)
    plan = api.compile_plan(spec)
    pop = api.materialize(spec)
    if sampler is not None:
        pop = dataclasses.replace(pop, sampler=sampler)
    return api.make_engine(plan, pop, mesh=mesh)


def build_engine(sc: Scenario, seed: int = 0,
                 sampler: Optional[ClientSampler] = None,
                 backend: str = "reference",
                 mesh: Optional["FleetMesh"] = None) -> FleetEngine:
    """Scenario -> FleetEngine on synthetic federated image data.

    ``mesh`` (a `fleet.FleetMesh`) shards the node axis across devices and
    runs the round under shard_map."""
    return _build(sc, "sync", seed, sampler, backend, mesh)


def build_async_engine(sc: Scenario, seed: int = 0,
                       sampler: Optional[ClientSampler] = None,
                       backend: str = "reference",
                       mesh: Optional["FleetMesh"] = None
                       ) -> AsyncFleetEngine:
    """Scenario -> AsyncFleetEngine (virtual-time arrival windows).

    `availability < 1` models mid-flight churn: arrivals from unavailable
    nodes are lost in transit (no mix, no detection entry) but the node is
    redispatched. `cohort_frac < 1` likewise gates arrivals per window to a
    sampled cohort (the async analogue of 'm of K' participation).
    """
    return _build(sc, sc.async_kind(), seed, sampler, backend, mesh)
