"""Declarative fleet scenarios: enumerate node populations, don't hand-wire.

A `Scenario` describes a whole population — size, adversary fraction,
straggler tail, availability/churn, cohort sampling, privacy/communication
knobs — and `build_engine` turns it into a ready-to-run `FleetEngine` on
synthetic federated data. Benchmarks, examples and tests pick scenarios by
name from `SCENARIOS` instead of re-assembling trainers by hand.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from ..core import detection
from ..data import make_federated_image_data
from ..models.cnn import cnn_accuracy, cnn_loss, init_cnn
from ..models.mlp import init_mlp, mlp_accuracy, mlp_loss
from .async_engine import AsyncFleetConfig, AsyncFleetEngine
from .engine import (AvailabilityTrace, ClientSampler, FleetConfig,
                     FleetEngine, FullParticipation, NodeProfile,
                     UniformSampler)
from .mesh import FleetMesh


@dataclass(frozen=True)
class Scenario:
    """One node population + training regime, fully declarative."""
    name: str
    n_nodes: int = 10
    # population composition
    malicious_frac: float = 0.0         # label-flipping adversaries (1 -> 7)
    straggler_frac: float = 0.0         # nodes with `straggler_slowdown`x compute
    straggler_slowdown: float = 10.0
    availability: float = 1.0           # per-round P(node is reachable)
    cohort_frac: float = 1.0            # uniform-C sampling fraction (<1)
    heterogeneity: float = 0.5          # lognormal sigma of compute speeds
    base_compute_s: float = 1.0
    bandwidth_bps: float = 12.5e6
    # training / privacy / communication
    model: str = "mlp"                  # mlp | cnn
    hw: Tuple[int, int] = (8, 8)
    local_steps: int = 5
    batch_size: int = 16
    lr: float = 0.1
    alpha: float = 0.5
    sigma: float = 0.0
    clip_s: float = 1.0
    detect: bool = False
    detect_s: float = 80.0
    sparsify_ratio: float = 1.0
    # async scheduling (consumed by build_async_engine only)
    staleness_adaptive: bool = False
    async_window: Optional[float] = None  # None => parity-safe auto window
    async_mixing: str = "sequential"      # sequential | buffered
    # data sizing
    samples_per_node: int = 60
    n_test: int = 256
    n_cloud_test: int = 128

    def with_nodes(self, n_nodes: int) -> "Scenario":
        return dataclasses.replace(self, n_nodes=n_nodes)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario("honest"),
    Scenario("label_flip_20", malicious_frac=0.2, detect=True),
    Scenario("stragglers", straggler_frac=0.2, straggler_slowdown=20.0),
    Scenario("churn", availability=0.7),
    Scenario("sampled_cohort", n_nodes=50, cohort_frac=0.2),
    Scenario("private_sparse", sigma=0.05, sparsify_ratio=0.1, detect=True),
    # asynchronous populations (run via build_async_engine)
    Scenario("async_stragglers", straggler_frac=0.2, straggler_slowdown=20.0,
             staleness_adaptive=True),
    Scenario("async_churn", availability=0.7),
    Scenario("async_label_flip", malicious_frac=0.2, detect=True),
    Scenario("async_buffered", async_mixing="buffered", async_window=2.0),
]}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have "
                       f"{sorted(SCENARIOS)}") from None


def _population(sc: Scenario, seed: int):
    """Scenario -> (params, loss_fn, acc_fn, node_data, test, cloud,
    profile): everything both engine builders share."""
    n_malicious = int(round(sc.malicious_frac * sc.n_nodes))
    node_data, test, cloud, _ = make_federated_image_data(
        seed, n_nodes=sc.n_nodes, n_malicious=n_malicious,
        n_train=sc.samples_per_node * sc.n_nodes, n_test=sc.n_test,
        n_cloud_test=sc.n_cloud_test, hw=sc.hw)

    key = jax.random.PRNGKey(seed)
    if sc.model == "cnn":
        params = init_cnn(key, in_hw=sc.hw)
        loss_fn, acc_fn = cnn_loss, cnn_accuracy
    else:
        params = init_mlp(key, in_dim=sc.hw[0] * sc.hw[1])
        loss_fn, acc_fn = mlp_loss, mlp_accuracy

    profile = NodeProfile.lognormal(
        sc.n_nodes, sc.base_compute_s, sc.heterogeneity, sc.bandwidth_bps,
        seed=seed, straggler_frac=sc.straggler_frac,
        straggler_slowdown=sc.straggler_slowdown)
    return params, loss_fn, acc_fn, node_data, test, cloud, profile


def build_engine(sc: Scenario, seed: int = 0,
                 sampler: Optional[ClientSampler] = None,
                 backend: str = "reference",
                 mesh: Optional["FleetMesh"] = None) -> FleetEngine:
    """Scenario -> FleetEngine on synthetic federated image data.

    ``mesh`` (a `fleet.FleetMesh`) shards the node axis across devices and
    runs the round under shard_map."""
    params, loss_fn, acc_fn, node_data, test, cloud, profile = \
        _population(sc, seed)
    cfg = FleetConfig(local_steps=sc.local_steps, batch_size=sc.batch_size,
                      lr=sc.lr, alpha=sc.alpha, clip_s=sc.clip_s,
                      sigma=sc.sigma, detect=sc.detect, detect_s=sc.detect_s,
                      sparsify_ratio=sc.sparsify_ratio, backend=backend,
                      seed=seed)

    if sampler is None:
        if sc.availability < 1.0:
            sampler = AvailabilityTrace(
                probs=np.full(sc.n_nodes, sc.availability), seed=seed)
        elif sc.cohort_frac < 1.0:
            sampler = UniformSampler(
                max(1, int(round(sc.cohort_frac * sc.n_nodes))), seed=seed)
        else:
            sampler = FullParticipation()

    return FleetEngine(params, loss_fn, acc_fn, node_data, test, cloud, cfg,
                       profile=profile, sampler=sampler, mesh=mesh)


def build_async_engine(sc: Scenario, seed: int = 0,
                       sampler: Optional[ClientSampler] = None,
                       backend: str = "reference",
                       mesh: Optional["FleetMesh"] = None
                       ) -> AsyncFleetEngine:
    """Scenario -> AsyncFleetEngine (virtual-time arrival windows).

    `availability < 1` models mid-flight churn: arrivals from unavailable
    nodes are lost in transit (no mix, no detection entry) but the node is
    redispatched. `cohort_frac < 1` likewise gates arrivals per window to a
    sampled cohort (the async analogue of 'm of K' participation).
    """
    params, loss_fn, acc_fn, node_data, test, cloud, profile = \
        _population(sc, seed)
    cfg = AsyncFleetConfig(
        local_steps=sc.local_steps, batch_size=sc.batch_size,
        lr=sc.lr, alpha=sc.alpha, clip_s=sc.clip_s,
        sigma=sc.sigma, detect=sc.detect, detect_s=sc.detect_s,
        sparsify_ratio=sc.sparsify_ratio, backend=backend, seed=seed,
        window=sc.async_window, mixing=sc.async_mixing,
        staleness_adaptive=sc.staleness_adaptive,
        detect_window=detection.default_window(sc.n_nodes))

    if sampler is None:
        if sc.availability < 1.0:
            sampler = AvailabilityTrace(
                probs=np.full(sc.n_nodes, sc.availability), seed=seed)
        elif sc.cohort_frac < 1.0:
            sampler = UniformSampler(
                max(1, int(round(sc.cohort_frac * sc.n_nodes))), seed=seed)

    return AsyncFleetEngine(params, loss_fn, acc_fn, node_data, test, cloud,
                            cfg, profile=profile, sampler=sampler, mesh=mesh)
