"""Stacked fleet state: every per-node quantity lives on a leading node axis.

The sequential `FederatedTrainer` keeps per-node state in Python lists
(`self.residuals`, `self.node_time`) and touches one node at a time. The
fleet engine instead stacks everything — residual pytrees, PRNG keys, data
shards — along axis 0 so a whole cohort moves through local SGD, ALDP and
detection in a single device program. This module is the stacking/indexing
layer: `FleetState` (a registered pytree), `FleetData` (padded per-node
shards), and gather/scatter helpers used to pull a sampled cohort out of the
fleet and write its updated state back.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# stacked-pytree primitives
# ---------------------------------------------------------------------------

def stack_trees(trees: Sequence):
    """[tree, tree, ...] -> one tree with a leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, n: int) -> List:
    """Inverse of :func:`stack_trees`."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def broadcast_tree(tree, n: int):
    """Tile a single tree along a new leading node axis of size ``n``."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def gather_nodes(tree, idx: jnp.ndarray):
    """Select rows ``idx`` of every leaf's leading node axis (fleet -> cohort)."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def scatter_nodes(tree, idx: jnp.ndarray, values):
    """Write cohort rows back into the fleet (inverse of gather).

    When ``idx`` contains duplicates (padded cohorts) the last write wins,
    which is correct because duplicated rows carry identical values.
    """
    return jax.tree.map(lambda x, v: x.at[idx].set(v), tree, values)


# ---------------------------------------------------------------------------
# FleetState
# ---------------------------------------------------------------------------

@dataclass
class FleetState:
    """Per-node training state, stacked along a leading node axis.

    Attributes:
      residuals: gradient-accumulation containers (§5.1), leaves (N, ...).
      chain_key: the engine's PRNG chain key () — advanced every round.
      round: host-side round counter (static metadata, not traced).

    The asynchronous engine additionally tracks (None for sync engines):
      dispatched: stacked params each node last received and trains from,
        leaves (N, ...) — asynchrony means nodes hold *stale* models.
      next_arrival: (N,) f32 virtual time each node's in-flight update
        finishes local compute (the event heap, vectorized).
      dispatched_version: (N,) i32 global-model version each node's
        in-flight update was trained from (staleness τ = version − this).
      version: () i32 global model version (increments per accepted mix).
      acc_ring: (W,) f32 streaming detection window of recent cloud-side
        accuracies (NaN = empty slot) — replaces the trainer's Python
        `acc_window` list; acc_count: () i32 total accuracies ever pushed
        (write cursor = acc_count % W).
    """
    residuals: object
    chain_key: jnp.ndarray
    round: int = 0
    dispatched: object = None
    next_arrival: Optional[jnp.ndarray] = None
    dispatched_version: Optional[jnp.ndarray] = None
    version: Optional[jnp.ndarray] = None
    acc_ring: Optional[jnp.ndarray] = None
    acc_count: Optional[jnp.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return jax.tree.leaves(self.residuals)[0].shape[0]


jax.tree_util.register_dataclass(
    FleetState,
    data_fields=["residuals", "chain_key", "dispatched", "next_arrival",
                 "dispatched_version", "version", "acc_ring", "acc_count"],
    meta_fields=["round"])


def init_fleet_state(template_params, n_nodes: int, key) -> FleetState:
    """Zero residuals for every node + the engine's starting chain key."""
    residuals = jax.tree.map(
        lambda x: jnp.zeros((n_nodes,) + x.shape, jnp.float32),
        template_params)
    return FleetState(residuals=residuals, chain_key=key, round=0)


def init_async_fleet_state(template_params, n_nodes: int, key,
                           first_arrival: np.ndarray,
                           detect_window: int) -> FleetState:
    """Async extension of :func:`init_fleet_state`: every node starts with
    the global model (version 0) in flight, arriving when its first local
    compute finishes; the detection ring starts empty."""
    st = init_fleet_state(template_params, n_nodes, key)
    return dataclasses.replace(
        st,
        dispatched=broadcast_tree(template_params, n_nodes),
        next_arrival=jnp.asarray(first_arrival, jnp.float32),
        dispatched_version=jnp.zeros((n_nodes,), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        acc_ring=jnp.full((detect_window,), jnp.nan, jnp.float32),
        acc_count=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# FleetData
# ---------------------------------------------------------------------------

@dataclass
class FleetData:
    """Per-node data shards stacked to (N, M, ...) with right-padding.

    ``sizes`` holds each node's true shard length so batched minibatch
    sampling (`randint(0, sizes[i])`) never touches padding — matching the
    sequential trainer's per-node `randint(0, len(x_i))` exactly when shards
    are unpadded.
    """
    x: jnp.ndarray          # (N, M, ...)
    y: jnp.ndarray          # (N, M)
    sizes: jnp.ndarray      # (N,) int32

    @property
    def n_nodes(self) -> int:
        return int(self.x.shape[0])

    @classmethod
    def from_node_data(cls, node_data: Sequence[Tuple[np.ndarray, np.ndarray]]
                       ) -> "FleetData":
        sizes = np.array([len(y) for _, y in node_data], np.int32)
        m = int(sizes.max())
        xs, ys = [], []
        for x, y in node_data:
            pad = m - len(y)
            x, y = np.asarray(x), np.asarray(y)
            if pad:
                x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
                y = np.concatenate([y, np.zeros((pad,), y.dtype)])
            xs.append(x)
            ys.append(y)
        return cls(x=jnp.asarray(np.stack(xs)), y=jnp.asarray(np.stack(ys)),
                   sizes=jnp.asarray(sizes))

    def gather(self, idx: jnp.ndarray) -> "FleetData":
        return FleetData(x=jnp.take(self.x, idx, axis=0),
                         y=jnp.take(self.y, idx, axis=0),
                         sizes=jnp.take(self.sizes, idx, axis=0))


jax.tree_util.register_dataclass(
    FleetData, data_fields=["x", "y", "sizes"], meta_fields=[])


# ---------------------------------------------------------------------------
# sequential-compatible key chain
# ---------------------------------------------------------------------------

def chain_node_keys(key, n: int):
    """Replicates the sequential trainer's per-node key chain, vectorized.

    The sequential loop does ``key, k1, k2 = split(key, 3)`` once per node;
    a `lax.scan` over the same split reproduces the identical key sequence
    in one traced program. Returns (advanced_key, k1s (n,2), k2s (n,2)).
    """
    def body(k, _):
        k, k1, k2 = jax.random.split(k, 3)
        return k, (k1, k2)

    key, (k1s, k2s) = jax.lax.scan(body, key, None, length=n)
    return key, k1s, k2s


def parallel_node_keys(key, n: int):
    """Order-independent key derivation: one split, no chain dependency."""
    key, sub = jax.random.split(key)
    ks = jax.random.split(sub, 2 * n)
    return key, ks[:n], ks[n:]


def _select_key(pred, a, b):
    """`jnp.where` that also works on new-style typed PRNG keys."""
    if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
        return jax.random.wrap_key_data(
            jnp.where(pred, jax.random.key_data(a), jax.random.key_data(b)),
            impl=jax.random.key_impl(a))
    return jnp.where(pred, a, b)


def chain_node_keys_masked(key, mask: jnp.ndarray):
    """:func:`chain_node_keys` that advances the chain only on True slots.

    The async engine processes a whole fleet-sized cohort each window but
    only the in-window arrivals consume PRNG keys (exactly as the sequential
    event loop splits 3-ways once per processed arrival); masked-out slots
    leave the chain untouched so the key sequence stays identical to the
    event loop's regardless of how arrivals bucket into windows. k1/k2 of
    masked-out slots are speculative splits — callers must not use them.
    """
    def body(k, m):
        nk, k1, k2 = jax.random.split(k, 3)
        return _select_key(m, nk, k), (k1, k2)

    key, (k1s, k2s) = jax.lax.scan(body, key, mask)
    return key, k1s, k2s
