"""Stacked fleet state: every per-node quantity lives on a leading node axis.

The sequential reference loop keeps per-node state in Python lists
(residuals, node compute times) and touches one node at a time. The
fleet engine instead stacks everything — residual pytrees, PRNG keys, data
shards — along axis 0 so a whole cohort moves through local SGD, ALDP and
detection in a single device program. This module is the stacking/indexing
layer: `FleetState` (a registered pytree), `FleetData` (padded per-node
shards), and gather/scatter helpers used to pull a sampled cohort out of the
fleet and write its updated state back.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# When true (or REPRO_FLEET_DEBUG=1), host-side `scatter_nodes` calls verify
# the duplicate-index contract (see `scatter_nodes`) instead of silently
# letting the last write win. Traced calls can't be checked and are skipped.
DEBUG_SCATTER = os.environ.get("REPRO_FLEET_DEBUG", "") not in ("", "0")


# ---------------------------------------------------------------------------
# stacked-pytree primitives
# ---------------------------------------------------------------------------

def stack_trees(trees: Sequence):
    """[tree, tree, ...] -> one tree with a leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, n: int) -> List:
    """Inverse of :func:`stack_trees`."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def broadcast_tree(tree, n: int):
    """Tile a single tree along a new leading node axis of size ``n``."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def gather_nodes(tree, idx: jnp.ndarray):
    """Select rows ``idx`` of every leaf's leading node axis (fleet -> cohort)."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def scatter_nodes(tree, idx: jnp.ndarray, values, *,
                  debug: Optional[bool] = None):
    """Write cohort rows back into the fleet (inverse of gather).

    Contract: when ``idx`` contains duplicates (padded cohorts that repeat a
    node), `.at[idx].set` resolves them last-write-wins — which is only
    correct if every duplicated slot carries **identical** values, i.e. the
    cohort rows for a repeated node are copies of one another. Callers that
    pad cohorts by repeating indices must therefore also duplicate the
    corresponding value rows (a `gather_nodes` of the same ``idx`` does this
    by construction).

    With ``debug=True`` (default: the module flag `DEBUG_SCATTER`, settable
    via ``REPRO_FLEET_DEBUG=1``) concrete (non-traced) calls verify the
    contract and raise ``ValueError`` on duplicated indices whose value rows
    differ. Traced calls (inside jit) cannot be checked and are skipped.
    """
    if debug is None:
        debug = DEBUG_SCATTER
    if debug and not isinstance(idx, jax.core.Tracer):
        _check_duplicate_scatter(idx, values)
    return jax.tree.map(lambda x, v: x.at[idx].set(v), tree, values)


def _check_duplicate_scatter(idx, values) -> None:
    """Raise if duplicated scatter indices carry differing value rows."""
    idx_h = np.asarray(idx)
    uniq, counts = np.unique(idx_h, return_counts=True)
    dups = uniq[counts > 1]
    if dups.size == 0:
        return
    for leaf in jax.tree.leaves(values):
        if isinstance(leaf, jax.core.Tracer):
            continue                    # traced leaf: cannot verify this one
        leaf_h = np.asarray(leaf)
        for u in dups:
            rows = leaf_h[idx_h == u]
            if not np.array_equal(rows, np.broadcast_to(rows[:1],
                                                        rows.shape)):
                raise ValueError(
                    f"scatter_nodes: duplicated index {int(u)} carries "
                    f"differing value rows — duplicate cohort slots must be "
                    f"identical copies (last write wins)")


# ---------------------------------------------------------------------------
# FleetState
# ---------------------------------------------------------------------------

@dataclass
class FleetState:
    """Per-node training state, stacked along a leading node axis.

    Attributes:
      residuals: gradient-accumulation containers (§5.1), leaves (N, ...).
      chain_key: the engine's PRNG chain key () — advanced every round.
      round: host-side round counter (static metadata, not traced).

    The asynchronous engine additionally tracks (None for sync engines):
      dispatched: stacked params each node last received and trains from,
        leaves (N, ...) — asynchrony means nodes hold *stale* models.
      next_arrival: (N,) f32 virtual time each node's in-flight update
        finishes local compute (the event heap, vectorized).
      dispatched_version: (N,) i32 global-model version each node's
        in-flight update was trained from (staleness τ = version − this).
      version: () i32 global model version (increments per accepted mix).
      acc_ring: (W,) f32 streaming detection window of recent cloud-side
        accuracies (NaN = empty slot) — replaces the trainer's Python
        `acc_window` list; acc_count: () i32 total accuracies ever pushed
        (write cursor = acc_count % W).

    The trust-scored defense and the adaptive attacker add two optional
    (N,) rings of their own (None unless the spec opts in — absent fields
    keep the default jitted programs byte-identical):
      trust: per-node trust scores in [0, 1], EWMA'd from detection
        verdicts (`detection.trust_update`), consumed as aggregation
        weights (`detection.trust_weights`).
      throttle: the detection-aware attacker's per-node poison scale —
        device-side adversary state, updated from the same verdicts.
    """
    residuals: object
    chain_key: jnp.ndarray
    round: int = 0
    dispatched: object = None
    next_arrival: Optional[jnp.ndarray] = None
    dispatched_version: Optional[jnp.ndarray] = None
    version: Optional[jnp.ndarray] = None
    acc_ring: Optional[jnp.ndarray] = None
    acc_count: Optional[jnp.ndarray] = None
    trust: Optional[jnp.ndarray] = None
    throttle: Optional[jnp.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return jax.tree.leaves(self.residuals)[0].shape[0]


jax.tree_util.register_dataclass(
    FleetState,
    data_fields=["residuals", "chain_key", "dispatched", "next_arrival",
                 "dispatched_version", "version", "acc_ring", "acc_count",
                 "trust", "throttle"],
    meta_fields=["round"])


def init_fleet_state(template_params, n_nodes: int, key, *,
                     trust: bool = False,
                     throttle: bool = False) -> FleetState:
    """Zero residuals for every node + the engine's starting chain key.
    ``trust``/``throttle`` allocate the optional (N,) defense/adversary
    rings (both start at full score/scale 1.0)."""
    residuals = jax.tree.map(
        lambda x: jnp.zeros((n_nodes,) + x.shape, jnp.float32),
        template_params)
    return FleetState(
        residuals=residuals, chain_key=key, round=0,
        trust=jnp.ones((n_nodes,), jnp.float32) if trust else None,
        throttle=jnp.ones((n_nodes,), jnp.float32) if throttle else None)


def init_async_fleet_state(template_params, n_nodes: int, key,
                           first_arrival: np.ndarray,
                           detect_window: int, *, trust: bool = False,
                           throttle: bool = False) -> FleetState:
    """Async extension of :func:`init_fleet_state`: every node starts with
    the global model (version 0) in flight, arriving when its first local
    compute finishes; the detection ring starts empty."""
    st = init_fleet_state(template_params, n_nodes, key, trust=trust,
                          throttle=throttle)
    return dataclasses.replace(
        st,
        dispatched=broadcast_tree(template_params, n_nodes),
        next_arrival=jnp.asarray(first_arrival, jnp.float32),
        dispatched_version=jnp.zeros((n_nodes,), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        acc_ring=jnp.full((detect_window,), jnp.nan, jnp.float32),
        acc_count=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# FleetData
# ---------------------------------------------------------------------------

@dataclass
class FleetData:
    """Per-node data shards stacked to (N, M, ...) with right-padding.

    ``sizes`` holds each node's true shard length so batched minibatch
    sampling (`randint(0, sizes[i])`) never touches padding — matching the
    sequential trainer's per-node `randint(0, len(x_i))` exactly when shards
    are unpadded.
    """
    x: jnp.ndarray          # (N, M, ...)
    y: jnp.ndarray          # (N, M)
    sizes: jnp.ndarray      # (N,) int32

    @property
    def n_nodes(self) -> int:
        return int(self.x.shape[0])

    @classmethod
    def from_node_data(cls, node_data: Sequence[Tuple[np.ndarray, np.ndarray]]
                       ) -> "FleetData":
        if len(node_data) == 0:
            raise ValueError("FleetData.from_node_data: empty node list — "
                             "a fleet needs at least one node shard")
        sizes = np.array([len(y) for _, y in node_data], np.int32)
        if (sizes == 0).any():
            empty = np.nonzero(sizes == 0)[0].tolist()
            raise ValueError(
                f"FleetData.from_node_data: node(s) {empty} have empty data "
                f"shards; every node needs at least one sample (batched "
                f"minibatch sampling draws indices in [0, size))")
        m = int(sizes.max())
        xs, ys = [], []
        for x, y in node_data:
            pad = m - len(y)
            x, y = np.asarray(x), np.asarray(y)
            if pad:
                x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
                y = np.concatenate([y, np.zeros((pad,), y.dtype)])
            xs.append(x)
            ys.append(y)
        return cls(x=jnp.asarray(np.stack(xs)), y=jnp.asarray(np.stack(ys)),
                   sizes=jnp.asarray(sizes))

    def gather(self, idx: jnp.ndarray) -> "FleetData":
        return FleetData(x=jnp.take(self.x, idx, axis=0),
                         y=jnp.take(self.y, idx, axis=0),
                         sizes=jnp.take(self.sizes, idx, axis=0))

    def pad_to(self, n_total: int) -> "FleetData":
        """Append dummy nodes up to `n_total` rows (mesh shard multiples).

        Padding nodes carry a single zero sample (``sizes=1``) so batched
        `randint(0, size)` minibatch sampling stays well defined; sharded
        engines mask them out of every aggregate, so their (garbage)
        updates never land anywhere.
        """
        pad = n_total - self.n_nodes
        if pad < 0:
            raise ValueError(f"pad_to: fleet already has {self.n_nodes} "
                             f"nodes > requested {n_total}")
        if pad == 0:
            return self
        x = jnp.concatenate(
            [self.x, jnp.zeros((pad,) + self.x.shape[1:], self.x.dtype)])
        y = jnp.concatenate(
            [self.y, jnp.zeros((pad,) + self.y.shape[1:], self.y.dtype)])
        sizes = jnp.concatenate(
            [self.sizes, jnp.ones((pad,), self.sizes.dtype)])
        return FleetData(x=x, y=y, sizes=sizes)


jax.tree_util.register_dataclass(
    FleetData, data_fields=["x", "y", "sizes"], meta_fields=[])


def pad_node_axis(tree, n_total: int):
    """Zero-pad every leaf's leading node axis up to ``n_total`` rows —
    the stacked-pytree analogue of `FleetData.pad_to`, used to grow
    residual/dispatched stacks to a mesh shard multiple."""
    def one(x):
        pad = n_total - x.shape[0]
        if pad < 0:
            raise ValueError(f"pad_node_axis: leading axis {x.shape[0]} "
                             f"> requested {n_total}")
        if pad == 0:
            return x
        return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])

    return jax.tree.map(one, tree)


def pad_keys(keys: jnp.ndarray, n_total: int) -> jnp.ndarray:
    """Pad a stacked per-node PRNG-key array to ``n_total`` rows by
    repeating the last real key — padding rows only ever feed masked-out
    dummy computations, but must still be *valid* keys."""
    n = keys.shape[0]
    return jnp.take(keys, jnp.minimum(jnp.arange(n_total), n - 1), axis=0)


# ---------------------------------------------------------------------------
# sequential-compatible key chain
# ---------------------------------------------------------------------------

def chain_node_keys(key, n: int):
    """Replicates the sequential trainer's per-node key chain, vectorized.

    The sequential loop does ``key, k1, k2 = split(key, 3)`` once per node;
    a `lax.scan` over the same split reproduces the identical key sequence
    in one traced program. Returns (advanced_key, k1s (n,2), k2s (n,2)).
    """
    def body(k, _):
        k, k1, k2 = jax.random.split(k, 3)
        return k, (k1, k2)

    key, (k1s, k2s) = jax.lax.scan(body, key, None, length=n)
    return key, k1s, k2s


def parallel_node_keys(key, n: int):
    """Order-independent key derivation: one split, no chain dependency."""
    key, sub = jax.random.split(key)
    ks = jax.random.split(sub, 2 * n)
    return key, ks[:n], ks[n:]


def _select_key(pred, a, b):
    """`jnp.where` that also works on new-style typed PRNG keys."""
    if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
        return jax.random.wrap_key_data(
            jnp.where(pred, jax.random.key_data(a), jax.random.key_data(b)),
            impl=jax.random.key_impl(a))
    return jnp.where(pred, a, b)


def chain_node_keys_masked(key, mask: jnp.ndarray):
    """:func:`chain_node_keys` that advances the chain only on True slots.

    The async engine processes a whole fleet-sized cohort each window but
    only the in-window arrivals consume PRNG keys (exactly as the sequential
    event loop splits 3-ways once per processed arrival); masked-out slots
    leave the chain untouched so the key sequence stays identical to the
    event loop's regardless of how arrivals bucket into windows. k1/k2 of
    masked-out slots are speculative splits — callers must not use them.
    """
    def body(k, m):
        nk, k1, k2 = jax.random.split(k, 3)
        return _select_key(m, nk, k), (k1, k2)

    key, (k1s, k2s) = jax.lax.scan(body, key, mask)
    return key, k1s, k2s
