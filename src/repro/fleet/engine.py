"""FleetEngine: cohort-batched federated rounds — one dispatch per round.

The sequential reference loop dispatches each node separately per round, so
wall-clock at fleet scale is dominated by Python dispatch, not math. The
engine stacks the whole cohort along a leading node axis and runs

  local SGD -> delta -> [DGC sparsify] -> [ALDP clip+noise]
            -> cloud detection (Alg. 2) -> masked aggregate -> Eq. (6) mix

as a single jitted program per round: `jax.vmap` over nodes of a
`lax.scan`-ed local-SGD body, with cohort gather/scatter of the stacked
residual state folded into the same program.

Pluggable pieces:
  * client sampling — `FullParticipation`, `UniformSampler` (paper's
    "m of K nodes"), `AvailabilityTrace` (availability/churn traces);
  * per-node compute/bandwidth via `NodeProfile` (replaces the seed
    implementation's scalar `node_time` array);
  * upload-pipeline backend — "reference" (pure-jnp `accumulator`/`aldp`,
    bit-compatible with the sequential reference loop) or "pallas" (the fused
    `sparsify`/`ldp_noise` kernels in node-batched form).

With `key_mode="sequential"` the engine reproduces the sequential reference
loop's per-node PRNG chain exactly (see `state.chain_node_keys`), which is
how the api's single-device sync path stays numerically faithful to the seed
implementation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import async_update, detection
from ..obs import WINDOW_SIZE_EDGES, get_tracer, timed_stage
from . import mesh as mesh_lib
from . import stages
from .mesh import FleetMesh, MeshStateIO
from .stages import detect_masked  # noqa: F401  (public re-export)
from .state import (FleetState, chain_node_keys, gather_nodes,
                    init_fleet_state, pad_keys, parallel_node_keys)


# ---------------------------------------------------------------------------
# client sampling
# ---------------------------------------------------------------------------

class ClientSampler:
    """Selects each round's cohort.

    `cohort(round_idx, n_nodes)` returns (idx (C,), valid (C,)) with a
    *static* C so every round reuses one compiled program; padded slots are
    marked invalid and contribute nothing (their residual writes are
    dropped, their accuracies are excluded from detection).
    """

    def cohort(self, round_idx: int, n_nodes: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


class FullParticipation(ClientSampler):
    """Every node, every round (the paper's synchronous barrier)."""

    def cohort(self, round_idx, n_nodes):
        return np.arange(n_nodes), np.ones(n_nodes, bool)


class UniformSampler(ClientSampler):
    """Uniform-C sampling without replacement (FedAvg's 'm of K' cohorts)."""

    def __init__(self, cohort_size: int, seed: int = 0):
        self.cohort_size = int(cohort_size)
        self.rng = np.random.default_rng(seed)

    def cohort(self, round_idx, n_nodes):
        c = min(self.cohort_size, n_nodes)
        idx = self.rng.choice(n_nodes, size=c, replace=False)
        return idx, np.ones(c, bool)


class AvailabilityTrace(ClientSampler):
    """Availability/churn model: node k answers round r with prob p_k (or
    per an explicit (rounds, N) boolean trace). Unavailable slots are padded
    so the compiled cohort size stays N."""

    def __init__(self, probs: Optional[np.ndarray] = None,
                 trace: Optional[np.ndarray] = None, seed: int = 0):
        if (probs is None) == (trace is None):
            raise ValueError("give exactly one of probs= or trace=")
        self.probs = None if probs is None else np.asarray(probs, np.float64)
        self.trace = None if trace is None else np.asarray(trace, bool)
        self.rng = np.random.default_rng(seed)

    def cohort(self, round_idx, n_nodes):
        src = self.trace if self.trace is not None else self.probs
        width = src.shape[-1]
        if width < n_nodes:
            raise ValueError(
                f"availability {'trace' if self.trace is not None else 'probs'}"
                f" covers {width} nodes but the fleet has {n_nodes}")
        if self.trace is not None:
            up = self.trace[round_idx % len(self.trace)][:n_nodes]
        else:
            up = self.rng.random(n_nodes) < self.probs[:n_nodes]
        if not up.any():              # never let a round starve entirely
            up = up.copy()
            up[self.rng.integers(n_nodes)] = True
        return np.arange(n_nodes), up


# ---------------------------------------------------------------------------
# per-node system model
# ---------------------------------------------------------------------------

@dataclass
class NodeProfile:
    """Per-node compute time and uplink bandwidth (replaces the trainer's
    scalar `node_time` array with an explicit, extensible system model)."""
    compute_s: np.ndarray          # (N,) seconds per local round
    bandwidth_bps: np.ndarray      # (N,) uplink bytes/s

    @classmethod
    def lognormal(cls, n_nodes: int, base_compute_s: float,
                  heterogeneity: float, bandwidth_bps: float,
                  seed: int = 0, straggler_frac: float = 0.0,
                  straggler_slowdown: float = 10.0) -> "NodeProfile":
        """The trainer's lognormal speed model + optional straggler tail."""
        rng = np.random.default_rng(seed)
        comp = base_compute_s * np.exp(rng.normal(0.0, heterogeneity, n_nodes))
        n_strag = int(round(straggler_frac * n_nodes))
        if n_strag:
            comp[rng.choice(n_nodes, n_strag, replace=False)] *= \
                straggler_slowdown
        bw = np.full(n_nodes, float(bandwidth_bps))
        return cls(compute_s=comp, bandwidth_bps=bw)

    def round_times(self, idx: np.ndarray, valid: np.ndarray,
                    bytes_per_node: float) -> Tuple[float, float]:
        """(comp, comm) for a synchronous cohort round: the barrier waits on
        the slowest participant; uplinks run in parallel."""
        sel = idx[valid]
        if sel.size == 0:
            return 0.0, 0.0
        comp = float(self.compute_s[sel].max())
        comm = float((bytes_per_node / self.bandwidth_bps[sel]).max())
        return comp, comm


# ---------------------------------------------------------------------------
# config + records
# ---------------------------------------------------------------------------

@dataclass
class FleetConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.05
    alpha: float = 0.5              # Eq. (6)
    clip_s: float = 1.0
    sigma: float = 0.0              # noise multiplier (0 disables ALDP)
    detect: bool = True
    detect_s: float = 80.0
    sparsify_ratio: float = 1.0
    key_mode: str = "parallel"      # parallel | sequential (seed-loop parity)
    backend: str = "reference"      # reference (jnp) | pallas (fused kernels)
    seed: int = 0
    # trust-scored defense (api.DefenseSpec.kind="trust_weighted"): verdict-
    # EWMA trust per node, trust/uncertainty-weighted aggregation
    defense_kind: str = "percentile"   # percentile | trust_weighted
    trust_eta: float = 0.25
    trust_floor: float = 0.05
    uncertainty_scale: float = 4.0

    @property
    def trust_on(self) -> bool:
        return self.detect and self.defense_kind == "trust_weighted"


@dataclass
class FleetRoundRecord:
    t: float                        # simulated wall clock
    round: int
    accuracy: float                 # global model on the test set
    comm_bytes: float               # total cohort upload bytes
    comp_time: float
    comm_time: float
    n_participating: int
    n_rejected: int                 # participants rejected by detection


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class FleetEngine(MeshStateIO):
    """Cohort-batched synchronous FEL over a stacked node fleet.

    Args:
      init_params: global model pytree ω_0.
      loss_fn: (params, batch{x,y}) -> (loss, aux).
      acc_fn: (params, x, y) -> scalar accuracy.
      node_data: per-node (x, y) shards (list) or a prebuilt `FleetData`.
      test_data: (x, y) for global accuracy; cloud_test: detection set (§5.4).
      cfg: `FleetConfig`.
      profile: `NodeProfile` (defaults to a homogeneous 1 s / 100 Mbit fleet).
      sampler: `ClientSampler` (defaults to `FullParticipation`).
      mesh: optional `FleetMesh` — shard the node axis across its devices
        and run the round under `shard_map` (node-parallel local SGD /
        upload pipeline per shard, detection + aggregation over collectives).
        The node axis is padded to a shard multiple; padding rows never
        participate. Sequential-chain PRNG parity with the single-device
        engine holds for arange-style cohorts (`FullParticipation`,
        `AvailabilityTrace`): the sharded round consumes one chain split per
        node in node order, exactly like an arange cohort. `UniformSampler`
        cohorts still run correctly but consume the chain in node order
        instead of cohort order.
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data, test_data, cloud_test, cfg: FleetConfig,
                 profile: Optional[NodeProfile] = None,
                 sampler: Optional[ClientSampler] = None,
                 mesh: Optional[FleetMesh] = None,
                 net=None, tracer=None, attack=None):
        self.cfg = cfg
        # per-round events/metrics go to the injected tracer, else whatever
        # global one `api.run` scoped in (disabled -> all no-ops); the jitted
        # round already returns accs/mask/thr, so tracing needs no program
        # change and cannot perturb numerics
        self.obs = tracer if tracer is not None else get_tracer()
        self.params = init_params
        self.loss_fn = loss_fn
        self.acc_fn = jax.jit(acc_fn)
        (self.data, self.n_nodes, self.test_data, self.cloud_test,
         self.profile, self.n_params) = stages.init_engine_common(
            init_params, node_data, test_data, cloud_test, profile)
        self.sampler = sampler or FullParticipation()
        self.mesh = mesh
        self.net = net          # Optional[repro.net.NetSim]: wire codecs +
                                # link sim replace the analytic comm model
        self.attack = attack    # Optional[stages.AttackPlan]: adversary zoo
        self.n_pad = mesh.padded(self.n_nodes) if mesh else self.n_nodes
        self.state = init_fleet_state(
            init_params, self.n_pad, jax.random.PRNGKey(cfg.seed),
            trust=cfg.trust_on,
            throttle=attack is not None and attack.needs_throttle)
        self.history: List[FleetRoundRecord] = []
        # barrier-clock origin: run_round continues from the last record's
        # t, or from here when the history is empty (repro.sim sets this on
        # checkpoint restore so the resumed clock doesn't restart at zero)
        self._t0 = 0.0
        if mesh is not None:
            self.data = mesh.put_nodes(self.data.pad_to(self.n_pad))
            self.state = dataclasses.replace(
                self.state, residuals=mesh.put_nodes(self.state.residuals),
                chain_key=mesh.put_replicated(self.state.chain_key),
                trust=(mesh.put_nodes(self.state.trust)
                       if self.state.trust is not None else None),
                throttle=(mesh.put_nodes(self.state.throttle)
                          if self.state.throttle is not None else None))
            self.params = mesh.put_replicated(self.params)
            self._round_fn = jax.jit(self._build_round_sharded())
        else:
            self._round_fn = jax.jit(self._build_round())

    # -- per-node upload bytes (wire format: values, or values+indices) -----
    def bytes_per_node(self) -> float:
        return stages.bytes_per_node(self.n_params, self.cfg.sparsify_ratio)

    # -- the single-dispatch round ------------------------------------------
    def _build_round(self):
        cfg = self.cfg
        raw_acc_fn = self.acc_fn
        cloud_x, cloud_y = self.cloud_test
        local_train = stages.make_local_train(self.loss_fn, cfg.local_steps,
                                              cfg.lr, cfg.batch_size)
        need_nnz = self.net is not None     # byte-accurate pricing only
        attack_stage = stages.make_delta_attack(self.attack)
        mal_full = (self.attack.mask(self.n_pad)
                    if attack_stage is not None else None)

        def round_fn(params, residuals, chain_key, trust, throttle,
                     x, y, sizes, idx, valid):
            c = idx.shape[0]
            xg = jnp.take(x, idx, axis=0)
            yg = jnp.take(y, idx, axis=0)
            sz = jnp.take(sizes, idx, axis=0)
            res_c = gather_nodes(residuals, idx)

            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys(chain_key, c)
            else:
                chain_key, k1s, k2s = parallel_node_keys(chain_key, c)

            local = jax.vmap(local_train, in_axes=(None, 0, 0, 0, 0))(
                params, xg, yg, sz, k1s)
            deltas = jax.tree.map(lambda l, g: l - g[None].astype(l.dtype),
                                  local, params)
            if attack_stage is not None:
                mal_c = jnp.take(mal_full, idx)
                thr_c = (jnp.take(throttle, idx)
                         if throttle is not None else None)
                deltas = attack_stage(deltas, mal_c, thr_c)
            deltas, res_c, nnz = stages.upload_pipeline(cfg, deltas, res_c,
                                                        k2s,
                                                        need_nnz=need_nnz)

            # cloud side: rebuild node models, test, detect, aggregate, mix
            omegas, accs = stages.rebuild_and_evaluate(
                raw_acc_fn, params, deltas, cloud_x, cloud_y)
            if cfg.detect:
                mask, thr = detect_masked(accs, valid, cfg.detect_s)
            else:
                mask, thr = valid, jnp.zeros((), jnp.float32)
            if trust is not None:
                trust_c = jnp.take(trust, idx)
                w = detection.trust_weights(
                    trust_c, accs, mask, cfg.trust_floor,
                    cfg.uncertainty_scale)
                omega_mean = detection.masked_weighted_mean(omegas, mask, w)
            else:
                omega_mean = detection.masked_mean(omegas, mask)
            new_params = async_update.mix(params, omega_mean, cfg.alpha)

            # write cohort residuals back; padded slots scatter out of bounds
            # and are dropped
            drop_idx = jnp.where(valid, idx, self.n_nodes)
            residuals = jax.tree.map(
                lambda full, part: full.at[drop_idx].set(part, mode="drop"),
                residuals, res_c)
            if trust is not None:
                trust_c = detection.trust_update(
                    jnp.take(trust, idx), mask, valid, cfg.trust_eta)
                trust = trust.at[drop_idx].set(trust_c, mode="drop")
            if throttle is not None:
                thr_new = stages.adaptive_throttle_update(
                    jnp.take(throttle, idx), valid & ~mask, valid,
                    self.attack.adapt_poison_scale)
                throttle = throttle.at[drop_idx].set(thr_new, mode="drop")
            m = {"accs": accs, "mask": mask, "thr": thr}
            if need_nnz:
                m["nnz"] = nnz
            return new_params, residuals, chain_key, trust, throttle, m

        return round_fn

    # -- the sharded round: one shard_map over the node mesh ----------------
    def _build_round_sharded(self):
        """The round as a `shard_map` program over the node mesh.

        Each device trains its shard of nodes (local SGD -> DGC -> ALDP ->
        cloud eval) with no communication; detection needs the global
        accuracy set, so the (n_pad,) accuracies are `all_gather`-ed and
        thresholded replicated; the masked-mean aggregate is a per-shard
        partial sum + `psum`. Cohorts arrive as a per-node participation
        mask instead of an index list — gather/scatter of cohort rows
        across shards is thereby avoided entirely for the synchronous
        barrier (every padded slot simply trains and is masked out).
        """
        cfg = self.cfg
        mesh = self.mesh
        raw_acc_fn = self.acc_fn
        cloud_x, cloud_y = self.cloud_test
        local_train = stages.make_local_train(self.loss_fn, cfg.local_steps,
                                              cfg.lr, cfg.batch_size)
        n, n_pad, d, axis = self.n_nodes, self.n_pad, mesh.n_devices, mesh.axis
        need_nnz = self.net is not None     # byte-accurate pricing only
        attack_stage = stages.make_delta_attack(self.attack)
        mal_full = (self.attack.mask(n_pad)
                    if attack_stage is not None else None)

        def round_body(params, residuals, chain_key, trust, throttle,
                       x, y, sizes, valid, cx, cy):
            # local leaves: residuals/x/y/sizes/valid lead with B = n_pad/d
            # keys are derived over the *true* node count then padded, so
            # both modes yield the exact per-node streams the single-device
            # engine draws for an arange cohort (padding rows reuse the last
            # real key for their masked-out dummy updates)
            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys(chain_key, n)
            else:
                chain_key, k1s, k2s = parallel_node_keys(chain_key, n)
            k1s, k2s = pad_keys(k1s, n_pad), pad_keys(k2s, n_pad)
            k1 = mesh_lib.my_block(k1s, axis, d)
            k2 = mesh_lib.my_block(k2s, axis, d)

            local = jax.vmap(local_train, in_axes=(None, 0, 0, 0, 0))(
                params, x, y, sizes, k1)
            deltas = jax.tree.map(lambda l, g: l - g[None].astype(l.dtype),
                                  local, params)
            if attack_stage is not None:
                # the attack stage is shard-oblivious: per-node row scaling
                # on this device's block of the (replicated) malicious mask
                mal_blk = mesh_lib.my_block(mal_full, axis, d)
                deltas = attack_stage(deltas, mal_blk, throttle)
            deltas, res_new, nnz = stages.upload_pipeline(
                cfg, deltas, residuals, k2, need_nnz=need_nnz)
            omegas, accs = stages.rebuild_and_evaluate(
                raw_acc_fn, params, deltas, cx, cy)

            # cloud side, replicated: global accuracy set -> Alg. 2 mask
            accs_all = jax.lax.all_gather(accs, axis, tiled=True)
            valid_all = jax.lax.all_gather(valid, axis, tiled=True)
            if cfg.detect:
                mask_all, thr = detect_masked(accs_all, valid_all,
                                              cfg.detect_s)
            else:
                mask_all, thr = valid_all, jnp.zeros((), jnp.float32)
            mask = mesh_lib.my_block(mask_all, axis, d)

            if trust is not None:
                # trust/uncertainty weights against the globally-reduced
                # accepted-mean accuracy (every shard shares the anchor)
                m_all = mask_all.astype(jnp.float32)
                ref = ((accs_all.astype(jnp.float32) * m_all).sum()
                       / jnp.maximum(m_all.sum(), 1.0))
                w = mask.astype(jnp.float32) * detection.trust_weights(
                    trust, accs, mask, cfg.trust_floor,
                    cfg.uncertainty_scale, ref=ref)
                total = jax.lax.psum(w.sum(), axis)
                denom = jnp.where(total > 0, total, 1.0)
            else:
                # masked mean: per-shard weighted partial sums + psum
                w = mask.astype(jnp.float32)
                denom = jnp.maximum(jax.lax.psum(w.sum(), axis), 1.0)

            def agg(o):
                wf = w.reshape((-1,) + (1,) * (o.ndim - 1))
                return jax.lax.psum((o.astype(jnp.float32) * wf).sum(0),
                                    axis) / denom

            omega_mean = jax.tree.map(agg, omegas)
            new_params = async_update.mix(params, omega_mean, cfg.alpha)

            # participants' residuals advance; everyone else's stay put
            residuals = jax.tree.map(
                lambda old, new: jnp.where(
                    valid.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
                residuals, res_new)
            if trust is not None:
                trust = detection.trust_update(trust, mask, valid,
                                               cfg.trust_eta)
            if throttle is not None:
                throttle = stages.adaptive_throttle_update(
                    throttle, valid & ~mask, valid,
                    self.attack.adapt_poison_scale)
            m = {"accs": accs_all, "mask": mask_all, "thr": thr}
            if need_nnz:
                m["nnz"] = jax.lax.all_gather(nnz, axis, tiled=True)
            return new_params, residuals, chain_key, trust, throttle, m

        pn, pr = mesh.spec_nodes(), mesh.spec_replicated()
        m_specs = {"accs": pr, "mask": pr, "thr": pr}
        if need_nnz:
            m_specs["nnz"] = pr
        return mesh.shard_map(
            round_body,
            in_specs=(pr, pn, pr, pn, pn, pn, pn, pn, pn, pr, pr),
            out_specs=(pr, pn, pr, pn, pn, m_specs))

    # -- host-side driver ---------------------------------------------------
    def run_round(self) -> FleetRoundRecord:
        cfg = self.cfg
        tr = self.obs
        r = self.state.round
        span = tr.span("round", round=r)
        span.__enter__()
        idx, valid = self.sampler.cohort(r, self.n_nodes)
        with timed_stage(tr, "round.device", round=r) as st:
            if self.mesh is not None:
                up = self._participation_mask(idx, valid)
                (self.params, residuals, chain_key, trust, throttle,
                 m) = self._round_fn(
                    self.params, self.state.residuals, self.state.chain_key,
                    self.state.trust, self.state.throttle,
                    self.data.x, self.data.y, self.data.sizes,
                    self.mesh.put_nodes(jnp.asarray(up)), *self.cloud_test)
            else:
                (self.params, residuals, chain_key, trust, throttle,
                 m) = self._round_fn(
                    self.params, self.state.residuals, self.state.chain_key,
                    self.state.trust, self.state.throttle,
                    self.data.x, self.data.y, self.data.sizes,
                    jnp.asarray(idx, jnp.int32), jnp.asarray(valid))
            st.fence((self.params, m))
        self.state = FleetState(residuals=residuals, chain_key=chain_key,
                                round=r + 1, trust=trust, throttle=throttle)

        n_part = int(valid.sum())
        if self.mesh is not None:   # sharded mask is per-node over n_pad
            n_rejected = int((up & ~np.asarray(m["mask"])).sum())
        else:
            n_rejected = int((np.asarray(valid)
                              & ~np.asarray(m["mask"])).sum())
        bpn = self.bytes_per_node()
        comp, comm = self.profile.round_times(np.asarray(idx),
                                              np.asarray(valid), bpn)
        comm_bytes = bpn * n_part
        if self.net is not None:
            # byte-accurate path: the round's measured nonzero counts price
            # each participant's upload through the wire codec; the link
            # model's per-upload transfer times replace the analytic uplink
            # (parallel uploads — the barrier waits on the slowest)
            if self.mesh is not None:       # nnz is per-node over n_pad
                sel_nodes = np.flatnonzero(up[:self.n_nodes])
                nnz_sel = np.asarray(m["nnz"])[sel_nodes]
            else:                           # nnz is in cohort (idx) order
                valid_np = np.asarray(valid)
                sel_nodes = np.asarray(idx)[valid_np]
                nnz_sel = np.asarray(m["nnz"])[valid_np]
            flood = self.attack.flood_uploads if self.attack else 0
            with timed_stage(tr, "net.draw", round=r) as st:
                draw = self.net.draw(sel_nodes, extra_concurrency=flood)
            with timed_stage(tr, "net.commit", round=r) as st:
                enc = self.net.commit(draw, nnz_sel, ctx={"round": r})
            comm = float(draw.transfer_s.max()) if sel_nodes.size else 0.0
            comm_bytes = float(enc.sum())
        t_prev = self.history[-1].t if self.history else self._t0
        with timed_stage(tr, "round.evaluate", round=r) as st:
            accuracy = self.global_accuracy()
        rec = FleetRoundRecord(
            t=t_prev + comp + comm, round=r,
            accuracy=accuracy, comm_bytes=comm_bytes,
            comp_time=comp, comm_time=comm, n_participating=n_part,
            n_rejected=n_rejected)
        self.history.append(rec)
        if tr.enabled:
            self._emit_round_events(rec, idx, valid, m, up if self.mesh
                                    is not None else None)
        span.set(n_participating=n_part, n_rejected=n_rejected)
        span.set_virtual(t_prev, rec.t)
        span.__exit__(None, None, None)
        return rec

    def _emit_round_events(self, rec: FleetRoundRecord, idx, valid, m,
                           up) -> None:
        """Per-participant detection audit (one `detect.verdict` instant per
        cloud evaluation, Alg. 2's batch top-s form) + round metrics — the
        trace alone reconstructs Fig. 6's per-round rejection series."""
        tr = self.obs
        thr = float(np.asarray(m["thr"]))
        accs = np.asarray(m["accs"])
        mask = np.asarray(m["mask"])
        if up is not None:          # sharded: node-order arrays over n_pad
            nodes = np.flatnonzero(up[:self.n_nodes])
            accs, mask = accs[nodes], mask[nodes]
        else:                       # single-device: cohort (idx) order
            valid_np = np.asarray(valid)
            nodes = np.asarray(idx)[valid_np]
            accs, mask = accs[valid_np], mask[valid_np]
        for i, node in enumerate(nodes):
            tr.instant("detect.verdict", virt_t=rec.t, node=int(node),
                       round=rec.round, accuracy=float(accs[i]),
                       threshold=thr, rejected=bool(~mask[i]),
                       detect=bool(self.cfg.detect))
        mx = tr.metrics
        if self.cfg.detect and nodes.size and detection.detect_fell_back(
                accs, thr):
            # the all-equal guard accepted everyone — the exact state a
            # detection-aware attacker forces; auditable from the trace
            mx.counter("detect.fallback").inc()
        mx.histogram("round.size", WINDOW_SIZE_EDGES).observe(
            rec.n_participating)
        mx.counter("round.participants").inc(rec.n_participating)
        mx.counter("round.rejected").inc(rec.n_rejected)
        mx.counter("round.comm_bytes").inc(rec.comm_bytes)
        mx.gauge("model.accuracy").set(rec.accuracy)

    def run(self, rounds: int) -> List[FleetRoundRecord]:
        for _ in range(rounds):
            self.run_round()
        return self.history

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    def kappa(self) -> float:
        """Eq. (5) over the whole run."""
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)
