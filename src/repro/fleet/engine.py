"""FleetEngine: cohort-batched federated rounds — one dispatch per round.

The sequential `FederatedTrainer` calls `_node_update` K times per round, so
wall-clock at fleet scale is dominated by Python dispatch, not math. The
engine stacks the whole cohort along a leading node axis and runs

  local SGD -> delta -> [DGC sparsify] -> [ALDP clip+noise]
            -> cloud detection (Alg. 2) -> masked aggregate -> Eq. (6) mix

as a single jitted program per round: `jax.vmap` over nodes of a
`lax.scan`-ed local-SGD body, with cohort gather/scatter of the stacked
residual state folded into the same program.

Pluggable pieces:
  * client sampling — `FullParticipation`, `UniformSampler` (paper's
    "m of K nodes"), `AvailabilityTrace` (availability/churn traces);
  * per-node compute/bandwidth via `NodeProfile` (replaces the trainer's
    scalar `node_time` array);
  * upload-pipeline backend — "reference" (pure-jnp `accumulator`/`aldp`,
    bit-compatible with the sequential trainer) or "pallas" (the fused
    `sparsify`/`ldp_noise` kernels in node-batched form).

With `key_mode="sequential"` the engine reproduces the sequential trainer's
per-node PRNG chain exactly (see `state.chain_node_keys`), which is how the
rewired `FederatedTrainer` sync path stays numerically faithful to the seed
implementation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import accumulator as accum
from ..core import aldp, async_update, detection
from .state import (FleetData, FleetState, chain_node_keys, gather_nodes,
                    init_fleet_state, parallel_node_keys)


# ---------------------------------------------------------------------------
# client sampling
# ---------------------------------------------------------------------------

class ClientSampler:
    """Selects each round's cohort.

    `cohort(round_idx, n_nodes)` returns (idx (C,), valid (C,)) with a
    *static* C so every round reuses one compiled program; padded slots are
    marked invalid and contribute nothing (their residual writes are
    dropped, their accuracies are excluded from detection).
    """

    def cohort(self, round_idx: int, n_nodes: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


class FullParticipation(ClientSampler):
    """Every node, every round (the paper's synchronous barrier)."""

    def cohort(self, round_idx, n_nodes):
        return np.arange(n_nodes), np.ones(n_nodes, bool)


class UniformSampler(ClientSampler):
    """Uniform-C sampling without replacement (FedAvg's 'm of K' cohorts)."""

    def __init__(self, cohort_size: int, seed: int = 0):
        self.cohort_size = int(cohort_size)
        self.rng = np.random.default_rng(seed)

    def cohort(self, round_idx, n_nodes):
        c = min(self.cohort_size, n_nodes)
        idx = self.rng.choice(n_nodes, size=c, replace=False)
        return idx, np.ones(c, bool)


class AvailabilityTrace(ClientSampler):
    """Availability/churn model: node k answers round r with prob p_k (or
    per an explicit (rounds, N) boolean trace). Unavailable slots are padded
    so the compiled cohort size stays N."""

    def __init__(self, probs: Optional[np.ndarray] = None,
                 trace: Optional[np.ndarray] = None, seed: int = 0):
        if (probs is None) == (trace is None):
            raise ValueError("give exactly one of probs= or trace=")
        self.probs = None if probs is None else np.asarray(probs, np.float64)
        self.trace = None if trace is None else np.asarray(trace, bool)
        self.rng = np.random.default_rng(seed)

    def cohort(self, round_idx, n_nodes):
        src = self.trace if self.trace is not None else self.probs
        width = src.shape[-1]
        if width < n_nodes:
            raise ValueError(
                f"availability {'trace' if self.trace is not None else 'probs'}"
                f" covers {width} nodes but the fleet has {n_nodes}")
        if self.trace is not None:
            up = self.trace[round_idx % len(self.trace)][:n_nodes]
        else:
            up = self.rng.random(n_nodes) < self.probs[:n_nodes]
        if not up.any():              # never let a round starve entirely
            up = up.copy()
            up[self.rng.integers(n_nodes)] = True
        return np.arange(n_nodes), up


# ---------------------------------------------------------------------------
# per-node system model
# ---------------------------------------------------------------------------

@dataclass
class NodeProfile:
    """Per-node compute time and uplink bandwidth (replaces the trainer's
    scalar `node_time` array with an explicit, extensible system model)."""
    compute_s: np.ndarray          # (N,) seconds per local round
    bandwidth_bps: np.ndarray      # (N,) uplink bytes/s

    @classmethod
    def lognormal(cls, n_nodes: int, base_compute_s: float,
                  heterogeneity: float, bandwidth_bps: float,
                  seed: int = 0, straggler_frac: float = 0.0,
                  straggler_slowdown: float = 10.0) -> "NodeProfile":
        """The trainer's lognormal speed model + optional straggler tail."""
        rng = np.random.default_rng(seed)
        comp = base_compute_s * np.exp(rng.normal(0.0, heterogeneity, n_nodes))
        n_strag = int(round(straggler_frac * n_nodes))
        if n_strag:
            comp[rng.choice(n_nodes, n_strag, replace=False)] *= \
                straggler_slowdown
        bw = np.full(n_nodes, float(bandwidth_bps))
        return cls(compute_s=comp, bandwidth_bps=bw)

    def round_times(self, idx: np.ndarray, valid: np.ndarray,
                    bytes_per_node: float) -> Tuple[float, float]:
        """(comp, comm) for a synchronous cohort round: the barrier waits on
        the slowest participant; uplinks run in parallel."""
        sel = idx[valid]
        if sel.size == 0:
            return 0.0, 0.0
        comp = float(self.compute_s[sel].max())
        comm = float((bytes_per_node / self.bandwidth_bps[sel]).max())
        return comp, comm


# ---------------------------------------------------------------------------
# config + records
# ---------------------------------------------------------------------------

@dataclass
class FleetConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.05
    alpha: float = 0.5              # Eq. (6)
    clip_s: float = 1.0
    sigma: float = 0.0              # noise multiplier (0 disables ALDP)
    detect: bool = True
    detect_s: float = 80.0
    sparsify_ratio: float = 1.0
    key_mode: str = "parallel"      # parallel | sequential (trainer-compat)
    backend: str = "reference"      # reference (jnp) | pallas (fused kernels)
    seed: int = 0


@dataclass
class FleetRoundRecord:
    t: float                        # simulated wall clock
    round: int
    accuracy: float                 # global model on the test set
    comm_bytes: float               # total cohort upload bytes
    comp_time: float
    comm_time: float
    n_participating: int
    n_rejected: int                 # participants rejected by detection


# ---------------------------------------------------------------------------
# masked detection (Alg. 2 over a partially-valid cohort)
# ---------------------------------------------------------------------------

def detect_masked(accs: jnp.ndarray, valid: jnp.ndarray, s: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 2 with padded slots excluded: threshold is the top-s percentile
    of the *valid* accuracies; reduces to `detection.detect` when all slots
    are valid."""
    masked = jnp.where(valid, accs.astype(jnp.float32), jnp.nan)
    thr = jnp.nanpercentile(masked, s)
    mask = (accs > thr) & valid
    mask = jnp.where(mask.any(), mask, (accs >= thr) & valid)
    return mask, thr


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class FleetEngine:
    """Cohort-batched synchronous FEL over a stacked node fleet.

    Args:
      init_params: global model pytree ω_0.
      loss_fn: (params, batch{x,y}) -> (loss, aux).
      acc_fn: (params, x, y) -> scalar accuracy.
      node_data: per-node (x, y) shards (list) or a prebuilt `FleetData`.
      test_data: (x, y) for global accuracy; cloud_test: detection set (§5.4).
      cfg: `FleetConfig`.
      profile: `NodeProfile` (defaults to a homogeneous 1 s / 100 Mbit fleet).
      sampler: `ClientSampler` (defaults to `FullParticipation`).
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data, test_data, cloud_test, cfg: FleetConfig,
                 profile: Optional[NodeProfile] = None,
                 sampler: Optional[ClientSampler] = None):
        self.cfg = cfg
        self.params = init_params
        self.loss_fn = loss_fn
        self.acc_fn = jax.jit(acc_fn)
        self.data = (node_data if isinstance(node_data, FleetData)
                     else FleetData.from_node_data(node_data))
        self.n_nodes = self.data.n_nodes
        self.test_data = (jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))
        self.cloud_test = (jnp.asarray(cloud_test[0]),
                           jnp.asarray(cloud_test[1]))
        self.profile = profile or NodeProfile(
            compute_s=np.ones(self.n_nodes),
            bandwidth_bps=np.full(self.n_nodes, 12.5e6))
        self.sampler = sampler or FullParticipation()
        self.state = init_fleet_state(init_params, self.n_nodes,
                                      jax.random.PRNGKey(cfg.seed))
        self.n_params = sum(x.size for x in jax.tree.leaves(init_params))
        self.history: List[FleetRoundRecord] = []
        self._round_fn = jax.jit(self._build_round())

    # -- per-node upload bytes (wire format: values, or values+indices) -----
    def bytes_per_node(self) -> float:
        r = self.cfg.sparsify_ratio
        if r >= 1.0:
            return self.n_params * 4
        return int(self.n_params * r) * 8

    # -- the single-dispatch round ------------------------------------------
    def _build_round(self):
        cfg = self.cfg
        loss_fn = self.loss_fn
        raw_acc_fn = self.acc_fn
        cloud_x, cloud_y = self.cloud_test

        def local_train(params, x, y, size, key):
            """Node-local minibatch SGD; identical math/key-use to the
            sequential trainer's `_local_train_impl` (bounds from `size`,
            not the padded shard length)."""
            def body(p, k):
                idx = jax.random.randint(k, (cfg.batch_size,), 0, size)
                batch = {"x": x[idx], "y": y[idx]}
                g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
                return jax.tree.map(lambda a, b: a - cfg.lr * b, p, g), None

            keys = jax.random.split(key, cfg.local_steps)
            p, _ = jax.lax.scan(body, params, keys)
            return p

        def upload_pipeline(deltas, residuals_c, k2s):
            """[DGC accumulate+sparsify] -> [ALDP clip+noise], cohort-batched."""
            if cfg.sparsify_ratio < 1.0:
                if cfg.backend == "pallas":
                    deltas, residuals_c = _sparsify_pallas_cohort(
                        deltas, residuals_c, cfg.sparsify_ratio)
                else:
                    deltas, residuals_c, _ = jax.vmap(
                        lambda r, d: accum.accumulate_and_sparsify(
                            r, d, cfg.sparsify_ratio))(residuals_c, deltas)
            if cfg.sigma > 0.0:
                if cfg.backend == "pallas":
                    deltas = _aldp_pallas_cohort(deltas, k2s, cfg.sigma,
                                                 cfg.clip_s)
                else:
                    deltas = jax.vmap(
                        lambda d, k: aldp.aldp_perturb(d, k, cfg.sigma,
                                                       cfg.clip_s)[0]
                    )(deltas, k2s)
            return deltas, residuals_c

        def round_fn(params, residuals, chain_key, x, y, sizes, idx, valid):
            c = idx.shape[0]
            xg = jnp.take(x, idx, axis=0)
            yg = jnp.take(y, idx, axis=0)
            sz = jnp.take(sizes, idx, axis=0)
            res_c = gather_nodes(residuals, idx)

            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys(chain_key, c)
            else:
                chain_key, k1s, k2s = parallel_node_keys(chain_key, c)

            local = jax.vmap(local_train, in_axes=(None, 0, 0, 0, 0))(
                params, xg, yg, sz, k1s)
            deltas = jax.tree.map(lambda l, g: l - g[None].astype(l.dtype),
                                  local, params)
            deltas, res_c = upload_pipeline(deltas, res_c, k2s)

            # cloud side: rebuild node models, test, detect, aggregate, mix
            omegas = jax.tree.map(lambda g, d: g[None].astype(d.dtype) + d,
                                  params, deltas)
            accs = jax.vmap(lambda p: raw_acc_fn(p, cloud_x, cloud_y))(omegas)
            if cfg.detect:
                mask, thr = detect_masked(accs, valid, cfg.detect_s)
            else:
                mask, thr = valid, jnp.zeros((), jnp.float32)
            omega_mean = detection.masked_mean(omegas, mask)
            new_params = async_update.mix(params, omega_mean, cfg.alpha)

            # write cohort residuals back; padded slots scatter out of bounds
            # and are dropped
            drop_idx = jnp.where(valid, idx, self.n_nodes)
            residuals = jax.tree.map(
                lambda full, part: full.at[drop_idx].set(part, mode="drop"),
                residuals, res_c)
            return new_params, residuals, chain_key, {
                "accs": accs, "mask": mask, "thr": thr}

        return round_fn

    # -- host-side driver ---------------------------------------------------
    def run_round(self) -> FleetRoundRecord:
        cfg = self.cfg
        r = self.state.round
        idx, valid = self.sampler.cohort(r, self.n_nodes)
        self.params, residuals, chain_key, m = self._round_fn(
            self.params, self.state.residuals, self.state.chain_key,
            self.data.x, self.data.y, self.data.sizes,
            jnp.asarray(idx, jnp.int32), jnp.asarray(valid))
        self.state = FleetState(residuals=residuals, chain_key=chain_key,
                                round=r + 1)

        n_part = int(valid.sum())
        n_rejected = int((np.asarray(valid) & ~np.asarray(m["mask"])).sum())
        bpn = self.bytes_per_node()
        comp, comm = self.profile.round_times(np.asarray(idx),
                                              np.asarray(valid), bpn)
        t_prev = self.history[-1].t if self.history else 0.0
        rec = FleetRoundRecord(
            t=t_prev + comp + comm, round=r,
            accuracy=self.global_accuracy(), comm_bytes=bpn * n_part,
            comp_time=comp, comm_time=comm, n_participating=n_part,
            n_rejected=n_rejected)
        self.history.append(rec)
        return rec

    def run(self, rounds: int) -> List[FleetRoundRecord]:
        for _ in range(rounds):
            self.run_round()
        return self.history

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    def kappa(self) -> float:
        """Eq. (5) over the whole run."""
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)


# ---------------------------------------------------------------------------
# pallas-backed cohort upload pipeline
# ---------------------------------------------------------------------------

def _flatten_cohort(tree):
    """Stacked tree with leading cohort axis -> ((C, P) flat, unflatten)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    flat = jnp.concatenate([l.reshape(l.shape[0], -1).astype(jnp.float32)
                            for l in leaves], axis=1)

    def unflatten(f):
        out, off = [], 0
        for shape, size, leaf in zip(shapes, sizes, leaves):
            out.append(f[:, off:off + size].reshape((f.shape[0],) + shape)
                       .astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def _sparsify_pallas_cohort(deltas, residuals, ratio: float):
    """Per-leaf DGC split via the node-batched `sparsify_fleet` kernel —
    same per-leaf quantile threshold rule as `accum.accumulate_and_sparsify`,
    but one kernel launch per leaf for the whole cohort."""
    from ..kernels.sparsify import sparsify_fleet

    def one_leaf(d, r):
        c = d.shape[0]
        df = d.reshape(c, -1).astype(jnp.float32)
        rf = r.reshape(c, -1).astype(jnp.float32)
        comb = df + rf
        thr = jax.vmap(lambda v: accum.leaf_threshold(v, ratio))(comb)
        up, newr = sparsify_fleet(df, rf, thr)
        return up.reshape(d.shape).astype(d.dtype), newr.reshape(r.shape)

    pairs = jax.tree.map(one_leaf, deltas, residuals)
    up = jax.tree.map(lambda p: p[0], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    newr = jax.tree.map(lambda p: p[1], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    return up, newr


def _aldp_pallas_cohort(deltas, k2s, sigma: float, clip_s: float):
    """Cohort ALDP via the node-batched `ldp_perturb_fleet` kernel: whole-
    delta clip scale per node, in-kernel Gaussian noise (node-distinct
    seeds folded from the per-node PRNG keys)."""
    from ..kernels.ldp_noise import ldp_perturb_fleet

    flat, unflatten = _flatten_cohort(deltas)
    norms = jnp.sqrt(jnp.sum(jnp.square(flat), axis=1))
    scales = 1.0 / jnp.maximum(1.0, norms / clip_s)
    raw = k2s
    if jnp.issubdtype(k2s.dtype, jax.dtypes.prng_key):   # new-style typed keys
        raw = jax.random.key_data(k2s)
    seeds = (raw[:, 0] ^ raw[:, -1]).astype(jnp.int32)
    out = ldp_perturb_fleet(flat, seeds, scales, sigma, clip_s)
    return unflatten(out)
