"""AsyncFleetEngine: the paper's asynchronous scheme, one dispatch per window.

The sequential `FederatedTrainer._run_async` event loop pops one heap event
at a time and runs one Python-dispatched node update per arrival — O(K)
dispatches per simulated round, dispatch-bound past a few dozen nodes. This
engine vectorizes the event queue itself: per-node virtual clocks
(`FleetState.next_arrival`), dispatched model versions and the streaming
detection window all live on device, and each step

  1. selects the *arrival window*: every in-flight update landing inside
     [t0, t0 + window) where t0 is the earliest pending arrival;
  2. runs the shared upload pipeline (local SGD from each node's stale
     dispatched params -> DGC sparsify -> ALDP) node-batched via
     `fleet.stages` — one device program for the whole window;
  3. folds the window into the global model:
       * ``mixing="sequential"`` — a `lax.scan` over arrival order applying
         Eq. (6) (`async_update.mix`) or the FedAsync staleness-adaptive
         `mix_stale` per arrival, with the device-side accuracy ring buffer
         (`core.detection.ring_*`) reproducing the event loop's sliding
         `acc_window` detection exactly;
       * ``mixing="buffered"`` — FedBuff-style: detect against the window
         once, then mix the masked mean of accepted arrivals in one Eq. (6)
         step (cheaper, coarser — diverges from the event loop by design);
  4. redispatches each processed node with the model it would have received
     from the cloud (sequential: the global model right after its own
     arrival was handled) and advances its clock by uplink + compute time.

With ``window=None`` (auto) the window length is min node compute time, so
no node processed in a window can re-arrive inside it — arrivals are handled
in exactly the event loop's global time order, and with
``key_mode="sequential"`` + `chain_node_keys_masked` the PRNG chain is
consumed identically. That is the *parity mode* the rewired
`FederatedTrainer._run_async` runs in (tested float-close in
tests/test_async_fleet.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import async_update, detection
from . import stages
from .engine import ClientSampler, FleetConfig, NodeProfile
from .state import (FleetState, chain_node_keys_masked, gather_nodes,
                    init_async_fleet_state, parallel_node_keys)


@dataclass
class AsyncFleetConfig(FleetConfig):
    """`FleetConfig` + the asynchronous scheduler knobs."""
    window: Optional[float] = None  # virtual-time window length; None =>
                                    # min node compute time (parity-safe:
                                    # preserves event-loop arrival order)
    mixing: str = "sequential"      # sequential (scan of Eq. 6/mix_stale)
                                    # | buffered (FedBuff-style masked mean)
    staleness_adaptive: bool = False
    staleness_a: float = 0.5        # FedAsync polynomial exponent
    detect_warmup: int = 4          # arrivals observed before detecting
    detect_window: int = 8          # accuracy ring-buffer capacity


@dataclass
class AsyncWindowRecord:
    t: float                        # simulated clock at window end
    window: int                     # window index
    version: int                    # global model version after the window
    accuracy: float                 # global model on the test set
    comm_bytes: float               # total window upload bytes
    comp_time: float                # summed node compute time in the window
    comm_time: float                # summed uplink time in the window
    n_processed: int                # arrivals handled this window
    n_rejected: int                 # arrivals rejected by detection
    max_staleness: int              # max τ = version − dispatched_version


class AsyncFleetEngine:
    """Event-driven async FEL over a stacked node fleet, batched per window.

    Args mirror `FleetEngine`; `sampler` (optional) models churn: a node
    whose arrival lands in a window while the sampler marks it unavailable
    loses that upload (no mix, no detection entry) but is redispatched —
    mid-flight churn rather than cohort sampling.
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data, test_data, cloud_test, cfg: AsyncFleetConfig,
                 profile: Optional[NodeProfile] = None,
                 sampler: Optional[ClientSampler] = None):
        self.cfg = cfg
        self.params = init_params
        self.loss_fn = loss_fn
        self.acc_fn = jax.jit(acc_fn)
        (self.data, self.n_nodes, self.test_data, self.cloud_test,
         self.profile, self.n_params) = stages.init_engine_common(
            init_params, node_data, test_data, cloud_test, profile)
        self.sampler = sampler
        self._bpn = stages.bytes_per_node(self.n_params, cfg.sparsify_ratio)
        # per-node uplink + compute, fixed over the run (device copies feed
        # the jitted clock update; float64 host copies feed window selection)
        self._comm_s = np.asarray(self._bpn / self.profile.bandwidth_bps,
                                  np.float64)
        self._comp_s = np.asarray(self.profile.compute_s, np.float64)
        self._window_len = (cfg.window if cfg.window is not None
                            else float(self._comp_s.min()))
        if self._window_len <= 0:
            raise ValueError(f"window must be positive, got "
                             f"{self._window_len}")
        self.state = init_async_fleet_state(
            init_params, self.n_nodes, jax.random.PRNGKey(cfg.seed),
            first_arrival=self._comp_s, detect_window=cfg.detect_window)
        self._window_idx = 0
        self.history: List[AsyncWindowRecord] = []
        self._window_fn = jax.jit(self._build_window())

    # -- the single-dispatch arrival window ---------------------------------
    def _build_window(self):
        cfg = self.cfg
        raw_acc_fn = self.acc_fn
        cloud_x, cloud_y = self.cloud_test
        local_train = stages.make_local_train(self.loss_fn, cfg.local_steps,
                                              cfg.lr, cfg.batch_size)
        comm_s = jnp.asarray(self._comm_s, jnp.float32)
        comp_s = jnp.asarray(self._comp_s, jnp.float32)
        n = self.n_nodes

        def sequential_fold(params, version, ring, count, omegas, accs,
                            vdisp_c, arrived):
            """Eq. (6)/mix_stale over arrival order with streaming
            detection — the event loop, as one lax.scan."""

            def body(carry, inp):
                params, version, ring, count = carry
                omega_i, acc_i, vdisp_i, arr_i = inp
                r2, c2 = detection.ring_push(ring, count, acc_i)
                ring = jnp.where(arr_i, r2, ring)
                count = jnp.where(arr_i, c2, count)
                if cfg.detect:
                    rej = arr_i & detection.ring_detect(
                        ring, count, acc_i, cfg.detect_s, cfg.detect_warmup)
                else:
                    rej = jnp.zeros((), bool)
                tau = version - vdisp_i
                if cfg.staleness_adaptive:
                    mixed = async_update.mix_stale(params, omega_i, cfg.alpha,
                                                   tau, cfg.staleness_a)
                else:
                    mixed = async_update.mix(params, omega_i, cfg.alpha)
                do_mix = arr_i & ~rej
                params = jax.tree.map(lambda m, p: jnp.where(do_mix, m, p),
                                      mixed, params)
                version = version + do_mix.astype(jnp.int32)
                return ((params, version, ring, count),
                        (params, version, rej, tau))

            (params, version, ring, count), (p_seq, v_seq, rej, taus) = \
                jax.lax.scan(body, (params, version, ring, count),
                             (omegas, accs, vdisp_c, arrived))
            return params, version, ring, count, p_seq, v_seq, rej, taus

        def buffered_fold(params, version, ring, count, omegas, accs,
                          vdisp_c, arrived):
            """FedBuff-style: one detection pass over the updated window,
            one masked-mean Eq. (6) mix for the whole buffer."""

            def push(carry, inp):
                ring, count = carry
                acc_i, arr_i = inp
                r2, c2 = detection.ring_push(ring, count, acc_i)
                return (jnp.where(arr_i, r2, ring),
                        jnp.where(arr_i, c2, count)), None

            version0 = version
            (ring, count), _ = jax.lax.scan(push, (ring, count),
                                            (accs, arrived))
            if cfg.detect:
                thr = detection.ring_threshold(ring, count, cfg.detect_s)
                held = jnp.minimum(count, ring.shape[0])
                rej = arrived & (held >= cfg.detect_warmup) & (accs <= thr)
            else:
                rej = jnp.zeros_like(arrived)
            mask = arrived & ~rej
            omega_mean = detection.masked_mean(omegas, mask)
            mixed = async_update.mix(params, omega_mean, cfg.alpha)
            any_mix = mask.any()
            params = jax.tree.map(lambda m, p: jnp.where(any_mix, m, p),
                                  mixed, params)
            version = version + any_mix.astype(jnp.int32)
            taus = version0 - vdisp_c         # staleness at mix time
            # every processed node receives the post-window model/version
            c = vdisp_c.shape[0]
            p_seq = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), params)
            v_seq = jnp.broadcast_to(version, (c,))
            return params, version, ring, count, p_seq, v_seq, rej, taus

        def window_fn(params, state: FleetState, x, y, sizes,
                      order, proc, avail):
            """order: node ids sorted by (arrival time, node id), truncated
            to the compute bucket (in-window arrivals are a prefix of the
            sort, so the host passes the smallest power-of-two cohort
            covering them — one compiled program per bucket size); proc:
            in-window flags (sorted positions); avail: churn mask."""
            t_arr = jnp.take(state.next_arrival, order)
            vdisp_c = jnp.take(state.dispatched_version, order)
            disp_c = gather_nodes(state.dispatched, order)
            res_c = gather_nodes(state.residuals, order)
            xg = jnp.take(x, order, axis=0)
            yg = jnp.take(y, order, axis=0)
            sz = jnp.take(sizes, order, axis=0)

            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys_masked(
                    state.chain_key, proc)
            else:
                chain_key, k1s, k2s = parallel_node_keys(state.chain_key,
                                                         order.shape[0])

            local = jax.vmap(local_train)(disp_c, xg, yg, sz, k1s)
            deltas = jax.tree.map(lambda l, d: l - d.astype(l.dtype),
                                  local, disp_c)
            deltas, res_c = stages.upload_pipeline(cfg, deltas, res_c, k2s)
            omegas, accs = stages.rebuild_and_evaluate(
                raw_acc_fn, disp_c, deltas, cloud_x, cloud_y)

            arrived = proc & avail
            fold = (sequential_fold if cfg.mixing == "sequential"
                    else buffered_fold)
            params, version, ring, count, p_seq, v_seq, rej, taus = fold(
                params, state.version, state.acc_ring, state.acc_count,
                omegas, accs, vdisp_c, arrived)

            # redispatch: processed nodes get the model right after their
            # own slot (sequential) / the post-window model (buffered), the
            # matching version, and a fresh clock = arrival + uplink + next
            # local compute. Untouched slots scatter out of bounds.
            drop_idx = jnp.where(proc, order, n)
            scatter = lambda full, part: jax.tree.map(
                lambda f, p: f.at[drop_idx].set(p, mode="drop"), full, part)
            dispatched = scatter(state.dispatched, p_seq)
            residuals = scatter(state.residuals, res_c)
            dv = state.dispatched_version.at[drop_idx].set(v_seq, mode="drop")
            t_next = t_arr + jnp.take(comm_s, order) + jnp.take(comp_s, order)
            na = state.next_arrival.at[drop_idx].set(t_next, mode="drop")

            new_state = dataclasses.replace(
                state, residuals=residuals, chain_key=chain_key,
                dispatched=dispatched, next_arrival=na,
                dispatched_version=dv, version=version, acc_ring=ring,
                acc_count=count)
            metrics = {
                "n_rejected": (rej & arrived).sum(),
                "max_staleness": jnp.where(arrived, taus, 0).max(),
            }
            return params, new_state, metrics

        return window_fn

    # -- host-side driver ---------------------------------------------------
    def select_window(self, max_arrivals: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(order, proc): node ids sorted by (arrival, id) and in-window
        flags — every pending arrival inside [t0, t0 + window)."""
        na = np.asarray(self.state.next_arrival, np.float64)
        order = np.lexsort((np.arange(self.n_nodes), na))
        proc = na[order] < na[order[0]] + self._window_len
        if max_arrivals is not None:
            proc &= np.cumsum(proc) <= max_arrivals
        # in-window arrivals are a prefix of the sort: truncate the cohort
        # to the smallest power-of-two bucket covering them so the device
        # program only trains nodes that can arrive (one compile per bucket;
        # floored at 16 — small fleets get a single full-size program)
        c = 16
        while c < int(proc.sum()):
            c *= 2
        c = min(c, self.n_nodes)
        return order[:c], proc[:c]

    def run_window(self, max_arrivals: Optional[int] = None,
                   evaluate: bool = True) -> AsyncWindowRecord:
        """Process one arrival window. `evaluate=False` skips the global
        test-set accuracy (recorded as NaN) — callers that only consume
        accuracy at coarser boundaries (the trainer: once per n_nodes
        arrivals) avoid a test forward pass + device sync per window."""
        w = self._window_idx
        order, proc = self.select_window(max_arrivals)
        t_arr = np.asarray(self.state.next_arrival, np.float64)[order]
        if self.sampler is not None:
            # cohort() returns (idx, valid) aligned to idx; fold it into a
            # per-node availability mask (a node absent from the cohort, or
            # present but invalid, loses arrivals this window)
            idx_s, up = self.sampler.cohort(w, self.n_nodes)
            up_by_node = np.zeros(self.n_nodes, bool)
            up_by_node[np.asarray(idx_s)[np.asarray(up)]] = True
            avail = up_by_node[order]
        else:
            avail = np.ones(order.size, bool)

        self.params, self.state, m = self._window_fn(
            self.params, self.state, self.data.x, self.data.y,
            self.data.sizes, jnp.asarray(order, jnp.int32),
            jnp.asarray(proc), jnp.asarray(avail))
        self._window_idx = w + 1

        # host-side clock/traffic accounting over the processed arrivals
        sel = order[proc]
        t_arrive = t_arr[proc] + self._comm_s[sel]  # arrival + uplink times
        bpn = self._bpn
        rec = AsyncWindowRecord(
            t=float(t_arrive.max()) if sel.size else 0.0,
            window=w, version=int(self.state.version),
            accuracy=self.global_accuracy() if evaluate else float("nan"),
            comm_bytes=float(bpn * sel.size),
            comp_time=float(self._comp_s[sel].sum()),
            comm_time=float(self._comm_s[sel].sum()),
            n_processed=int(sel.size),
            n_rejected=int(m["n_rejected"]),
            max_staleness=int(m["max_staleness"]))
        self.history.append(rec)
        return rec

    def run(self, windows: int) -> List[AsyncWindowRecord]:
        for _ in range(windows):
            self.run_window()
        return self.history

    def run_arrivals(self, total: int) -> List[AsyncWindowRecord]:
        """Process exactly `total` arrivals (the trainer's rounds×nodes
        budget), truncating the final window."""
        done = 0
        while done < total:
            done += self.run_window(max_arrivals=total - done).n_processed
        return self.history

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    def kappa(self) -> float:
        """Eq. (5) over the whole run (per-arrival totals)."""
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)
