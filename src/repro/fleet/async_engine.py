"""AsyncFleetEngine: the paper's asynchronous scheme, one dispatch per window.

The sequential reference event loop (`api` Topology('sequential')) pops one
heap event
at a time and runs one Python-dispatched node update per arrival — O(K)
dispatches per simulated round, dispatch-bound past a few dozen nodes. This
engine vectorizes the event queue itself: per-node virtual clocks
(`FleetState.next_arrival`), dispatched model versions and the streaming
detection window all live on device, and each step

  1. selects the *arrival window*: every in-flight update landing inside
     [t0, t0 + window) where t0 is the earliest pending arrival;
  2. runs the shared upload pipeline (local SGD from each node's stale
     dispatched params -> DGC sparsify -> ALDP) node-batched via
     `fleet.stages` — one device program for the whole window;
  3. folds the window into the global model:
       * ``mixing="sequential"`` — a `lax.scan` over arrival order applying
         Eq. (6) (`async_update.mix`) or the FedAsync staleness-adaptive
         `mix_stale` per arrival, with the device-side accuracy ring buffer
         (`core.detection.ring_*`) reproducing the event loop's sliding
         `acc_window` detection exactly;
       * ``mixing="buffered"`` — FedBuff-style: detect against the window
         once, then mix the masked mean of accepted arrivals in one Eq. (6)
         step (cheaper, coarser — diverges from the event loop by design);
  4. redispatches each processed node with the model it would have received
     from the cloud (sequential: the global model right after its own
     arrival was handled) and advances its clock by uplink + compute time.

With ``window=None`` (auto) the window length is min node compute time, so
no node processed in a window can re-arrive inside it — arrivals are handled
in exactly the event loop's global time order, and with
``key_mode="sequential"`` + `chain_node_keys_masked` the PRNG chain is
consumed identically. That is the *parity mode* the api's single-device
async path runs in (tested float-close in tests/test_async_fleet.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import async_update, detection
from ..obs import (STALENESS_EDGES, WINDOW_SIZE_EDGES, get_tracer,
                   timed_stage)
from . import mesh as mesh_lib
from . import stages
from .engine import ClientSampler, FleetConfig, NodeProfile
from .mesh import FleetMesh, MeshStateIO
from .state import (FleetState, chain_node_keys_masked, gather_nodes,
                    init_async_fleet_state, parallel_node_keys)


@dataclass
class AsyncFleetConfig(FleetConfig):
    """`FleetConfig` + the asynchronous scheduler knobs."""
    window: Optional[float] = None  # virtual-time window length; None =>
                                    # min node compute time (parity-safe:
                                    # preserves event-loop arrival order)
    mixing: str = "sequential"      # sequential (scan of Eq. 6/mix_stale)
                                    # | buffered (FedBuff-style masked mean)
    staleness_adaptive: bool = False
    staleness_a: float = 0.5        # FedAsync polynomial exponent
    detect_warmup: int = 4          # arrivals observed before detecting
    detect_window: int = 8          # accuracy ring-buffer capacity


@dataclass
class AsyncWindowRecord:
    t: float                        # simulated clock at window end
    window: int                     # window index
    version: int                    # global model version after the window
    accuracy: float                 # global model on the test set
    comm_bytes: float               # total window upload bytes
    comp_time: float                # summed node compute time in the window
    comm_time: float                # summed uplink time in the window
    n_processed: int                # arrivals handled this window
    n_rejected: int                 # arrivals rejected by detection
    max_staleness: int              # max τ = version − dispatched_version


def make_window_folds(cfg: "AsyncFleetConfig", need_audit: bool = False):
    """(sequential_fold, buffered_fold) — the window-to-global-model mixing
    programs, shared between the single-device window and the mesh-sharded
    window (where they run replicated on every device after the in-window
    arrival set has been `all_gather`-ed).

    Both folds return ``(..., audit)``: an empty dict normally (zero extra
    pytree leaves and zero extra ops, so untraced programs stay
    structurally identical), or — with ``need_audit`` (a traced run) — the
    per-slot detection audit: the ring threshold and occupancy each
    arrival was judged against, enough to replay every Alg. 2 verdict from
    the event stream alone.

    With ``cfg.backend == "pallas"`` the sequential fold splits into a
    scalar control scan (ring / staleness / version bookkeeping, emitting
    per-arrival mix gates + coefficients) and the
    `kernels.window_fold.window_fold_fleet` Pallas kernel, which folds the
    param mixing with each param block resident in VMEM across the window
    instead of carrying the whole model through a lax.scan.  Bit-equal for
    f32 params; non-f32 models fall back to the reference scan."""

    def sequential_fold_reference(params, version, ring, count, omegas,
                                  accs, vdisp_c, arrived, trust_c=None):
        """Eq. (6)/mix_stale over arrival order with streaming
        detection — the event loop, as one lax.scan.  With ``trust_c``
        (the cohort's per-node trust scores; defense
        ``kind="trust_weighted"``) each arrival's new-model mixing
        coefficient is scaled by its `detection.trust_weights` weight —
        w ∈ (0, 1], anchored on the sliding window's mean accuracy, so
        low-trust / outlier arrivals take proportionally smaller steps."""
        use_trust = trust_c is not None

        def body(carry, inp):
            params, version, ring, count = carry
            if use_trust:
                omega_i, acc_i, vdisp_i, arr_i, t_i = inp
            else:
                omega_i, acc_i, vdisp_i, arr_i = inp
            r2, c2 = detection.ring_push(ring, count, acc_i)
            ring = jnp.where(arr_i, r2, ring)
            count = jnp.where(arr_i, c2, count)
            if cfg.detect:
                rej = arr_i & detection.ring_detect(
                    ring, count, acc_i, cfg.detect_s, cfg.detect_warmup)
            else:
                rej = jnp.zeros((), bool)
            tau = version - vdisp_i
            if use_trust:
                # uncertainty anchor: mean of the occupied ring slots (the
                # arrival's own accuracy is already pushed, matching the
                # detection semantics)
                occupied = jnp.arange(ring.shape[0]) < count
                held = jnp.minimum(count, ring.shape[0])
                ref = (jnp.where(occupied, ring, 0.0).sum()
                       / jnp.maximum(held, 1).astype(jnp.float32))
                w = detection.trust_weights(
                    t_i, acc_i, arr_i, cfg.trust_floor,
                    cfg.uncertainty_scale, ref=ref)
                if cfg.staleness_adaptive:
                    b = async_update.staleness_alpha(
                        cfg.alpha, tau, cfg.staleness_a) * w
                else:
                    b = jnp.float32(1.0 - cfg.alpha) * w
                mixed = jax.tree.map(
                    lambda p, o: ((1.0 - b) * p.astype(jnp.float32)
                                  + b * o.astype(jnp.float32)),
                    params, omega_i)
            elif cfg.staleness_adaptive:
                mixed = async_update.mix_stale(params, omega_i, cfg.alpha,
                                               tau, cfg.staleness_a)
            else:
                mixed = async_update.mix(params, omega_i, cfg.alpha)
            do_mix = arr_i & ~rej
            params = jax.tree.map(lambda m, p: jnp.where(do_mix, m, p),
                                  mixed, params)
            version = version + do_mix.astype(jnp.int32)
            out = (params, version, rej, tau)
            if need_audit:
                out += (detection.ring_threshold(ring, count, cfg.detect_s),
                        jnp.minimum(count, ring.shape[0]))
            return (params, version, ring, count), out

        xs = (omegas, accs, vdisp_c, arrived)
        if use_trust:
            xs += (trust_c,)
        (params, version, ring, count), ys = \
            jax.lax.scan(body, (params, version, ring, count), xs)
        p_seq, v_seq, rej, taus = ys[:4]
        audit = {"thr": ys[4], "held": ys[5]} if need_audit else {}
        return params, version, ring, count, p_seq, v_seq, rej, taus, audit

    def control_scan(version, ring, count, accs, vdisp_c, arrived):
        """The reference fold's scalar bookkeeping only: ring pushes,
        detection verdicts, staleness and version tracking — emitting, per
        arrival, the gate + (a, b) coefficients of the params mix
        ``gate ? a·params + b·omega : params`` for the param-fold kernel.
        Rejection never depends on params, so the split is exact."""

        def body(carry, inp):
            version, ring, count = carry
            acc_i, vdisp_i, arr_i = inp
            r2, c2 = detection.ring_push(ring, count, acc_i)
            ring = jnp.where(arr_i, r2, ring)
            count = jnp.where(arr_i, c2, count)
            if cfg.detect:
                rej = arr_i & detection.ring_detect(
                    ring, count, acc_i, cfg.detect_s, cfg.detect_warmup)
            else:
                rej = jnp.zeros((), bool)
            tau = version - vdisp_i
            if cfg.staleness_adaptive:
                w = async_update.staleness_alpha(cfg.alpha, tau,
                                                 cfg.staleness_a)
                a_i, b_i = 1.0 - w, w
            else:
                a_i = jnp.float32(cfg.alpha)
                b_i = jnp.float32(1.0 - cfg.alpha)
            do_mix = arr_i & ~rej
            version = version + do_mix.astype(jnp.int32)
            out = (version, rej, tau, do_mix, a_i, b_i)
            if need_audit:
                out += (detection.ring_threshold(ring, count, cfg.detect_s),
                        jnp.minimum(count, ring.shape[0]))
            return (version, ring, count), out

        (version, ring, count), ys = jax.lax.scan(
            body, (version, ring, count), (accs, vdisp_c, arrived))
        v_seq, rej, taus, gates, a, b = ys[:6]
        audit = {"thr": ys[6], "held": ys[7]} if need_audit else {}
        return version, ring, count, v_seq, rej, taus, gates, a, b, audit

    def sequential_fold_pallas(params, version, ring, count, omegas, accs,
                               vdisp_c, arrived, trust_c=None):
        from ..kernels.window_fold import window_fold_fleet

        if trust_c is not None:
            # trust-weighted mixing needs the per-arrival ring mean, which
            # the control/param-fold split doesn't carry — reference scan
            return sequential_fold_reference(params, version, ring, count,
                                             omegas, accs, vdisp_c, arrived,
                                             trust_c)
        if any(l.dtype != jnp.float32 for l in jax.tree.leaves(params)):
            return sequential_fold_reference(params, version, ring, count,
                                             omegas, accs, vdisp_c, arrived)
        version, ring, count, v_seq, rej, taus, gates, a, b, audit = \
            control_scan(version, ring, count, accs, vdisp_c, arrived)
        layout = stages.cohort_layout(omegas)
        final, seq = window_fold_fleet(layout.flatten_one(params),
                                       layout.flatten(omegas), gates, a, b)
        return (layout.unflatten_one(final), version, ring, count,
                layout.unflatten(seq), v_seq, rej, taus, audit)

    sequential_fold = (sequential_fold_pallas if cfg.backend == "pallas"
                       else sequential_fold_reference)

    def buffered_fold(params, version, ring, count, omegas, accs,
                      vdisp_c, arrived, trust_c=None):
        """FedBuff-style: one detection pass over the updated window, one
        masked-mean Eq. (6) mix for the whole buffer.  With
        ``staleness_adaptive`` the buffer mean is staleness-weighted per
        update — (τ+1)^-a FedAsync discounts inside the FedBuff mean
        (uniform weights reproduce the plain masked mean bit-for-bit).
        With ``trust_c`` the buffer mean is additionally trust/uncertainty
        weighted via `detection.trust_weights`."""

        def push(carry, inp):
            ring, count = carry
            acc_i, arr_i = inp
            r2, c2 = detection.ring_push(ring, count, acc_i)
            return (jnp.where(arr_i, r2, ring),
                    jnp.where(arr_i, c2, count)), None

        version0 = version
        (ring, count), _ = jax.lax.scan(push, (ring, count),
                                        (accs, arrived))
        if cfg.detect or need_audit:
            thr = detection.ring_threshold(ring, count, cfg.detect_s)
            held = jnp.minimum(count, ring.shape[0])
        if cfg.detect:
            rej = arrived & (held >= cfg.detect_warmup) & (accs <= thr)
        else:
            rej = jnp.zeros_like(arrived)
        mask = arrived & ~rej
        taus = version0 - vdisp_c         # staleness at mix time
        if trust_c is not None:
            w = detection.trust_weights(trust_c, accs, mask,
                                        cfg.trust_floor,
                                        cfg.uncertainty_scale)
            if cfg.staleness_adaptive:
                w = w * detection.staleness_weights(taus, cfg.staleness_a)
            omega_mean = detection.masked_weighted_mean(omegas, mask, w)
        elif cfg.staleness_adaptive:
            omega_mean = detection.masked_weighted_mean(
                omegas, mask, detection.staleness_weights(taus,
                                                          cfg.staleness_a))
        else:
            omega_mean = detection.masked_mean(omegas, mask)
        mixed = async_update.mix(params, omega_mean, cfg.alpha)
        any_mix = mask.any()
        params = jax.tree.map(lambda m, p: jnp.where(any_mix, m, p),
                              mixed, params)
        version = version + any_mix.astype(jnp.int32)
        # every processed node receives the post-window model/version
        c = vdisp_c.shape[0]
        p_seq = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), params)
        v_seq = jnp.broadcast_to(version, (c,))
        # the whole buffer was judged against one threshold/ring state
        audit = ({"thr": jnp.broadcast_to(thr, (c,)),
                  "held": jnp.broadcast_to(held, (c,))} if need_audit
                 else {})
        return params, version, ring, count, p_seq, v_seq, rej, taus, audit

    return sequential_fold, buffered_fold


class AsyncFleetEngine(MeshStateIO):
    """Event-driven async FEL over a stacked node fleet, batched per window.

    Args mirror `FleetEngine`; `sampler` (optional) models churn: a node
    whose arrival lands in a window while the sampler marks it unavailable
    loses that upload (no mix, no detection entry) but is redispatched —
    mid-flight churn rather than cohort sampling.

    With ``mesh`` (a `FleetMesh`) the node axis of every per-node state
    array is sharded across devices and the window runs under `shard_map`:
    the in-window cohort is gathered out of the shards via collectives,
    its local SGD / upload pipeline is split over the mesh along the cohort
    axis, and the (small) arrival set is `all_gather`-ed so the sequential
    Eq. (6)/`mix_stale` fold and the detection ring run replicated —
    keeping exact parity with the event-loop processing order.

    Sharded-vs-unsharded PRNG parity: exact with ``key_mode="sequential"``
    (the masked chain only advances on in-window slots, so the shard-
    rounded cohort bucket is irrelevant); with ``key_mode="parallel"`` the
    key split count tracks the bucket size, which the mesh rounds up to a
    shard multiple — statistically equivalent, but not stream-identical.
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data, test_data, cloud_test, cfg: AsyncFleetConfig,
                 profile: Optional[NodeProfile] = None,
                 sampler: Optional[ClientSampler] = None,
                 mesh: Optional[FleetMesh] = None,
                 net=None, tracer=None, attack=None):
        self.cfg = cfg
        self.params = init_params
        self.attack = attack    # Optional[stages.AttackPlan]: adversary zoo
        # the obs tracer is bound at construction: whether the jitted
        # window carries detection-audit outputs is decided here, so an
        # untraced engine's program is structurally identical to pre-obs
        self.obs = tracer if tracer is not None else get_tracer()
        self._need_audit = self.obs.enabled
        self.loss_fn = loss_fn
        self.acc_fn = jax.jit(acc_fn)
        (self.data, self.n_nodes, self.test_data, self.cloud_test,
         self.profile, self.n_params) = stages.init_engine_common(
            init_params, node_data, test_data, cloud_test, profile)
        self.sampler = sampler
        self.mesh = mesh
        self.net = net          # Optional[repro.net.NetSim]: per-upload
                                # wire-encoded bytes + stochastic link times
                                # drive the node clocks instead of _comm_s
        self.n_pad = mesh.padded(self.n_nodes) if mesh else self.n_nodes
        self._bpn = stages.bytes_per_node(self.n_params, cfg.sparsify_ratio)
        # per-node uplink + compute, fixed over the run (float64 host copies
        # feed window selection and record accounting; an f32 copy padded to
        # the mesh width feeds the jitted clock update as the per-window
        # uplink-time input when no network simulation is attached)
        self._comm_s = np.asarray(self._bpn / self.profile.bandwidth_bps,
                                  np.float64)
        self._comp_s = np.asarray(self.profile.compute_s, np.float64)
        self._window_len = (cfg.window if cfg.window is not None
                            else float(self._comp_s.min()))
        if self._window_len <= 0:
            raise ValueError(f"window must be positive, got "
                             f"{self._window_len}")
        # padding rows never arrive (+inf clocks) and never participate
        self._comm_pad32 = np.concatenate(
            [self._comm_s, np.zeros(self.n_pad - self.n_nodes)]
        ).astype(np.float32)
        first_arrival = np.concatenate(
            [self._comp_s, np.full(self.n_pad - self.n_nodes, np.inf)])
        self.state = init_async_fleet_state(
            init_params, self.n_pad, jax.random.PRNGKey(cfg.seed),
            first_arrival=first_arrival, detect_window=cfg.detect_window,
            trust=cfg.trust_on,
            throttle=attack is not None and attack.needs_throttle)
        self._window_idx = 0
        self.history: List[AsyncWindowRecord] = []
        if mesh is not None:
            self.data = mesh.put_nodes(self.data.pad_to(self.n_pad))
            self.state = dataclasses.replace(
                self.state,
                residuals=mesh.put_nodes(self.state.residuals),
                dispatched=mesh.put_nodes(self.state.dispatched),
                next_arrival=mesh.put_nodes(self.state.next_arrival),
                dispatched_version=mesh.put_nodes(
                    self.state.dispatched_version),
                chain_key=mesh.put_replicated(self.state.chain_key),
                version=mesh.put_replicated(self.state.version),
                acc_ring=mesh.put_replicated(self.state.acc_ring),
                acc_count=mesh.put_replicated(self.state.acc_count),
                trust=(mesh.put_nodes(self.state.trust)
                       if self.state.trust is not None else None),
                throttle=(mesh.put_nodes(self.state.throttle)
                          if self.state.throttle is not None else None))
            self.params = mesh.put_replicated(self.params)
            self._window_fn = jax.jit(self._build_window_sharded())
        else:
            self._window_fn = jax.jit(self._build_window())

    # -- the single-dispatch arrival window ---------------------------------
    def _build_window(self):
        cfg = self.cfg
        raw_acc_fn = self.acc_fn
        cloud_x, cloud_y = self.cloud_test
        local_train = stages.make_local_train(self.loss_fn, cfg.local_steps,
                                              cfg.lr, cfg.batch_size)
        comp_s = jnp.asarray(self._comp_s, jnp.float32)
        n = self.n_nodes
        need_nnz = self.net is not None     # byte-accurate pricing only
        need_audit = self._need_audit
        sequential_fold, buffered_fold = make_window_folds(cfg, need_audit)
        attack_stage = stages.make_delta_attack(self.attack)
        mal_full = (self.attack.mask(self.n_pad)
                    if attack_stage is not None else None)
        eta, adapt_scale = cfg.trust_eta, (
            self.attack.adapt_poison_scale if self.attack else 1.0)

        def window_fn(params, state: FleetState, x, y, sizes,
                      order, proc, avail, up_s):
            """order: node ids sorted by (arrival time, node id), truncated
            to the compute bucket (in-window arrivals are a prefix of the
            sort, so the host passes the smallest power-of-two cohort
            covering them — one compiled program per bucket size); proc:
            in-window flags (sorted positions); avail: churn mask; up_s:
            per-slot uplink transfer seconds (the fixed analytic per-node
            times, or the network simulator's per-upload draws)."""
            t_arr = jnp.take(state.next_arrival, order)
            vdisp_c = jnp.take(state.dispatched_version, order)
            disp_c = gather_nodes(state.dispatched, order)
            res_c = gather_nodes(state.residuals, order)
            xg = jnp.take(x, order, axis=0)
            yg = jnp.take(y, order, axis=0)
            sz = jnp.take(sizes, order, axis=0)

            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys_masked(
                    state.chain_key, proc)
            else:
                chain_key, k1s, k2s = parallel_node_keys(state.chain_key,
                                                         order.shape[0])

            local = jax.vmap(local_train)(disp_c, xg, yg, sz, k1s)
            deltas = jax.tree.map(lambda l, d: l - d.astype(l.dtype),
                                  local, disp_c)
            if attack_stage is not None:
                mal_c = jnp.take(mal_full, order)
                thr_c = (jnp.take(state.throttle, order)
                         if state.throttle is not None else None)
                deltas = attack_stage(deltas, mal_c, thr_c)
            deltas, res_c, nnz = stages.upload_pipeline(cfg, deltas, res_c,
                                                        k2s,
                                                        need_nnz=need_nnz)
            omegas, accs = stages.rebuild_and_evaluate(
                raw_acc_fn, disp_c, deltas, cloud_x, cloud_y)

            arrived = proc & avail
            trust_c = (jnp.take(state.trust, order)
                       if state.trust is not None else None)
            fold = (sequential_fold if cfg.mixing == "sequential"
                    else buffered_fold)
            params, version, ring, count, p_seq, v_seq, rej, taus, aud = \
                fold(params, state.version, state.acc_ring, state.acc_count,
                     omegas, accs, vdisp_c, arrived, trust_c=trust_c)

            # redispatch: processed nodes get the model right after their
            # own slot (sequential) / the post-window model (buffered), the
            # matching version, and a fresh clock = arrival + uplink + next
            # local compute. Untouched slots scatter out of bounds.
            drop_idx = jnp.where(proc, order, n)
            scatter = lambda full, part: jax.tree.map(
                lambda f, p: f.at[drop_idx].set(p, mode="drop"), full, part)
            dispatched = scatter(state.dispatched, p_seq)
            residuals = scatter(state.residuals, res_c)
            dv = state.dispatched_version.at[drop_idx].set(v_seq, mode="drop")
            t_next = t_arr + up_s + jnp.take(comp_s, order)
            na = state.next_arrival.at[drop_idx].set(t_next, mode="drop")

            # trust EWMA / adaptive-attacker throttle, from this window's
            # verdicts (only arrived slots were judged; churned slots keep
            # their scores — trust_update's `seen` mask is the identity
            # for them, so the proc-indexed scatter is harmless)
            trust = state.trust
            if trust is not None:
                t_new = detection.trust_update(trust_c, arrived & ~rej,
                                               arrived, eta)
                trust = trust.at[drop_idx].set(t_new, mode="drop")
            throttle = state.throttle
            if throttle is not None:
                th_new = stages.adaptive_throttle_update(
                    thr_c, rej & arrived, arrived, adapt_scale)
                throttle = throttle.at[drop_idx].set(th_new, mode="drop")

            new_state = dataclasses.replace(
                state, residuals=residuals, chain_key=chain_key,
                dispatched=dispatched, next_arrival=na,
                dispatched_version=dv, version=version, acc_ring=ring,
                acc_count=count, trust=trust, throttle=throttle)
            metrics = {
                "n_rejected": (rej & arrived).sum(),
                "max_staleness": jnp.where(arrived, taus, 0).max(),
            }
            if need_nnz:
                metrics["nnz"] = nnz
            if need_audit:
                metrics["audit"] = dict(aud, accs=accs, rej=rej, taus=taus)
            return params, new_state, metrics

        return window_fn

    # -- the sharded window: one shard_map over the node mesh ---------------
    def _build_window_sharded(self):
        """The arrival window as a `shard_map` program over the node mesh.

        Data flow per window (cohort size C, devices D, node blocks B):
          1. gather the C cohort rows (dispatched params, residuals, clocks,
             data shards) out of the node-sharded fleet arrays — a masked
             `psum` reconstructs them replicated on every device;
          2. each device trains its C/D cohort block (local SGD -> DGC ->
             ALDP -> cloud eval), embarrassingly parallel;
          3. `all_gather` the per-arrival models/accuracies back to cohort
             order and run the sequential Eq. (6)/`mix_stale` fold (or the
             buffered FedBuff mix) replicated — identical on every device,
             so the global model/version/ring need no further collective;
          4. scatter redispatched models, residuals, versions and fresh
             clocks back to whichever device owns each processed node.

        The transient replicated cohort (step 1) is the price of arbitrary
        arrival order; it is bounded by the power-of-two arrival bucket,
        not the fleet size, so per-device memory stays O(N/D + C).
        """
        cfg = self.cfg
        mesh = self.mesh
        raw_acc_fn = self.acc_fn
        local_train = stages.make_local_train(self.loss_fn, cfg.local_steps,
                                              cfg.lr, cfg.batch_size)
        pad = self.n_pad - self.n_nodes
        comp_s = jnp.asarray(np.concatenate([self._comp_s,
                                             np.full(pad, np.inf)]),
                             jnp.float32)
        d, axis = mesh.n_devices, mesh.axis
        b = self.n_pad // d
        need_nnz = self.net is not None     # byte-accurate pricing only
        need_audit = self._need_audit
        sequential_fold, buffered_fold = make_window_folds(cfg, need_audit)
        attack_stage = stages.make_delta_attack(self.attack)
        mal_full = (self.attack.mask(self.n_pad)
                    if attack_stage is not None else None)
        eta, adapt_scale = cfg.trust_eta, (
            self.attack.adapt_poison_scale if self.attack else 1.0)

        def window_body(params, residuals, chain_key, dispatched,
                        next_arrival, dispatched_version, version, ring,
                        count, trust, throttle, x, y, sizes, order, proc,
                        avail, up_s, cx, cy):
            # 1. cohort gather: node-sharded -> replicated (C, ...) rows
            t_arr = mesh_lib.gather_rows(next_arrival, order, axis, b)
            vdisp_c = mesh_lib.gather_rows(dispatched_version, order,
                                           axis, b)
            disp_c = mesh_lib.gather_rows_tree(dispatched, order, axis, b)
            res_c = mesh_lib.gather_rows_tree(residuals, order, axis, b)
            xg = mesh_lib.gather_rows(x, order, axis, b)
            yg = mesh_lib.gather_rows(y, order, axis, b)
            sz = mesh_lib.gather_rows(sizes, order, axis, b)

            if cfg.key_mode == "sequential":
                chain_key, k1s, k2s = chain_node_keys_masked(chain_key, proc)
            else:
                chain_key, k1s, k2s = parallel_node_keys(chain_key,
                                                         order.shape[0])

            # 2. this device's cohort block through the upload pipeline
            blk = lambda t: mesh_lib.my_block_tree(t, axis, d)
            disp_b, res_b = blk(disp_c), blk(res_c)
            local = jax.vmap(local_train)(disp_b, blk(xg), blk(yg), blk(sz),
                                          blk(k1s))
            deltas = jax.tree.map(lambda l, dd: l - dd.astype(l.dtype),
                                  local, disp_b)
            thr_c = (mesh_lib.gather_rows(throttle, order, axis, b)
                     if throttle is not None else None)
            if attack_stage is not None:
                # shard-oblivious per-node row scaling on this device's
                # cohort block (mal_full closes over as a replicated const)
                mal_b = mesh_lib.my_block(jnp.take(mal_full, order), axis, d)
                thr_b = (mesh_lib.my_block(thr_c, axis, d)
                         if thr_c is not None else None)
                deltas = attack_stage(deltas, mal_b, thr_b)
            deltas, res_b, nnz_b = stages.upload_pipeline(
                cfg, deltas, res_b, blk(k2s), need_nnz=need_nnz)
            omegas_b, accs_b = stages.rebuild_and_evaluate(
                raw_acc_fn, disp_b, deltas, cx, cy)

            # 3. gather the arrival set; fold replicated
            omegas = mesh_lib.all_gather_tree(omegas_b, axis)
            accs = jax.lax.all_gather(accs_b, axis, tiled=True)
            res_c = mesh_lib.all_gather_tree(res_b, axis)

            arrived = proc & avail
            # the cohort trust rows are gathered replicated, so the fold's
            # trust-weighted mixing stays identical on every device
            trust_c = (mesh_lib.gather_rows(trust, order, axis, b)
                       if trust is not None else None)
            fold = (sequential_fold if cfg.mixing == "sequential"
                    else buffered_fold)
            params, version, ring, count, p_seq, v_seq, rej, taus, aud = \
                fold(params, version, ring, count, omegas, accs, vdisp_c,
                     arrived, trust_c=trust_c)

            # 4. redispatch: scatter processed rows back to their owners
            dispatched = mesh_lib.scatter_rows_tree(dispatched, order, p_seq,
                                                    proc, axis, b)
            residuals = mesh_lib.scatter_rows_tree(residuals, order, res_c,
                                                   proc, axis, b)
            dispatched_version = mesh_lib.scatter_rows(
                dispatched_version, order, v_seq, proc, axis, b)
            t_next = t_arr + up_s + jnp.take(comp_s, order)
            next_arrival = mesh_lib.scatter_rows(next_arrival, order, t_next,
                                                 proc, axis, b)
            if trust is not None:
                t_new = detection.trust_update(trust_c, arrived & ~rej,
                                               arrived, eta)
                trust = mesh_lib.scatter_rows(trust, order, t_new, proc,
                                              axis, b)
            if throttle is not None:
                th_new = stages.adaptive_throttle_update(
                    thr_c, rej & arrived, arrived, adapt_scale)
                throttle = mesh_lib.scatter_rows(throttle, order, th_new,
                                                 proc, axis, b)
            metrics = {
                "n_rejected": (rej & arrived).sum(),
                "max_staleness": jnp.where(arrived, taus, 0).max(),
            }
            if need_nnz:
                metrics["nnz"] = jax.lax.all_gather(nnz_b, axis, tiled=True)
            if need_audit:
                # accs and the fold outputs are already replicated
                metrics["audit"] = dict(aud, accs=accs, rej=rej, taus=taus)
            return (params, residuals, chain_key, dispatched, next_arrival,
                    dispatched_version, version, ring, count, trust,
                    throttle, metrics)

        pn, pr = mesh.spec_nodes(), mesh.spec_replicated()
        m_specs = {"n_rejected": pr, "max_staleness": pr}
        if need_nnz:
            m_specs["nnz"] = pr
        if need_audit:
            m_specs["audit"] = {"accs": pr, "rej": pr, "taus": pr,
                                "thr": pr, "held": pr}
        # trust/throttle are node-sharded when present and leafless Nones
        # when the spec keeps the defaults (specs over None are vacuous)
        return mesh.shard_map(
            window_body,
            in_specs=(pr, pn, pr, pn, pn, pn, pr, pr, pr, pn, pn,
                      pn, pn, pn, pr, pr, pr, pr, pr, pr),
            out_specs=(pr, pn, pr, pn, pn, pn, pr, pr, pr, pn, pn,
                       m_specs))

    # -- host-side driver ---------------------------------------------------
    def select_window(self, max_arrivals: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(order, proc): node ids sorted by (arrival, id) and in-window
        flags — every pending arrival inside [t0, t0 + window). Padding
        rows of a sharded fleet carry +inf clocks: they sort last and are
        never in-window."""
        na = np.asarray(self.state.next_arrival, np.float64)
        order = np.lexsort((np.arange(self.n_pad), na))
        proc = na[order] < na[order[0]] + self._window_len
        if max_arrivals is not None:
            proc &= np.cumsum(proc) <= max_arrivals
        # in-window arrivals are a prefix of the sort: truncate the cohort
        # to the smallest power-of-two bucket covering them so the device
        # program only trains nodes that can arrive (one compile per bucket;
        # floored at 16 — small fleets get a single full-size program). On a
        # mesh the bucket is additionally rounded up to a shard multiple so
        # the cohort axis splits evenly across devices.
        c = 16
        while c < int(proc.sum()):
            c *= 2
        c = min(c, self.n_pad)
        if self.mesh is not None:
            d = self.mesh.n_devices
            c = min(self.n_pad, ((c + d - 1) // d) * d)
        return order[:c], proc[:c]

    def run_window(self, max_arrivals: Optional[int] = None,
                   evaluate: bool = True) -> AsyncWindowRecord:
        """Process one arrival window. `evaluate=False` skips the global
        test-set accuracy (recorded as NaN) — callers that only consume
        accuracy at coarser boundaries (the trainer: once per n_nodes
        arrivals) avoid a test forward pass + device sync per window."""
        tr = self.obs
        w = self._window_idx
        span = tr.span("window", window=w)
        span.__enter__()
        with timed_stage(tr, "window.select", window=w):
            order, proc = self.select_window(max_arrivals)
        t_arr = np.asarray(self.state.next_arrival, np.float64)[order]
        if self.sampler is not None:
            # cohort() returns (idx, valid) aligned to idx; fold it into a
            # per-node availability mask (a node absent from the cohort, or
            # present but invalid, loses arrivals this window)
            idx_s, up = self.sampler.cohort(w, self.n_nodes)
            avail = self._participation_mask(idx_s, up)[order]
        else:
            avail = np.ones(order.size, bool)

        # per-slot uplink seconds: the analytic per-node constants, or one
        # stochastic link draw per in-window upload (non-proc slots never
        # scatter a clock, their value is irrelevant)
        sel = order[proc]
        draw = None
        if self.net is not None:
            up_host = np.zeros(order.size, np.float64)
            # DDoS flash traffic: flood flows contend for the shared
            # uplink alongside every window's real uploads
            flood = self.attack.flood_uploads if self.attack else 0
            with timed_stage(tr, "net.draw", window=w):
                draw = self.net.draw(sel, extra_concurrency=flood)
            up_host[proc] = draw.transfer_s
        else:
            up_host = self._comm_pad32[order].astype(np.float64)
        up_s = jnp.asarray(up_host, jnp.float32)

        dev = timed_stage(tr, "window.device", window=w)
        dev.__enter__()
        if self.mesh is not None:
            st = self.state
            (self.params, residuals, chain_key, dispatched, next_arrival,
             dispatched_version, version, ring, count, trust, throttle,
             m) = self._window_fn(
                self.params, st.residuals, st.chain_key, st.dispatched,
                st.next_arrival, st.dispatched_version, st.version,
                st.acc_ring, st.acc_count, st.trust, st.throttle,
                self.data.x, self.data.y,
                self.data.sizes, jnp.asarray(order, jnp.int32),
                jnp.asarray(proc), jnp.asarray(avail), up_s,
                *self.cloud_test)
            self.state = dataclasses.replace(
                st, residuals=residuals, chain_key=chain_key,
                dispatched=dispatched, next_arrival=next_arrival,
                dispatched_version=dispatched_version, version=version,
                acc_ring=ring, acc_count=count, trust=trust,
                throttle=throttle)
        else:
            self.params, self.state, m = self._window_fn(
                self.params, self.state, self.data.x, self.data.y,
                self.data.sizes, jnp.asarray(order, jnp.int32),
                jnp.asarray(proc), jnp.asarray(avail), up_s)
        dev.fence((self.params, m))
        dev.__exit__(None, None, None)
        self._window_idx = w + 1

        # host-side clock/traffic accounting over the processed arrivals.
        # Churned-out slots (proc & ~avail) are billed too, by design: the
        # node transmitted its update before going unreachable (its clock
        # pays uplink + compute above), the cloud just discards it — the
        # same semantics as the analytic path's bpn * n_processed.
        if self.net is not None:
            # byte-accurate: price each upload's measured nonzero count
            # through the wire codec; times are the link draws
            with timed_stage(tr, "net.commit", window=w):
                enc = self.net.commit(draw, np.asarray(m["nnz"])[proc],
                                      ctx={"window": w})
            uplink = draw.transfer_s
            comm_bytes = float(enc.sum())
        else:
            uplink = self._comm_s[sel]
            comm_bytes = float(self._bpn * sel.size)
        t_arrive = t_arr[proc] + uplink             # arrival + uplink times
        if evaluate:
            with timed_stage(tr, "window.evaluate", window=w):
                accuracy = self.global_accuracy()
        else:
            accuracy = float("nan")
        rec = AsyncWindowRecord(
            t=float(t_arrive.max()) if sel.size else 0.0,
            window=w, version=int(self.state.version),
            accuracy=accuracy,
            comm_bytes=comm_bytes,
            comp_time=float(self._comp_s[sel].sum()),
            comm_time=float(uplink.sum()),
            n_processed=int(sel.size),
            n_rejected=int(m["n_rejected"]),
            max_staleness=int(m["max_staleness"]))
        self.history.append(rec)
        if tr.enabled:
            self._emit_window_events(rec, sel, proc, avail, t_arrive, m)
        span.set(n_processed=rec.n_processed, n_rejected=rec.n_rejected,
                 version=rec.version)
        span.set_virtual(float(t_arr[0]) if t_arr.size else 0.0, rec.t)
        span.__exit__(None, None, None)
        return rec

    def _emit_window_events(self, rec: AsyncWindowRecord, sel, proc, avail,
                            t_arrive, m) -> None:
        """One window's trace: arrival instants (every processed upload),
        a `detect.verdict` instant per cloud evaluation (the Alg. 2 audit
        log — accuracy, ring threshold/occupancy, verdict, staleness), and
        the aggregated window metrics."""
        tr = self.obs
        arrived = avail[proc]
        aud = m.get("audit")
        if aud is not None:
            accs = np.asarray(aud["accs"])[proc]
            rej = np.asarray(aud["rej"])[proc]
            taus = np.asarray(aud["taus"])[proc]
            thr = np.asarray(aud["thr"])[proc]
            held = np.asarray(aud["held"])[proc]
        for i in range(sel.size):
            t_i = float(t_arrive[i])
            node = int(sel[i])
            tr.instant("arrival", virt_t=t_i, node=node, window=rec.window,
                       arrived=bool(arrived[i]))
            if aud is not None and arrived[i]:
                tr.instant(
                    "detect.verdict", virt_t=t_i, node=node,
                    window=rec.window, accuracy=float(accs[i]),
                    threshold=float(thr[i]), ring_held=int(held[i]),
                    rejected=bool(rej[i]), tau=int(taus[i]),
                    detect=bool(self.cfg.detect))
        mx = tr.metrics
        mx.histogram("window.size", WINDOW_SIZE_EDGES).observe(
            rec.n_processed)
        mx.histogram("window.max_staleness", STALENESS_EDGES).observe(
            rec.max_staleness)
        mx.counter("window.arrivals").inc(rec.n_processed)
        mx.counter("window.rejected").inc(rec.n_rejected)
        mx.counter("window.comm_bytes").inc(rec.comm_bytes)
        mx.gauge("model.version").set(rec.version)

    def run(self, windows: int) -> List[AsyncWindowRecord]:
        for _ in range(windows):
            self.run_window()
        return self.history

    def run_arrivals(self, total: int) -> List[AsyncWindowRecord]:
        """Process exactly `total` arrivals (the trainer's rounds×nodes
        budget), truncating the final window."""
        done = 0
        while done < total:
            done += self.run_window(max_arrivals=total - done).n_processed
        return self.history

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    def kappa(self) -> float:
        """Eq. (5) over the whole run (per-arrival totals)."""
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)
