"""FleetMesh: shard the stacked node axis of a fleet across local devices.

Both fleet engines keep every per-node quantity — residual pytrees, data
shards, dispatched models, virtual clocks — stacked along a leading node
axis (`state.FleetState` / `state.FleetData`). On one device that axis caps
fleet size by memory, not math. `FleetMesh` places those arrays on a 1-D
``Mesh(("nodes",))`` with a `NamedSharding` over the node axis (the same
Mesh/NamedSharding/PartitionSpec conventions as `repro.sharding.ctx`) and
the engines run their per-round / per-window programs under `shard_map`:

  * the embarrassingly node-parallel stages (local SGD, DGC sparsify, ALDP,
    per-node cloud evaluation) run on each device's shard of nodes;
  * the small cross-node steps (detection threshold, masked-mean aggregate,
    the async sequential Eq. (6)/`mix_stale` fold and its accuracy ring)
    see globally gathered values via `psum`/`all_gather` collectives and
    run replicated, so their results are identical on every device.

The node axis is padded up to a multiple of the device count
(`FleetMesh.padded`); padding rows carry a size-1 dummy shard, never
participate (their valid/proc masks are False) and never arrive
(`next_arrival = +inf`).

This module also hosts the collective primitives the sharded round/window
programs are written with: `my_block` (slice a replicated array down to this
device's block), `gather_rows` (pull an arbitrary global-index cohort out of
a node-sharded array, replicated everywhere via a masked `psum`) and
`scatter_rows` (write cohort rows back into the owner device's shard).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class FleetMesh:
    """A 1-D device mesh over the fleet's node axis.

    Args:
      devices: the devices to shard over (defaults to all local devices).
      axis: mesh axis name (default ``"nodes"``).
    """

    def __init__(self, devices: Optional[Sequence] = None,
                 axis: str = "nodes"):
        devices = list(devices) if devices is not None else jax.devices()
        if not devices:
            raise ValueError("FleetMesh needs at least one device")
        self.axis = axis
        self.mesh = Mesh(np.asarray(devices), (axis,))

    @classmethod
    def create(cls, n_devices: Optional[int] = None,
               axis: str = "nodes") -> "FleetMesh":
        """Mesh over the first `n_devices` local devices (None = all).

        Raises with a clear message when the host exposes fewer devices
        than requested — use ``--xla_force_host_platform_device_count`` to
        fake a multi-device CPU host.
        """
        avail = jax.devices()
        if n_devices is None:
            n_devices = len(avail)
        if n_devices > len(avail):
            raise ValueError(
                f"FleetMesh over {n_devices} devices requested but only "
                f"{len(avail)} visible; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_devices} "
                f"before importing jax to fake a multi-device host")
        return cls(avail[:n_devices], axis=axis)

    @property
    def n_devices(self) -> int:
        return self.mesh.shape[self.axis]

    def padded(self, n_nodes: int) -> int:
        """Node count rounded up to a shard multiple."""
        d = self.n_devices
        return ((n_nodes + d - 1) // d) * d

    # -- placement ----------------------------------------------------------
    def spec_nodes(self) -> P:
        return P(self.axis)

    def spec_replicated(self) -> P:
        return P()

    def put_nodes(self, tree):
        """Place every leaf's leading (node) axis across the mesh. The axis
        length must already be a shard multiple (see :meth:`padded`)."""
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    def put_replicated(self, tree):
        sh = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    # -- program wrapper ----------------------------------------------------
    def shard_map(self, f, in_specs, out_specs):
        """`shard_map` bound to this mesh. Replication checking is disabled:
        the fleet programs mix replicated PRNG-chain scans and collectives,
        and their replicated outputs are established by `psum`/`all_gather`
        by construction."""
        return _shard_map(f, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# collectives used inside sharded round/window programs
#
# Every helper takes the mesh axis name plus the per-device block size B of
# the node-sharded operand (global padded rows = B * n_devices, device d
# owning the contiguous rows [d*B, (d+1)*B) — NamedSharding's layout for a
# 1-D mesh).
# ---------------------------------------------------------------------------

def my_block(x, axis: str, n_devices: int):
    """Slice this device's contiguous block out of a replicated array whose
    leading axis is a multiple of the device count (replicated -> sharded)."""
    b = x.shape[0] // n_devices
    return jax.lax.dynamic_slice_in_dim(x, jax.lax.axis_index(axis) * b, b)


def my_block_tree(tree, axis: str, n_devices: int):
    return jax.tree.map(lambda x: my_block(x, axis, n_devices), tree)


def gather_rows(x_local, idx, axis: str, block: int):
    """Gather global rows `idx` from a node-sharded array; result replicated.

    Each device contributes the rows it owns (zeros elsewhere) and a `psum`
    over the mesh reconstructs the full cohort on every device — exactly one
    device owns each row, so the sum is exact (no float reordering).
    """
    off = jax.lax.axis_index(axis) * block
    local = idx - off
    mine = (local >= 0) & (local < block)
    rows = jnp.take(x_local, jnp.clip(local, 0, block - 1), axis=0)
    shape = (mine.shape[0],) + (1,) * (rows.ndim - 1)
    contrib = jnp.where(mine.reshape(shape), rows,
                        jnp.zeros((), rows.dtype))
    return jax.lax.psum(contrib, axis)


def gather_rows_tree(tree_local, idx, axis: str, block: int):
    return jax.tree.map(lambda x: gather_rows(x, idx, axis, block),
                        tree_local)


def scatter_rows(x_local, idx, values, keep, axis: str, block: int):
    """Write replicated cohort rows `values` back into the node-sharded
    array: each device updates only the rows it owns; `keep` masks cohort
    slots that must not be written (padding / out-of-window). Duplicate
    global indices in `idx` must carry identical values (last write wins,
    same contract as `state.scatter_nodes`)."""
    off = jax.lax.axis_index(axis) * block
    local = idx - off
    mine = keep & (local >= 0) & (local < block)
    rows = jnp.where(mine, local, block)          # out of bounds => dropped
    return x_local.at[rows].set(values, mode="drop")


def scatter_rows_tree(tree_local, idx, values, keep, axis: str, block: int):
    return jax.tree.map(
        lambda x, v: scatter_rows(x, idx, v, keep, axis, block),
        tree_local, values)


def all_gather_tree(tree, axis: str):
    """Concatenate every leaf's sharded leading axis back to the full
    (replicated) cohort, preserving global row order (sharded -> replicated)."""
    return jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis, tiled=True), tree)


class MeshStateIO:
    """Mesh-aware state ingress/egress shared by both fleet engines.

    Host classes provide ``self.mesh`` (a `FleetMesh` or None),
    ``self.n_nodes`` / ``self.n_pad``, and ``self.state`` (a `FleetState`
    with ``.residuals`` / ``.chain_key``).
    """

    def load_state(self, residuals_stacked, chain_key) -> None:
        """Adopt externally-held per-node residuals (stacked, n_nodes rows)
        and a chain key — padding/placing them onto the mesh when sharded."""
        import dataclasses

        from .state import pad_node_axis
        if self.mesh is not None:
            residuals_stacked = self.mesh.put_nodes(
                pad_node_axis(residuals_stacked, self.n_pad))
            chain_key = self.mesh.put_replicated(chain_key)
        self.state = dataclasses.replace(
            self.state, residuals=residuals_stacked, chain_key=chain_key)

    def export_residuals(self):
        """The stacked residuals restricted to real nodes (padding dropped),
        gathered to host-addressable arrays."""
        return jax.tree.map(lambda x: jax.device_get(x[:self.n_nodes]),
                            self.state.residuals)

    def _participation_mask(self, idx, valid) -> np.ndarray:
        """(idx, valid) cohort -> per-node bool mask over the padded fleet
        (padding rows always False)."""
        up = np.zeros(self.n_pad, bool)
        up[np.asarray(idx)[np.asarray(valid)]] = True
        return up

    # -- full-state snapshot (repro.sim checkpoint/resume) ------------------
    # per-node FleetState fields (leading node axis, trimmed to real nodes
    # on export) and replicated fields; None fields are simply absent from
    # the snapshot, so sync/async engines and defense on/off variants all
    # share this one code path
    _SIM_NODE_FIELDS = ("next_arrival", "dispatched_version", "trust",
                        "throttle")
    _SIM_REP_FIELDS = ("version", "acc_ring", "acc_count")

    def export_sim_state(self) -> dict:
        """Every device-side array a bit-exact resume needs, as a flat
        host-side dict of numpy arrays/pytrees (padding rows dropped)."""
        st = self.state
        n = self.n_nodes

        def trim(tree):
            return jax.tree.map(
                lambda x: np.asarray(jax.device_get(x))[:n], tree)

        out = {
            "params": jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                   self.params),
            "chain_key": _key_data(st.chain_key),
            "residuals": trim(st.residuals),
        }
        if st.dispatched is not None:
            out["dispatched"] = trim(st.dispatched)
        for name in self._SIM_NODE_FIELDS:
            v = getattr(st, name)
            if v is not None:
                out[name] = np.asarray(jax.device_get(v))[:n]
        for name in self._SIM_REP_FIELDS:
            v = getattr(st, name)
            if v is not None:
                out[name] = np.asarray(jax.device_get(v))
        return out

    def load_sim_state(self, tree: dict) -> None:
        """Restore an `export_sim_state` snapshot into this engine.

        The engine must be freshly constructed for the same spec shape
        (same node count / defense fields): real-node rows are overwritten,
        padding rows keep their init values (+inf arrival clocks, dummy
        data) — they never participate, so the restored run is bit-exact.
        Fields present in the snapshot but absent on this engine (or vice
        versa, e.g. trust rings after a defense-onset event) keep their
        fresh init — exactly the semantics a mid-run spec mutation wants.
        """
        import dataclasses
        st = self.state
        n = self.n_nodes
        if self.mesh is not None:
            place_nodes = self.mesh.put_nodes
            place_rep = self.mesh.put_replicated
        else:
            def place_nodes(t):
                return jax.tree.map(jnp.asarray, t)
            place_rep = place_nodes

        def rows(cur, new):
            host = np.array(jax.device_get(cur))    # padding rows survive
            host[:n] = np.asarray(new)
            return host

        updates = {
            "residuals": place_nodes(
                jax.tree.map(rows, st.residuals, tree["residuals"])),
            "chain_key": place_rep(_key_like(st.chain_key,
                                             tree["chain_key"])),
        }
        if st.dispatched is not None and "dispatched" in tree:
            updates["dispatched"] = place_nodes(
                jax.tree.map(rows, st.dispatched, tree["dispatched"]))
        for name in self._SIM_NODE_FIELDS:
            cur = getattr(st, name)
            if cur is not None and name in tree:
                updates[name] = place_nodes(rows(cur, tree[name]))
        for name in self._SIM_REP_FIELDS:
            cur = getattr(st, name)
            if cur is not None and name in tree:
                updates[name] = place_rep(np.asarray(tree[name]))
        self.state = dataclasses.replace(st, **updates)
        self.params = place_rep(jax.tree.map(jnp.asarray, tree["params"]))


def _key_data(key):
    """A PRNG chain key as raw host bits (typed keys unwrapped)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(jax.device_get(key))


def _key_like(cur, data):
    """Raw key bits back to the kind of key the engine carries."""
    data = jnp.asarray(np.asarray(data))
    if jnp.issubdtype(cur.dtype, jax.dtypes.prng_key):
        return jax.random.wrap_key_data(data, impl=jax.random.key_impl(cur))
    return data
