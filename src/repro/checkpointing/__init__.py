from .checkpoint import (  # noqa: F401
    CheckpointError,
    load_checkpoint,
    read_manifest,
    save_checkpoint,
)
