"""Flat .npz checkpoints with a JSON tree manifest (no orbax offline)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint does not match the structure it is being restored into."""


def _base(path: str) -> str:
    # suffix-strip only: a ".npz" occurring mid-path (e.g. "runs.npz.d/ck")
    # must survive untouched
    return path[:-len(".npz")] if path.endswith(".npz") else path


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    stored = {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        # numpy's npz cannot serialise bfloat16 — store the raw bits
        stored[k] = v.view(np.uint16) if v.dtype.name == "bfloat16" else v
    base = _base(path)
    np.savez(base + ".npz", **stored)
    manifest = {"step": step, "keys": sorted(flat), "dtypes": dtypes,
                "extra": extra or {}}
    with open(base + ".json", "w") as f:
        json.dump(manifest, f)


def read_manifest(path: str) -> Dict[str, Any]:
    """The JSON manifest saved next to the .npz (step / keys / dtypes /
    extra) — readable without materializing any arrays, which is how
    `repro.sim` recovers the spec a checkpoint was saved under before it
    can build the template tree `load_checkpoint` needs."""
    base = _base(path)
    try:
        with open(base + ".json") as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointError(
            f"no checkpoint manifest at {base + '.json'!r}")


def load_checkpoint(path: str, like_tree) -> Tuple[Any, int]:
    """Restores into the structure of ``like_tree``; returns (tree, step).

    Leaves come back as the same kind of array as the template: numpy
    leaves restore through numpy (so float64/int64 survive even with
    jax x64 disabled), jax leaves restore through ``jax.numpy``.
    """
    base = _base(path)
    data = np.load(base + ".npz")
    with open(base + ".json") as f:
        manifest = json.load(f)

    flat_like = jax.tree_util.tree_flatten_with_path(like_tree)
    dtypes = manifest.get("dtypes", {})
    leaves = []
    for pathk, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        if key not in data:
            raise CheckpointError(
                f"checkpoint {base!r} has no entry for leaf {key!r} "
                f"(stored keys: {manifest.get('keys', [])})")
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        like = np.asarray(leaf)
        if arr.shape != like.shape:
            raise CheckpointError(
                f"checkpoint leaf {key!r} has shape {arr.shape} but the "
                f"template expects {like.shape} — the checkpoint was saved "
                "from a differently-shaped run")
        if isinstance(leaf, jax.Array):
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        else:
            leaves.append(np.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), manifest["step"]
