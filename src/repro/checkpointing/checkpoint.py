"""Flat .npz checkpoints with a JSON tree manifest (no orbax offline)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    stored = {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        # numpy's npz cannot serialise bfloat16 — store the raw bits
        stored[k] = v.view(np.uint16) if v.dtype.name == "bfloat16" else v
    np.savez(path if path.endswith(".npz") else path + ".npz", **stored)
    manifest = {"step": step, "keys": sorted(flat), "dtypes": dtypes,
                "extra": extra or {}}
    with open(path.replace(".npz", "") + ".json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like_tree) -> Tuple[Any, int]:
    """Restores into the structure of ``like_tree``; returns (tree, step)."""
    base = path.replace(".npz", "")
    data = np.load(base + ".npz")
    with open(base + ".json") as f:
        manifest = json.load(f)

    flat_like = jax.tree_util.tree_flatten_with_path(like_tree)
    dtypes = manifest.get("dtypes", {})
    leaves = []
    for pathk, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), manifest["step"]
