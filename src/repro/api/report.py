"""Structured, JSON-round-trippable run results.

`RunReport` is the single result schema for every execution path — the
sequential reference loops, the fleet engines, and the mesh-sharded
engines all produce the same record stream (one `RoundRecord` per
n_nodes arrivals / per barrier round), plus the derived quantities the
paper reports: κ (Eq. 5), ε spent, and the detection log.  Reports carry
a ``schema_version`` and round-trip through JSON, so `benchmarks/` and
``results/*.json`` consume one schema instead of hand-rolling their own.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.async_update import communication_efficiency
from ..obs import read_jsonl
from .spec import ACCEPTED_SCHEMA_VERSIONS, SCHEMA_VERSION


@dataclass
class RoundRecord:
    """One row of every trajectory: the per-round (sync) / per-n_nodes-
    arrivals (async) record stream all execution paths emit."""
    t: float
    version: int
    accuracy: float
    comm_bytes: float
    comp_time: float
    comm_time: float
    n_rejected: int
    # how comm_bytes was produced: "analytic" (the closed-form values +
    # indices estimate) or "encoded" (repro.net wire-codec byte counts) —
    # keeps mixed trajectories in results/*.json interpretable
    bytes_source: str = "analytic"


@dataclass
class RunReport:
    """The structured result of `run.run`.

    ``final_params`` is execution-side state (a pytree) — available on
    fresh reports for follow-on evaluation, never serialized, and None
    after a JSON round trip.
    """
    mode: str                           # sync | async
    engine: str                         # sequential | fleet | fleet-mesh
    records: List[RoundRecord] = field(default_factory=list)
    kappa: float = 0.0                  # Eq. (5) over the whole run
    epsilon_spent: float = 0.0          # 0 exactly for no-noise runs
    final_accuracy: float = 0.0
    detections: List[Dict] = field(default_factory=list)
    spec: Optional[Dict] = None         # ExperimentSpec.to_dict(), if known
    net: Optional[Dict] = None          # repro.net NetTrace summary (wire
                                        # codec + encoded/wire byte totals)
                                        # when the network subsystem ran
    # v5 resume metadata: set when the run was restored from a checkpoint
    # (repro.sim); None for uninterrupted runs and pre-v5 payloads
    resumed_from: Optional[str] = None  # checkpoint base path
    resume_round: Optional[int] = None  # record index the run resumed at
    schema_version: int = SCHEMA_VERSION
    final_params: Any = field(default=None, repr=False, compare=False)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "schema_version": self.schema_version,
            "mode": self.mode,
            "engine": self.engine,
            "records": [dataclasses.asdict(r) for r in self.records],
            "kappa": self.kappa,
            "epsilon_spent": self.epsilon_spent,
            "final_accuracy": self.final_accuracy,
            "detections": self.detections,
            "spec": self.spec,
            "net": self.net,
            "resumed_from": self.resumed_from,
            "resume_round": self.resume_round,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict) -> "RunReport":
        version = d.get("schema_version")
        if version not in ACCEPTED_SCHEMA_VERSIONS:
            raise ValueError(f"RunReport schema_version {version!r} not in "
                             f"supported {ACCEPTED_SCHEMA_VERSIONS}")
        # v1 records predate bytes_source — RoundRecord defaults it to
        # "analytic", which is what every v1 trajectory actually was
        return cls(mode=d["mode"], engine=d["engine"],
                   records=[RoundRecord(**r) for r in d["records"]],
                   kappa=d["kappa"], epsilon_spent=d["epsilon_spent"],
                   final_accuracy=d["final_accuracy"],
                   detections=list(d.get("detections", [])),
                   spec=d.get("spec"), net=d.get("net"),
                   # pre-v5 payloads have no resume metadata — uninterrupted
                   resumed_from=d.get("resumed_from"),
                   resume_round=d.get("resume_round"),
                   schema_version=SCHEMA_VERSION)

    @classmethod
    def from_json(cls, s: str) -> "RunReport":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))

    @classmethod
    def load(cls, path: str) -> "RunReport":
        with open(path) as f:
            return cls.from_json(f.read())


def detection_log(records: List[RoundRecord]) -> List[Dict]:
    """The rounds where the cloud rejected updates (Alg. 2 firing)."""
    return [{"round": i, "t": r.t, "n_rejected": r.n_rejected}
            for i, r in enumerate(records) if r.n_rejected]


def append_json_records(path: str, records: List[Dict]) -> None:
    """Append schema-stamped result records to a JSON trajectory file —
    the one write path for ``results/*.json`` (benchmarks route through
    this instead of hand-rolling their own schemas)."""
    if not records:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    traj = []
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
        if not isinstance(traj, list):
            raise ValueError(
                f"append_json_records: {path} holds a JSON "
                f"{type(traj).__name__}, not a trajectory list — single "
                f"RunReports written by RunReport.save live in their own "
                f"files")
    for rec in records:
        stamped = dict(rec)
        stamped.setdefault("schema_version", SCHEMA_VERSION)
        traj.append(stamped)
    # write-then-rename: a crash mid-dump must never replace a valid
    # trajectory with a torn one (the old file survives intact)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(traj, f, indent=1)
    os.replace(tmp, path)


def load_json_records(path: str) -> List[Dict]:
    """Read an `append_json_records` trajectory back, validating shape."""
    with open(path) as f:
        traj = json.load(f)
    if not isinstance(traj, list):
        raise ValueError(f"load_json_records: {path} holds a JSON "
                         f"{type(traj).__name__}, not a trajectory list")
    return traj


# ---------------------------------------------------------------------------
# streamed-record replay (the ObsSpec.records_jsonl stream)
# ---------------------------------------------------------------------------

def replay_records(path: str, strict: bool = True) -> RunReport:
    """Rebuild a `RunReport` from an ``obs.records_jsonl`` stream.

    The stream is header / one line per `RoundRecord` / a final ``report``
    footer.  Derived quantities (κ, final accuracy, the detection log) are
    recomputed from the replayed records — for a complete stream the
    result equals the in-memory report exactly; for a crashed stream
    (``strict=False`` drops a torn tail, the footer may be missing) it is
    the faithful report of every round that completed.
    """
    rows = read_jsonl(path, strict=strict)
    header = rows[0] if rows and rows[0].get("kind") == "header" else {}
    records = [RoundRecord(**{k: v for k, v in r.items() if k != "kind"})
               for r in rows if r.get("kind") == "record"]
    footer = next((r for r in reversed(rows)
                   if r.get("kind") == "report"), None)
    meta = dict(footer) if footer is not None else dict(header)
    comm = sum(r.comm_time for r in records)
    comp = sum(r.comp_time for r in records)
    return RunReport(
        mode=meta["mode"], engine=meta["engine"], records=records,
        kappa=communication_efficiency(comm, comp),
        epsilon_spent=meta.get("epsilon_spent", 0.0),
        final_accuracy=records[-1].accuracy if records else 0.0,
        detections=detection_log(records),
        spec=meta.get("spec"), net=meta.get("net"))
