"""FedConfig -> ExperimentSpec: the migration path off the flag soup.

`FederatedTrainer` is now a thin shim: its `run()` lowers the legacy
`FedConfig` through `spec_from_fed_config` / `plan_from_fed_config` and
executes via `run.execute`.  The mapping is exact — mode strings become a
`SchedulePolicy`, the σ/ε/δ tangle becomes a `PrivacySpec` with the noise
multiplier resolved by the same rule (`FedConfig.noise_multiplier`: 0 for
the no-noise schemes regardless of the sigma field), `use_fleet` /
`fleet_mesh` become a `Topology` — so shimmed runs reproduce the
pre-redesign trajectories bit-equal-to-float-close.
"""
from __future__ import annotations

from .plan import ExperimentPlan, compile_plan
from .spec import (AttackMix, CompressionSpec, DefenseSpec, ExperimentSpec,
                   FleetSpec, NodeHeterogeneity, PrivacySpec, SchedulePolicy,
                   Topology, TrainSpec)

MODE_TO_SCHEDULE = {"sfl": "sync", "sldpfl": "sync",
                    "afl": "async", "aldpfl": "async"}


def spec_from_fed_config(cfg) -> ExperimentSpec:
    """Lower a legacy `FedConfig` to the declarative spec it denotes.

    Raises ValueError (via `FedConfig.validate`) on the cross-field gaps
    the old constructor let through silently — unknown modes, a mesh
    without the fleet engines, out-of-range knobs.
    """
    cfg.validate()
    kind = MODE_TO_SCHEDULE[cfg.mode]
    if not cfg.use_fleet:
        topology = Topology(kind="sequential")
    elif cfg.fleet_mesh is not None:
        topology = Topology(kind="mesh", devices=cfg.fleet_mesh)
    else:
        topology = Topology(kind="single")
    return ExperimentSpec(
        fleet=FleetSpec(
            n_nodes=cfg.n_nodes,
            profile=NodeHeterogeneity(
                base_compute_s=cfg.base_compute_s,
                heterogeneity=cfg.heterogeneity,
                bandwidth_bps=cfg.bandwidth_bytes_per_s),
            attack=AttackMix()),
        schedule=SchedulePolicy(
            kind=kind, alpha=cfg.alpha,
            staleness_adaptive=(cfg.staleness_adaptive
                                if kind == "async" else False)),
        # noise_multiplier() already applies the mode rule (0 for sfl/afl)
        # and the (epsilon, delta) calibration when sigma is None
        privacy=PrivacySpec(sigma=cfg.noise_multiplier(),
                            epsilon=cfg.epsilon, delta=cfg.delta,
                            clip_s=cfg.clip_s),
        compression=CompressionSpec(sparsify_ratio=cfg.sparsify_ratio),
        defense=DefenseSpec(detect=cfg.detect, detect_s=cfg.detect_s,
                            detect_warmup=cfg.detect_warmup,
                            detect_window=cfg.detection_window()),
        topology=topology,
        train=TrainSpec(local_steps=cfg.local_steps,
                        batch_size=cfg.batch_size, lr=cfg.lr),
        rounds=cfg.rounds, seed=cfg.seed)


def plan_from_fed_config(cfg) -> ExperimentPlan:
    """`spec_from_fed_config` + `compile_plan` in one step."""
    return compile_plan(spec_from_fed_config(cfg))
