"""Spec -> plan: validate cross-field constraints once, select the engine.

`compile_plan` is the single choke point between a declarative
`ExperimentSpec` and execution: it checks every cross-field constraint
(mesh topology needs the fleet engines, no accountant when σ=0, window
policies only on windowed schedules, ...) with explicit errors, resolves
derived quantities (the calibrated noise multiplier, the detection window)
and returns an `ExperimentPlan` naming the engine and the pipeline stages
that will run.  `run.run` consumes plans, never raw specs — so invalid
axis combinations fail loudly at compile time, not silently mid-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from ..core import aldp, detection
from ..net.codecs import CODEC_NAMES, SparseBitpack
from .spec import (SIM_EVENT_KINDS, TRACE_KINDS, ExperimentSpec,
                   apply_sim_event)
from .window import AutoWindow, FixedWindow, TargetArrivalsWindow

SCHEDULE_KINDS = ("sync", "async", "buffered")
TOPOLOGY_KINDS = ("sequential", "single", "mesh")
BACKENDS = ("reference", "pallas")
NET_CODECS = ("analytic",) + CODEC_NAMES
ATTACK_KINDS = ("label_flip", "sybil", "backdoor", "adaptive", "ddos")
DEFENSE_KINDS = ("percentile", "trust_weighted")
PLACEMENTS = ("random", "first")


class SpecError(ValueError):
    """An `ExperimentSpec` with contradictory or out-of-range fields."""


@dataclass(frozen=True)
class ExperimentPlan:
    """A validated, lowered experiment: which engine, which stages.

    Plans are produced by `compile_plan` only; the runner trusts them.
    """
    spec: ExperimentSpec
    mode: str                   # "sync" | "async" (execution family)
    engine: str                 # "sequential" | "fleet"
    mixing: str                 # "barrier" | "sequential" | "buffered"
    mesh_devices: Optional[int]  # None = unsharded; 0 = all local devices
    sigma: float                # resolved noise multiplier
    detect_window: int          # resolved async detection ring capacity
    total_arrivals: int         # async arrival budget (rounds * n_nodes)
    accountant: bool            # spend privacy budget? (sigma > 0)
    key_mode: str               # engine PRNG chain mode
    stages: Tuple[str, ...]     # descriptive upload/aggregate pipeline
    net_codec: Optional[str] = None  # repro.net wire codec; None = analytic

    def describe(self) -> str:
        placement = ("sequential reference loop" if self.engine == "sequential"
                     else "fleet engine"
                     + (f" over {self.mesh_devices or 'all'}-device mesh"
                        if self.mesh_devices is not None else ""))
        return (f"{self.spec.schedule.kind} schedule on {placement}: "
                + " -> ".join(self.stages))


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


def compile_plan(spec: ExperimentSpec) -> ExperimentPlan:
    """Validate ``spec`` and lower it to an `ExperimentPlan`.

    Raises `SpecError` (a ValueError) on any contradictory or out-of-range
    field combination.
    """
    f, sch, priv = spec.fleet, spec.schedule, spec.privacy
    comp, dfs, topo, tr = (spec.compression, spec.defense, spec.topology,
                           spec.train)

    # -- enumerations -------------------------------------------------------
    _require(sch.kind in SCHEDULE_KINDS,
             f"schedule.kind {sch.kind!r} not in {SCHEDULE_KINDS}")
    _require(topo.kind in TOPOLOGY_KINDS,
             f"topology.kind {topo.kind!r} not in {TOPOLOGY_KINDS}")
    _require(topo.backend in BACKENDS,
             f"topology.backend {topo.backend!r} not in {BACKENDS}")
    _require(f.model in ("mlp", "cnn"),
             f"fleet.model {f.model!r} not in ('mlp', 'cnn')")

    # -- ranges -------------------------------------------------------------
    _require(f.n_nodes >= 1, f"fleet.n_nodes must be >= 1, got {f.n_nodes}")
    _require(spec.rounds >= 1, f"rounds must be >= 1, got {spec.rounds}")
    _require(tr.local_steps >= 1 and tr.batch_size >= 1,
             "train.local_steps and train.batch_size must be >= 1")
    _require(tr.lr > 0, f"train.lr must be > 0, got {tr.lr}")
    _require(0.0 <= sch.alpha <= 1.0,
             f"schedule.alpha must be in [0, 1], got {sch.alpha}")
    _require(0.0 < comp.sparsify_ratio <= 1.0,
             f"compression.sparsify_ratio must be in (0, 1], got "
             f"{comp.sparsify_ratio}")
    _require(0.0 < dfs.detect_s < 100.0,
             f"defense.detect_s is a percentile in (0, 100), got "
             f"{dfs.detect_s}")
    _require(dfs.detect_warmup >= 1,
             f"defense.detect_warmup must be >= 1, got {dfs.detect_warmup}")
    _require(dfs.detect_window is None or dfs.detect_window >= 1,
             f"defense.detect_window must be >= 1, got {dfs.detect_window}")
    _require(0.0 < f.availability <= 1.0,
             f"fleet.availability must be in (0, 1], got {f.availability}")
    _require(0.0 < f.cohort_frac <= 1.0,
             f"fleet.cohort_frac must be in (0, 1], got {f.cohort_frac}")
    _require(0.0 <= f.attack.malicious_frac <= 1.0,
             "fleet.attack.malicious_frac must be in [0, 1]")
    _require(0.0 <= f.profile.straggler_frac <= 1.0,
             "fleet.profile.straggler_frac must be in [0, 1]")
    _require(f.profile.base_compute_s > 0 and f.profile.bandwidth_bps > 0,
             "fleet.profile.base_compute_s and bandwidth_bps must be > 0")
    _require(f.profile.heterogeneity >= 0,
             "fleet.profile.heterogeneity must be >= 0")
    _require(f.samples_per_node >= 1,
             "fleet.samples_per_node must be >= 1")
    _require(f.dirichlet_alpha > 0,
             f"fleet.dirichlet_alpha must be > 0, got {f.dirichlet_alpha}")

    # -- cross-field contradictions -----------------------------------------
    _require(not (f.availability < 1.0 and f.cohort_frac < 1.0),
             "fleet.availability < 1 and fleet.cohort_frac < 1 are two "
             "different participation models — declare exactly one")
    _require(not (topo.kind == "mesh" and topo.devices is not None
                  and topo.devices < 1),
             f"topology.devices must be >= 1, got {topo.devices}")
    _require(not (topo.kind != "mesh" and topo.devices is not None),
             f"topology.devices={topo.devices} is set but topology.kind="
             f"{topo.kind!r} is not 'mesh' — a mesh size without a mesh "
             f"is a contradiction, not a default")
    _require(not (topo.kind == "sequential" and sch.kind == "buffered"),
             "buffered aggregation has no sequential reference loop — use "
             "topology.kind='single' or 'mesh'")
    _require(not (topo.kind == "sequential" and topo.backend == "pallas"),
             "the sequential reference loop has no pallas upload pipeline — "
             "use topology.kind='single' or 'mesh'")
    _require(not (sch.kind == "sync" and sch.staleness_adaptive),
             "schedule.staleness_adaptive weights staleness τ, which a "
             "synchronous barrier never has — use kind='async'")
    _require(sch.staleness_a > 0,
             f"schedule.staleness_a must be > 0, got {sch.staleness_a}")

    # -- window policy ------------------------------------------------------
    win = sch.window
    if sch.kind == "sync":
        _require(isinstance(win, AutoWindow),
                 f"schedule.window={type(win).__name__} but kind='sync' has "
                 f"no arrival windows — window policies apply to "
                 f"async/buffered schedules")
    if isinstance(win, FixedWindow):
        _require(win.seconds > 0,
                 f"FixedWindow: window must be positive, got {win.seconds}")
    if isinstance(win, TargetArrivalsWindow):
        _require(sch.kind == "buffered",
                 "TargetArrivalsWindow batches many arrivals per window, "
                 "which reorders them vs the event loop — only the buffered "
                 "schedule (order-free masked-mean mix) supports it")
        _require(win.target_arrivals >= 1,
                 f"TargetArrivalsWindow.target_arrivals must be >= 1, got "
                 f"{win.target_arrivals}")
    if not isinstance(win, AutoWindow) and topo.kind == "sequential":
        raise SpecError("the sequential reference loop processes arrivals "
                        "one at a time — window policies need the fleet "
                        "engines (topology.kind='single' or 'mesh')")

    # -- network ------------------------------------------------------------
    net = spec.network
    _require(net.codec in NET_CODECS,
             f"network.codec {net.codec!r} not in {NET_CODECS}")
    _require(net.value_bits in SparseBitpack.VALUE_BITS,
             f"network.value_bits must be one of "
             f"{SparseBitpack.VALUE_BITS}, got {net.value_bits}")
    _require(net.value_bits == 32 or net.codec == "sparse_bitpack",
             f"network.value_bits={net.value_bits} is the sparse_bitpack "
             f"quantized-value variant; codec {net.codec!r} stores f32 "
             f"values")
    _require(0.0 <= net.loss_prob < 1.0,
             f"network.loss_prob must be in [0, 1), got {net.loss_prob}")
    _require(net.latency_s >= 0 and net.jitter_s >= 0,
             "network.latency_s and network.jitter_s must be >= 0")
    _require(net.bandwidth_sigma >= 0 and net.shared_uplink_bps >= 0,
             "network.bandwidth_sigma and network.shared_uplink_bps must "
             "be >= 0")
    _require(net.mtu_bytes >= 1,
             f"network.mtu_bytes must be >= 1, got {net.mtu_bytes}")
    if not net.enabled:
        _require(net.bandwidth_sigma == 0 and net.latency_s == 0
                 and net.jitter_s == 0 and net.loss_prob == 0
                 and net.shared_uplink_bps == 0,
                 "link simulation needs a wire codec — network.codec="
                 "'analytic' keeps the analytic comm model; pick "
                 "dense_f32/sparse_coo/sparse_bitpack to enable the link "
                 "parameters")
    else:
        _require(topo.kind != "sequential",
                 "the sequential reference loop has no network simulation "
                 "— use topology.kind='single' or 'mesh'")

    # -- adversary zoo + defense --------------------------------------------
    atk = f.attack
    attacking = atk.malicious_frac > 0.0
    _require(atk.kind in ATTACK_KINDS,
             f"fleet.attack.kind {atk.kind!r} not in {ATTACK_KINDS}")
    _require(atk.placement in PLACEMENTS,
             f"fleet.attack.placement {atk.placement!r} not in {PLACEMENTS}")
    _require(f.n_classes >= 2,
             f"fleet.n_classes must be >= 2, got {f.n_classes}")
    _require(0 <= atk.flip_src < f.n_classes,
             f"fleet.attack.flip_src={atk.flip_src} is not a class id in "
             f"[0, {f.n_classes}) — check fleet.n_classes")
    _require(0 <= atk.flip_dst < f.n_classes,
             f"fleet.attack.flip_dst={atk.flip_dst} is not a class id in "
             f"[0, {f.n_classes}) — check fleet.n_classes")
    _require(not (attacking and atk.kind in ("label_flip", "sybil", "adaptive")
                  and atk.flip_src == atk.flip_dst),
             f"fleet.attack.flip_src == flip_dst == {atk.flip_src} flips "
             f"every label onto itself — a silent no-op 'attack', not a "
             f"default")
    _require(atk.sybil_boost > 0,
             f"fleet.attack.sybil_boost must be > 0, got {atk.sybil_boost}")
    _require(0.0 < atk.adapt_poison_scale < 1.0,
             f"fleet.attack.adapt_poison_scale must be in (0, 1) — the "
             f"throttle must actually back off on rejection, got "
             f"{atk.adapt_poison_scale}")
    _require(0.0 < atk.trigger_frac <= 1.0,
             f"fleet.attack.trigger_frac must be in (0, 1], got "
             f"{atk.trigger_frac}")
    _require(0 <= atk.trigger_label < f.n_classes,
             f"fleet.attack.trigger_label={atk.trigger_label} is not a class "
             f"id in [0, {f.n_classes})")
    _require(1 <= atk.trigger_size <= min(f.hw),
             f"fleet.attack.trigger_size={atk.trigger_size} must fit the "
             f"{f.hw} image (1 <= size <= {min(f.hw)})")
    _require(atk.ddos_uploads >= 1,
             f"fleet.attack.ddos_uploads must be >= 1, got "
             f"{atk.ddos_uploads}")
    if attacking and atk.kind == "ddos":
        _require(net.enabled and net.shared_uplink_bps > 0,
                 "fleet.attack.kind='ddos' floods the shared uplink — it "
                 "needs a real network.codec and network.shared_uplink_bps "
                 "> 0 (the analytic comm model has no contention to abuse)")
    if attacking and atk.kind in ("sybil", "adaptive", "ddos"):
        _require(topo.kind != "sequential",
                 f"fleet.attack.kind={atk.kind!r} manipulates the engines' "
                 f"delta/verdict/link pipeline — the sequential reference "
                 f"loop only supports data-level attacks (label_flip, "
                 f"backdoor); use topology.kind='single' or 'mesh'")
    _require(dfs.kind in DEFENSE_KINDS,
             f"defense.kind {dfs.kind!r} not in {DEFENSE_KINDS}")
    _require(0.0 < dfs.trust_eta <= 1.0,
             f"defense.trust_eta must be in (0, 1], got {dfs.trust_eta}")
    _require(0.0 <= dfs.trust_floor <= 1.0,
             f"defense.trust_floor must be in [0, 1], got {dfs.trust_floor}")
    _require(dfs.uncertainty_scale >= 0,
             f"defense.uncertainty_scale must be >= 0, got "
             f"{dfs.uncertainty_scale}")
    if dfs.kind == "trust_weighted":
        _require(dfs.detect,
                 "defense.kind='trust_weighted' accumulates trust from "
                 "detection verdicts — it needs defense.detect=True")
        _require(topo.kind != "sequential",
                 "defense.kind='trust_weighted' keeps trust state in "
                 "FleetState — the sequential reference loop has none; use "
                 "topology.kind='single' or 'mesh'")

    # -- observability ------------------------------------------------------
    obs = spec.obs
    for name in ("events_jsonl", "chrome_trace", "records_jsonl"):
        path = getattr(obs, name)
        _require(path is None or (isinstance(path, str) and path != ""),
                 f"obs.{name} must be a non-empty path or None, got "
                 f"{path!r}")
        _require(path is None or obs.enabled,
                 f"obs.{name}={path!r} is set but obs.enabled=False — an "
                 f"output path without the tracer is a contradiction, not "
                 f"a default")
    _require(not (obs.stage_timings and not obs.enabled),
             "obs.stage_timings needs obs.enabled=True — fenced stage "
             "timing only exists inside a traced run")
    _require(not (obs.enabled and topo.kind == "sequential"
                  and obs.stage_timings),
             "obs.stage_timings times the fleet engines' pipeline stages — "
             "the sequential reference loop has none (use topology.kind="
             "'single' or 'mesh')")

    # -- fleet health (repro.obs.health) -------------------------------------
    hlt = obs.health
    if hlt is not None:
        _require(obs.enabled,
                 "obs.health declares SLO probes over the trace stream — "
                 "it needs obs.enabled=True")
        probes = hlt.enabled_probes()
        _require(len(probes) > 0,
                 "obs.health enables no probe — every threshold is 0/off; "
                 "set at least one of straggler_factor, "
                 "bytes_per_record_budget, reject_rate_threshold, "
                 "occupancy_floor")
        _require(hlt.straggler_factor == 0 or hlt.straggler_factor > 1.0,
                 f"obs.health.straggler_factor flags nodes slower than "
                 f"factor × the fleet median gap — it must be > 1 when "
                 f"set, got {hlt.straggler_factor}")
        _require(hlt.straggler_min_arrivals >= 2,
                 f"obs.health.straggler_min_arrivals must be >= 2 (one "
                 f"arrival has no cadence), got "
                 f"{hlt.straggler_min_arrivals}")
        _require(hlt.bytes_per_record_budget >= 0,
                 f"obs.health.bytes_per_record_budget must be >= 0, got "
                 f"{hlt.bytes_per_record_budget}")
        _require(0.0 <= hlt.reject_rate_threshold <= 1.0,
                 f"obs.health.reject_rate_threshold must be in [0, 1], "
                 f"got {hlt.reject_rate_threshold}")
        _require(hlt.reject_rate_window >= 1,
                 f"obs.health.reject_rate_window must be >= 1, got "
                 f"{hlt.reject_rate_window}")
        _require(0.0 <= hlt.occupancy_floor < 1.0,
                 f"obs.health.occupancy_floor must be in [0, 1), got "
                 f"{hlt.occupancy_floor}")
        _require(hlt.warmup_records >= 0,
                 f"obs.health.warmup_records must be >= 0, got "
                 f"{hlt.warmup_records}")
        if "straggler" in probes:
            _require(sch.kind != "sync",
                     "obs.health.straggler_factor scores arrival cadence — "
                     "sync barrier rounds emit no arrival instants; use "
                     "schedule.kind='async' or 'buffered'")
        if "byte_budget" in probes:
            _require(spec.network.enabled,
                     "obs.health.bytes_per_record_budget meters net.upload "
                     "events — it needs a real network codec "
                     "(network.codec != 'analytic')")
        if "reject_rate" in probes:
            _require(dfs.detect,
                     "obs.health.reject_rate_threshold watches the "
                     "detect.verdict audit log — it needs "
                     "defense.detect=True")

    # -- simulation service (repro.sim) -------------------------------------
    sim = spec.sim
    if sim is not None:
        _require(sim.checkpoint_every >= 0,
                 f"sim.checkpoint_every must be >= 0, got "
                 f"{sim.checkpoint_every}")
        _require(not (sim.checkpoint_every > 0 and not sim.checkpoint_dir),
                 "sim.checkpoint_every > 0 schedules automatic checkpoints "
                 "— it needs sim.checkpoint_dir")
        for i, trc in enumerate(sim.traces):
            _require(trc.kind in TRACE_KINDS,
                     f"sim.traces[{i}].kind {trc.kind!r} not in "
                     f"{TRACE_KINDS}")
            _require(0.0 <= trc.amplitude < 1.0,
                     f"sim.traces[{i}].amplitude must be in [0, 1) — an "
                     f"amplitude of 1 zeroes the link rate, got "
                     f"{trc.amplitude}")
            _require(0.0 < trc.node_frac <= 1.0,
                     f"sim.traces[{i}].node_frac must be in (0, 1], got "
                     f"{trc.node_frac}")
            _require(0.0 <= trc.region_start < 1.0,
                     f"sim.traces[{i}].region_start must be in [0, 1), got "
                     f"{trc.region_start}")
            if trc.kind == "diurnal":
                _require(trc.period_s > 0,
                         f"sim.traces[{i}] (diurnal) needs period_s > 0, "
                         f"got {trc.period_s}")
            else:
                _require(trc.duration_s > 0 and trc.t_start >= 0,
                         f"sim.traces[{i}] ({trc.kind}) is an epoch — needs "
                         f"duration_s > 0 and t_start >= 0, got "
                         f"({trc.t_start}, {trc.duration_s})")
            if trc.kind in ("diurnal", "flash_crowd"):
                _require(net.enabled,
                         f"sim.traces[{i}] ({trc.kind}) modulates link "
                         f"bandwidth — it needs a real network.codec "
                         f"(network.codec='analytic' has no links to "
                         f"throttle)")
            if trc.kind == "outage":
                _require(topo.kind != "sequential",
                         f"sim.traces[{i}] (outage) drops nodes via the "
                         f"churn sampler — the sequential reference loop "
                         f"has none; use topology.kind='single' or 'mesh'")
                _require(not (sch.kind == "sync" and trc.node_frac >= 1.0),
                         f"sim.traces[{i}]: a full-fleet outage would "
                         f"starve a synchronous barrier round — use "
                         f"node_frac < 1 on sync schedules")
        members = set(range(f.n_nodes))
        last_round = 0
        mutated = dataclasses.replace(spec, sim=None)
        for i, ev in enumerate(sim.events):
            _require(ev.kind in SIM_EVENT_KINDS,
                     f"sim.events[{i}].kind {ev.kind!r} not in "
                     f"{SIM_EVENT_KINDS}")
            _require(isinstance(ev.payload, dict),
                     f"sim.events[{i}].payload must be a dict, got "
                     f"{type(ev.payload).__name__}")
            _require(1 <= ev.at_round < spec.rounds,
                     f"sim.events[{i}].at_round={ev.at_round} must be in "
                     f"[1, rounds={spec.rounds}) — events fire between "
                     f"records")
            _require(ev.at_round >= last_round,
                     f"sim.events[{i}] fires at round {ev.at_round}, before "
                     f"sim.events[{i - 1}] at {last_round} — the timeline "
                     f"must be ordered by at_round")
            last_round = ev.at_round
            if ev.kind == "nodes":
                _require(topo.kind != "sequential",
                         f"sim.events[{i}] (nodes) churns membership via "
                         f"the dynamic sampler — the sequential reference "
                         f"loop has none; use topology.kind='single' or "
                         f"'mesh'")
                _require(set(ev.payload) <= {"join", "leave"},
                         f"sim.events[{i}] (nodes) payload keys must be a "
                         f"subset of {{'join', 'leave'}}, got "
                         f"{sorted(ev.payload)}")
                for kk in ("join", "leave"):
                    ids = ev.payload.get(kk, [])
                    _require(all(isinstance(x, int) and 0 <= x < f.n_nodes
                                 for x in ids),
                             f"sim.events[{i}] (nodes) {kk} ids must be "
                             f"node ids in [0, {f.n_nodes}), got {ids}")
                members -= set(ev.payload.get("leave", []))
                members |= set(ev.payload.get("join", []))
                _require(len(members) >= 1,
                         f"sim.events[{i}] (nodes) would leave the fleet "
                         f"empty at round {ev.at_round}")
            else:
                try:
                    mutated = apply_sim_event(mutated, ev)
                except (TypeError, ValueError) as e:
                    raise SpecError(
                        f"sim.events[{i}] ({ev.kind}): bad payload "
                        f"{ev.payload!r} — {e}") from e
                try:
                    compile_plan(mutated)
                except SpecError as e:
                    raise SpecError(
                        f"sim.events[{i}] ({ev.kind}) at round "
                        f"{ev.at_round} yields an invalid spec: {e}") from e

    # -- privacy resolution -------------------------------------------------
    if priv.sigma is None:
        _require(priv.epsilon > 0 and 0.0 < priv.delta < 1.0,
                 f"privacy.sigma=None calibrates from (epsilon, delta); "
                 f"need epsilon > 0 and delta in (0, 1), got "
                 f"({priv.epsilon}, {priv.delta})")
        sigma = aldp.sigma_for_epsilon(priv.epsilon, priv.delta)
    else:
        _require(priv.sigma >= 0,
                 f"privacy.sigma must be >= 0 (0 = no noise), got "
                 f"{priv.sigma}")
        sigma = float(priv.sigma)
    _require(priv.clip_s > 0, f"privacy.clip_s must be > 0, got "
             f"{priv.clip_s}")

    # -- lowering -----------------------------------------------------------
    mode = "sync" if sch.kind == "sync" else "async"
    engine = "sequential" if topo.kind == "sequential" else "fleet"
    mixing = {"sync": "barrier", "async": "sequential",
              "buffered": "buffered"}[sch.kind]
    mesh_devices = ((topo.devices if topo.devices is not None else 0)
                    if topo.kind == "mesh" else None)
    detect_window = (dfs.detect_window if dfs.detect_window is not None
                     else detection.default_window(f.n_nodes))

    stages = ["local_sgd"]
    if attacking:
        stages.append(f"attack[{atk.kind}]")
    if comp.sparsify_ratio < 1.0:
        stages.append("dgc_sparsify")
    if sigma > 0:
        stages.append("aldp_perturb")
    if net.enabled:
        stages.append(f"wire_encode[{net.codec}]")
        stages.append("link_sim")
    if dfs.detect:
        stages.append("cloud_detect")
        if dfs.kind == "trust_weighted":
            stages.append("trust_weighted_agg")
    if obs.enabled:
        stages.append("obs_trace")
    if obs.health is not None:
        stages.append("health_probes")
    stages.append({"barrier": "masked_mean_mix",
                   "sequential": "eq6_arrival_mix",
                   "buffered": "fedbuff_window_mix"}[mixing])

    return ExperimentPlan(
        spec=spec, mode=mode, engine=engine, mixing=mixing,
        mesh_devices=mesh_devices, sigma=sigma, detect_window=detect_window,
        total_arrivals=spec.rounds * f.n_nodes, accountant=sigma > 0,
        key_mode="sequential", stages=tuple(stages),
        net_codec=net.codec if net.enabled else None)
