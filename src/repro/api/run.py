"""Execute an `ExperimentPlan`: plan -> engines -> `RunReport`.

This is the one execution layer behind every entry point — the
declarative `run(compile_plan(spec))` surface and the scenario builders
all land here.  The four execution paths (sync/async × sequential
reference loop / fleet engines) are the seed trainer's former ``_run_*``
branches, ported verbatim so the round-record trajectories stay
bit-equal-to-float-close with the pre-redesign implementation (enforced
by tests/test_api.py):

  * ``engine="fleet"``      — the cohort-batched `FleetEngine` (sync) or
    window-batched `AsyncFleetEngine` (async/buffered), optionally
    node-sharded over a `FleetMesh`;
  * ``engine="sequential"`` — the per-node / per-arrival reference loops
    (the seed implementation: one Python dispatch per update, kept as the
    bit-exact ground truth the engines are tested against).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import heapq
from dataclasses import dataclass, field
from functools import partial
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import accumulator as accum
from ..core import aldp, async_update, detection
from ..core.accountant import MomentsAccountant
from .. import fleet
from .. import obs as _obs
from ..fleet import stages as fleet_stages
from ..net import netsim_from_network
from .plan import ExperimentPlan, SpecError
from .population import Population, materialize
from .report import RoundRecord, RunReport, detection_log
from .spec import SCHEMA_VERSION


# ---------------------------------------------------------------------------
# mutable run state (what the trainer used to keep on `self`)
# ---------------------------------------------------------------------------

@dataclass
class RunState:
    """Everything that evolves over a run and survives it: the global
    model, the host-side PRNG chain key, per-node DGC residuals, the
    privacy accountant, and the record history.  Repeated `execute` calls
    over the same state continue the PRNG chain / residuals faithfully."""
    params: Any
    key: Any
    residuals: List[Any]
    accountant: Optional[MomentsAccountant]
    history: List[RoundRecord] = field(default_factory=list)
    net: Optional[dict] = None      # NetTrace summary when repro.net ran


def init_state(plan: ExperimentPlan, population: Population) -> RunState:
    """Fresh run state: ω_0 from the population, chain key from the spec
    seed, zero residuals, and an accountant only when σ > 0 (no-noise runs
    must spend exactly zero privacy budget)."""
    return RunState(
        params=population.params,
        key=jax.random.PRNGKey(plan.spec.seed),
        residuals=[accum.init_residual(population.params)
                   for _ in range(population.n_nodes)],
        accountant=(MomentsAccountant(plan.sigma, 1.0)
                    if plan.sigma > 0 else None))


# ---------------------------------------------------------------------------
# the per-run observability session (ObsSpec -> tracer + sinks + streams)
# ---------------------------------------------------------------------------

class _ObsSession:
    """Materialize one run's `ObsSpec`: build the tracer and its sinks,
    stream `RoundRecord`s as they land, export the Chrome trace and the
    metrics snapshot at the end.  With the spec disabled every method is a
    no-op and no tracer is installed — the run is byte-identical to a
    pre-obs build."""

    def __init__(self, plan: ExperimentPlan):
        o = plan.spec.obs
        self.enabled = o.enabled
        self.tracer: Optional[_obs.Tracer] = None
        self.analytics: Optional[_obs.FleetAnalytics] = None
        self.health: Optional[_obs.HealthMonitor] = None
        self._chrome_path = o.chrome_trace
        self._mem: Optional[_obs.MemorySink] = None
        self._events: Optional[_obs.JsonlSink] = None
        self._records: Optional[_obs.JsonlWriter] = None
        self._last_virt_t = 0.0
        self._last_records_done = 0
        if not self.enabled:
            return
        engine_name = ("fleet-mesh" if plan.mesh_devices is not None
                       else plan.engine)
        header = {"schema_version": SCHEMA_VERSION, "mode": plan.mode,
                  "engine": engine_name, "spec": plan.spec.to_dict()}
        sinks = []
        if o.chrome_trace:
            self._mem = _obs.MemorySink()
            sinks.append(self._mem)
        if o.events_jsonl:
            self._events = _obs.JsonlSink(o.events_jsonl,
                                          header=dict(header,
                                                      stream="events"))
            sinks.append(self._events)
        if o.health is not None:
            # the analytics sink sees every event the file sinks see —
            # including the monitor's own alerts/incidents, which it
            # collects but never probes on
            self.analytics = _obs.FleetAnalytics(
                n_nodes=plan.spec.fleet.n_nodes)
            sinks.append(self.analytics)
        self.tracer = _obs.Tracer(sinks=sinks, enabled=True,
                                  stage_timings=o.stage_timings)
        if o.health is not None:
            self.health = _obs.HealthMonitor(
                o.health, self.analytics, self.tracer,
                n_nodes=plan.spec.fleet.n_nodes)
        if o.records_jsonl:
            self._records = _obs.JsonlWriter(o.records_jsonl,
                                             header=dict(header,
                                                         stream="records"))

    def scope(self):
        """The `use_tracer` context the run executes inside (engines and
        `NetSim` pick the tracer up from the process-global slot)."""
        return (_obs.use_tracer(self.tracer) if self.tracer is not None
                else contextlib.nullcontext())

    def record(self, rec: RoundRecord) -> None:
        """Stream one completed round record (called from the history
        hook the moment each record is appended — crash-safe JSONL, not
        the at-end dump)."""
        if self._records is not None:
            self._records.write({"kind": "record",
                                 **dataclasses.asdict(rec)})

    def history(self) -> Optional[List[RoundRecord]]:
        """An append-hooked record list when ``records_jsonl`` is set
        (swapped in for ``state.history``), else None."""
        if self._records is None:
            return None
        return _StreamingHistory(self.record)

    def poll_health(self, virt_t: float, records_done: int) -> None:
        """Evaluate the health probes between records (no-op without an
        `ObsSpec.health` axis)."""
        if self.health is None:
            return
        self._last_virt_t = virt_t
        self._last_records_done = records_done
        self.health.evaluate(virt_t, records_done)

    def finish(self, report: Optional[RunReport] = None) -> None:
        """Flush everything: close open health incidents, report footer
        on the record stream, metrics snapshot on the event stream, the
        Chrome-trace export, then close every sink."""
        if not self.enabled:
            return
        if self.health is not None:
            # run end closes whatever is still open (tagged unresolved),
            # before the metrics snapshot so incident counters land in it
            t = max(self._last_virt_t,
                    self.analytics.t_max or 0.0)
            self.health.finalize(t, self._last_records_done)
        if self._records is not None:
            if report is not None:
                footer = {k: v for k, v in report.to_dict().items()
                          if k != "records"}
                self._records.write({"kind": "report", **footer})
            self._records.close()
        if self._events is not None:
            snap = self.tracer.metrics.snapshot()
            if snap:
                self._events.writer.write({"kind": "metrics",
                                           "metrics": snap})
        if self._chrome_path and self._mem is not None:
            _obs.write_chrome_trace(self._chrome_path, self._mem.events)
        self.tracer.close()


class _StreamingHistory(list):
    """A record list that streams each append (the `_run_*` drivers and
    the sequential runner all append to ``state.history`` — hooking the
    list streams every path without touching the drivers)."""

    def __init__(self, callback):
        super().__init__()
        self._callback = callback

    def append(self, rec) -> None:
        super().append(rec)
        self._callback(rec)


# ---------------------------------------------------------------------------
# engine construction (shared with the scenario builders)
# ---------------------------------------------------------------------------

def make_engine(plan: ExperimentPlan, population: Population,
                mesh: Optional["fleet.FleetMesh"] = None):
    """Build the fleet engine a plan selects, faithful to the trainer's
    construction (sequential PRNG chain, reference/pallas backend, the
    population's profile/sampler).  ``mesh`` overrides the plan's
    topology-derived mesh (scenario builders pass prebuilt meshes)."""
    if plan.engine != "fleet":
        raise ValueError("make_engine: plan selects the sequential "
                         "reference loop, which has no engine object")
    spec = plan.spec
    if mesh is None and plan.mesh_devices is not None:
        mesh = fleet.FleetMesh.create(plan.mesh_devices or None)

    common = dict(
        local_steps=spec.train.local_steps, batch_size=spec.train.batch_size,
        lr=spec.train.lr, alpha=spec.schedule.alpha,
        clip_s=spec.privacy.clip_s, sigma=plan.sigma,
        detect=spec.defense.detect, detect_s=spec.defense.detect_s,
        defense_kind=spec.defense.kind, trust_eta=spec.defense.trust_eta,
        trust_floor=spec.defense.trust_floor,
        uncertainty_scale=spec.defense.uncertainty_scale,
        sparsify_ratio=spec.compression.sparsify_ratio,
        key_mode=plan.key_mode, backend=spec.topology.backend,
        seed=spec.seed)
    args = (population.params, population.loss_fn, population.acc_fn,
            population.node_data, population.test_data, population.cloud_test)
    # model-delta adversary stages (sybil/adaptive scaling, ddos flood
    # accounting) ride the engines only when the spec staffs the fleet
    # with malicious nodes; None keeps the jitted programs byte-identical
    attack = (fleet_stages.AttackPlan.from_spec(
                  spec.fleet.attack, population.n_nodes,
                  population.malicious_ids)
              if population.malicious_ids else None)

    n_params = sum(x.size for x in jax.tree.leaves(population.params))
    # the repro.net transport (None with NetworkSpec at its analytic
    # defaults — the engines then keep the pre-net comm model exactly)
    net = netsim_from_network(
        spec.network, population.profile.bandwidth_bps, n_params,
        sparsify_ratio=spec.compression.sparsify_ratio, seed=spec.seed)

    if plan.mode == "sync":
        cfg = fleet.FleetConfig(**common)
        return fleet.FleetEngine(
            *args, cfg, profile=population.profile,
            sampler=population.sampler or fleet.FullParticipation(),
            mesh=mesh, net=net, attack=attack)

    bpn = fleet_stages.bytes_per_node(n_params,
                                      spec.compression.sparsify_ratio)
    cfg = fleet.AsyncFleetConfig(
        **common,
        window=spec.schedule.window.resolve(population.profile, bpn),
        mixing="buffered" if plan.mixing == "buffered" else "sequential",
        staleness_adaptive=spec.schedule.staleness_adaptive,
        staleness_a=spec.schedule.staleness_a,
        detect_warmup=spec.defense.detect_warmup,
        detect_window=plan.detect_window)
    return fleet.AsyncFleetEngine(*args, cfg, profile=population.profile,
                                  sampler=population.sampler, mesh=mesh,
                                  net=net, attack=attack)


# ---------------------------------------------------------------------------
# record steppers (the trainer's former _run_* drivers, one record at a time)
# ---------------------------------------------------------------------------
#
# Each execution path is a *stepper*: `step()` advances the run by exactly
# one `RoundRecord` (a barrier round, or n_nodes async arrivals, or one
# buffered window), `done` says whether the record budget is spent, and
# `finalize()` hands node-local state back to the `RunState`.  `execute`
# just drains a stepper — byte-for-byte the old loops — while `repro.sim`
# drives the same steppers incrementally: its coordinator installs a
# `pre_step` hook (traffic-trace modulation), checkpoints between steps
# via `export_state`/`restore_state` (only ever called at a record
# boundary, where the span accumulators are exactly zero), and swaps
# steppers mid-run to apply `SimEvent` spec mutations.

class _SyncFleetStepper:
    """Barrier rounds on the cohort-batched `FleetEngine`."""

    def __init__(self, plan, pop, state, eng):
        self.plan, self.pop, self.state, self.eng = plan, pop, state, eng
        self.n = pop.n_nodes
        self.src = "encoded" if eng.net is not None else "analytic"
        eng.load_state(fleet.stack_trees(state.residuals), state.key)
        self.emitted = 0
        self.pre_step = None

    @property
    def net(self):
        return self.eng.net

    @property
    def done(self) -> bool:
        return self.emitted >= self.plan.spec.rounds

    def virtual_time(self) -> float:
        h = self.eng.history
        return float(h[-1].t) if h else float(self.eng._t0)

    def step(self) -> None:
        if self.pre_step is not None:
            self.pre_step(self)
        state, eng = self.state, self.eng
        rec = eng.run_round()
        if state.accountant is not None:
            # charge only the nodes that actually uploaded a noised delta
            # (cohort sampling / availability: n_participating <= n_nodes)
            state.accountant.step(rec.n_participating)
        state.params = eng.params
        state.history.append(RoundRecord(
            rec.t, self.emitted, rec.accuracy, rec.comm_bytes, rec.comp_time,
            rec.comm_time, rec.n_rejected, bytes_source=self.src))
        self.emitted += 1

    def finalize(self) -> None:
        _fleet_handback(self.state, self.eng, self.n)

    # -- checkpoint/resume (repro.sim) --------------------------------------
    def export_state(self):
        arrays = self.eng.export_sim_state()
        meta = {"emitted": self.emitted,
                "round": int(self.eng.state.round),
                "t0": self.virtual_time()}
        _export_net(self.eng.net, arrays, meta)
        return arrays, meta

    def restore_state(self, arrays, meta) -> None:
        arrays = dict(arrays)
        _restore_net(self.eng.net, arrays, meta)
        self.eng.load_sim_state(arrays)
        self.eng.state = dataclasses.replace(self.eng.state,
                                             round=int(meta["round"]))
        # the engine's barrier clock continues from the checkpointed time
        # (its own history list is empty after a restore)
        self.eng._t0 = float(meta["t0"])
        self.state.params = self.eng.params
        self.emitted = int(meta["emitted"])


class _AsyncFleetStepper:
    """Event-loop cadence on the window-batched `AsyncFleetEngine`: one
    record per n_nodes arrivals — windows are capped so they never
    straddle a record boundary (a cap only truncates the arrival prefix,
    so the processed order is unchanged)."""

    def __init__(self, plan, pop, state, eng):
        self.plan, self.pop, self.state, self.eng = plan, pop, state, eng
        self.n = pop.n_nodes
        self.src = "encoded" if eng.net is not None else "analytic"
        eng.load_state(fleet.stack_trees(state.residuals), state.key)
        self.acc_fn = eng.acc_fn
        self.test_dev = eng.test_data
        self.emitted = 0
        self.processed = 0
        self.pre_step = None

    @property
    def net(self):
        return self.eng.net

    @property
    def done(self) -> bool:
        return self.processed >= self.plan.total_arrivals

    def virtual_time(self) -> float:
        arr = np.asarray(jax.device_get(self.eng.state.next_arrival),
                         np.float64)[:self.n]
        return float(arr.min())

    def step(self) -> None:
        state, eng, n = self.state, self.eng, self.n
        target = min(self.processed + n, self.plan.total_arrivals)
        span_bytes = span_comp = span_comm = 0.0
        span_rejected = 0
        rec = None
        while self.processed < target:
            if self.pre_step is not None:
                self.pre_step(self)
            rec = eng.run_window(max_arrivals=target - self.processed,
                                 evaluate=False)
            self.processed += rec.n_processed
            if state.accountant is not None:
                state.accountant.step(rec.n_processed)
            state.params = eng.params
            span_bytes += rec.comm_bytes
            span_comp += rec.comp_time
            span_comm += rec.comm_time
            span_rejected += rec.n_rejected
        state.history.append(RoundRecord(
            rec.t, rec.version,
            float(self.acc_fn(state.params, *self.test_dev)),
            span_bytes, span_comp, span_comm, span_rejected,
            bytes_source=self.src))
        self.emitted += 1

    def finalize(self) -> None:
        _fleet_handback(self.state, self.eng, self.n)

    # -- checkpoint/resume (repro.sim) --------------------------------------
    def export_state(self):
        arrays = self.eng.export_sim_state()
        meta = {"emitted": self.emitted, "processed": self.processed,
                "window_idx": int(self.eng._window_idx)}
        _export_net(self.eng.net, arrays, meta)
        return arrays, meta

    def restore_state(self, arrays, meta) -> None:
        arrays = dict(arrays)
        _restore_net(self.eng.net, arrays, meta)
        self.eng.load_sim_state(arrays)
        self.state.params = self.eng.params
        self.emitted = int(meta["emitted"])
        self.processed = int(meta["processed"])
        # the window index seeds the cohort sampler's round stream
        self.eng._window_idx = int(meta["window_idx"])


class _BufferedFleetStepper(_AsyncFleetStepper):
    """Buffered (FedBuff-style) windows: process the arrival budget window
    by window without the event-loop record boundary — one record per
    window (load-aware policies make windows fat on purpose)."""

    def step(self) -> None:
        if self.pre_step is not None:
            self.pre_step(self)
        state, eng = self.state, self.eng
        rec = eng.run_window(
            max_arrivals=self.plan.total_arrivals - self.processed,
            evaluate=False)
        self.processed += rec.n_processed
        if state.accountant is not None:
            state.accountant.step(rec.n_processed)
        state.params = eng.params
        state.history.append(RoundRecord(
            rec.t, rec.version,
            float(self.acc_fn(state.params, *self.test_dev)),
            rec.comm_bytes, rec.comp_time, rec.comm_time, rec.n_rejected,
            bytes_source=self.src))
        self.emitted += 1


def _fleet_handback(state, eng, n) -> None:
    """Hand node-local state back so follow-on runs stay faithful."""
    state.key = jax.device_get(eng.state.chain_key)
    state.residuals = fleet.unstack_tree(eng.export_residuals(), n)
    if eng.net is not None:
        state.net = eng.net.summary()


def _export_net(net, arrays, meta) -> None:
    """Fold the `NetSim` counter/trace state into a stepper snapshot."""
    if net is not None:
        counters, columns = net.export_sim_state()
        arrays["net_counters"] = counters
        meta["net_trace"] = columns


def _restore_net(net, arrays, meta) -> None:
    counters = arrays.pop("net_counters", None)
    if net is not None and counters is not None:
        net.restore_sim_state(counters, meta.get("net_trace"))


# ---------------------------------------------------------------------------
# sequential reference loops (the seed implementation, kept bit-exact)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _jitted_local_train(loss_fn, steps, lr, bs):
    """One jitted local-SGD program per (loss_fn, hyperparams) — repeated
    `execute` calls (the trainer shim's run-again pattern, benchmark
    timing loops) reuse the trace instead of recompiling."""
    return jax.jit(partial(_local_train_impl, loss_fn, steps, lr, bs))


@functools.lru_cache(maxsize=64)
def _jitted_acc(acc_fn):
    return jax.jit(acc_fn)


def _local_train_impl(loss_fn, steps, lr, bs, params, x, y, key):
    n = x.shape[0]

    def body(carry, k):
        p, = carry
        idx = jax.random.randint(k, (bs,), 0, n)
        batch = {"x": x[idx], "y": y[idx]}
        g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        return (p,), None

    keys = jax.random.split(key, steps)
    (p,), _ = jax.lax.scan(body, (params,), keys)
    return p


class _SequentialRunner:
    """The per-node upload pipeline + both reference loops, operating on a
    (plan, population, state) triple instead of trainer attributes.

    Stepper protocol: `step()` emits one `RoundRecord` (a barrier round,
    or n_nodes arrivals of the event loop); the loop state (clock /
    arrival heap / dispatch cache) lives on the instance so `repro.sim`
    can snapshot and restore it between records."""

    def __init__(self, plan: ExperimentPlan, pop: Population,
                 state: RunState):
        spec = plan.spec
        self.plan, self.pop, self.state, self.spec = plan, pop, state, spec
        self.node_data = [(jnp.asarray(x), jnp.asarray(y))
                          for x, y in pop.node_data]
        self.test_data = (jnp.asarray(pop.test_data[0]),
                          jnp.asarray(pop.test_data[1]))
        self.cloud_test = (jnp.asarray(pop.cloud_test[0]),
                           jnp.asarray(pop.cloud_test[1]))
        self.acc_fn = _jitted_acc(pop.acc_fn)
        self.n_params = sum(x.size for x in jax.tree.leaves(pop.params))
        self.node_time = np.asarray(pop.profile.compute_s, np.float64)
        self.node_bw = np.asarray(pop.profile.bandwidth_bps, np.float64)
        self._local_train = _jitted_local_train(
            pop.loss_fn, spec.train.local_steps, spec.train.lr,
            spec.train.batch_size)
        # -- stepper loop state -------------------------------------------
        n = pop.n_nodes
        self.emitted = 0
        self.pre_step = None
        self.net = None             # no repro.net on the reference loops
        if plan.mode == "sync":
            self.clock = 0.0
        else:
            self.version = 0
            # (arrival_time, node, dispatched_version, seq) heap
            self.events = []
            for node in range(n):
                heapq.heappush(self.events,
                               (self.node_time[node], node, 0, node))
            self.dispatched_params = {k: state.params for k in range(n)}
            self.acc_window: List[float] = []
            self.seq = n
            self.processed = 0

    # -- per-node upload pipeline ------------------------------------------
    def node_update(self, node: int, start_params):
        """Local train -> delta -> [accumulate/sparsify] -> [ALDP] -> ω_new.
        Returns (uploaded model, upload_bytes, cloud-test accuracy)."""
        plan, spec, state = self.plan, self.spec, self.state
        x, y = self.node_data[node]
        state.key, k1, k2 = jax.random.split(state.key, 3)
        local = self._local_train(start_params, x, y, k1)
        delta = jax.tree.map(lambda a, b: a - b, local, start_params)

        ratio = spec.compression.sparsify_ratio
        if ratio < 1.0:
            delta, state.residuals[node], _ = accum.accumulate_and_sparsify(
                state.residuals[node], delta, ratio)
            bytes_up = accum.upload_bytes(delta, ratio)
        else:
            bytes_up = self.n_params * 4

        if plan.sigma > 0:
            delta, _ = aldp.aldp_perturb(delta, k2, plan.sigma,
                                         spec.privacy.clip_s)
            state.accountant.step()   # accountant exists whenever sigma > 0

        omega_new = jax.tree.map(lambda a, b: a + b, start_params, delta)
        acc = float(self.acc_fn(omega_new, *self.cloud_test))
        return omega_new, bytes_up, acc

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.state.params, *self.test_data))

    # -- stepper protocol ---------------------------------------------------
    @property
    def done(self) -> bool:
        if self.plan.mode == "sync":
            return self.emitted >= self.spec.rounds
        return self.processed >= self.plan.total_arrivals

    def virtual_time(self) -> float:
        if self.plan.mode == "sync":
            return float(self.clock)
        return float(self.events[0][0])

    def step(self) -> None:
        if self.pre_step is not None:
            self.pre_step(self)
        if self.plan.mode == "sync":
            self._step_sync()
        else:
            self._step_async()

    def finalize(self) -> None:
        pass        # params/key/residuals already live on the RunState

    # -- synchronous barrier loop (one round per step) ----------------------
    def _step_sync(self) -> None:
        spec, state = self.spec, self.state
        n = self.pop.n_nodes
        alpha = spec.schedule.alpha
        uploads, accs, nbytes = [], [], 0.0
        for node in range(n):
            w, b, a = self.node_update(node, state.params)
            uploads.append(w)
            accs.append(a)
            nbytes += b
        accs = jnp.asarray(accs)
        if spec.defense.detect:
            mask, _ = detection.detect(accs, spec.defense.detect_s)
        else:
            mask = jnp.ones(n, bool)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *uploads)
        omega_new = detection.masked_mean(stacked, mask)
        state.params = async_update.mix(state.params, omega_new, alpha)
        comp = float(np.max(self.node_time))         # barrier: slowest
        comm = float(np.max((nbytes / n) / self.node_bw))  # parallel up
        self.clock += comp + comm
        state.history.append(RoundRecord(
            self.clock, self.emitted, self.global_accuracy(), nbytes, comp,
            comm, int(n - mask.sum())))
        self.emitted += 1

    # -- asynchronous per-arrival event loop (n_nodes arrivals per step) ----
    def _step_async(self) -> None:
        plan, spec, state = self.plan, self.spec, self.state
        n = self.pop.n_nodes
        alpha = spec.schedule.alpha
        # per-record accumulators: a RoundRecord spans n_nodes arrivals, so
        # traffic/time must be summed over the span, not the last arrival
        # (steps align with record boundaries, where the spans are zero)
        span_bytes = span_comp = span_comm = 0.0
        span_rejected = 0
        target = min(self.processed + n, plan.total_arrivals)
        t_arrive = 0.0
        while self.processed < target:
            t, node, v_disp, _ = heapq.heappop(self.events)
            w, b, a = self.node_update(node, self.dispatched_params[node])
            comm = float(b / self.node_bw[node])
            t_arrive = t + comm
            self.acc_window.append(a)
            self.acc_window = self.acc_window[-plan.detect_window:]
            rejected = 0
            if spec.defense.detect and \
                    len(self.acc_window) >= spec.defense.detect_warmup:
                accs = jnp.asarray(self.acc_window)
                thr = detection.detection_threshold(accs,
                                                    spec.defense.detect_s)
                if a <= float(thr):
                    rejected = 1
            if not rejected:
                staleness = self.version - v_disp
                if spec.schedule.staleness_adaptive:
                    state.params = async_update.mix_stale(
                        state.params, w, alpha, staleness)
                else:
                    state.params = async_update.mix(state.params, w, alpha)
                self.version += 1
            self.processed += 1
            span_bytes += b
            span_comp += float(self.node_time[node])
            span_comm += comm
            span_rejected += rejected
            # redispatch node with the fresh global model
            self.dispatched_params[node] = state.params
            heapq.heappush(self.events,
                           (t_arrive + self.node_time[node], node,
                            self.version, self.seq))
            self.seq += 1
        state.history.append(RoundRecord(
            t_arrive, self.version, self.global_accuracy(), span_bytes,
            span_comp, span_comm, span_rejected))
        self.emitted += 1

    # -- checkpoint/resume (repro.sim) --------------------------------------
    def export_state(self):
        state, n = self.state, self.pop.n_nodes
        arrays = {
            "params": jax.tree.map(np.asarray,
                                   jax.device_get(state.params)),
            "key": np.asarray(jax.device_get(state.key)),
            "residuals": jax.tree.map(
                np.asarray,
                jax.device_get(fleet.stack_trees(state.residuals))),
        }
        meta = {"emitted": self.emitted}
        if self.plan.mode == "sync":
            meta["clock"] = float(self.clock)
        else:
            # the heap is a multiset with a total order (seq is unique), so
            # any serialization order restores the identical pop sequence
            ev = sorted(self.events)
            arrays["heap_t"] = np.asarray([e[0] for e in ev], np.float64)
            arrays["heap_node"] = np.asarray([e[1] for e in ev], np.int64)
            arrays["heap_vdisp"] = np.asarray([e[2] for e in ev], np.int64)
            arrays["heap_seq"] = np.asarray([e[3] for e in ev], np.int64)
            arrays["dispatched"] = jax.tree.map(
                np.asarray,
                jax.device_get(fleet.stack_trees(
                    [self.dispatched_params[i] for i in range(n)])))
            meta.update(processed=self.processed, version=self.version,
                        seq=self.seq,
                        acc_window=[float(a) for a in self.acc_window])
        return arrays, meta

    def restore_state(self, arrays, meta) -> None:
        state, n = self.state, self.pop.n_nodes
        state.params = jax.tree.map(jnp.asarray, arrays["params"])
        state.key = jnp.asarray(arrays["key"])
        state.residuals = fleet.unstack_tree(
            jax.tree.map(jnp.asarray, arrays["residuals"]), n)
        self.emitted = int(meta["emitted"])
        if self.plan.mode == "sync":
            self.clock = float(meta["clock"])
        else:
            events = [(float(t), int(nd), int(v), int(s))
                      for t, nd, v, s in zip(arrays["heap_t"],
                                             arrays["heap_node"],
                                             arrays["heap_vdisp"],
                                             arrays["heap_seq"])]
            heapq.heapify(events)
            self.events = events
            disp = jax.tree.map(jnp.asarray, arrays["dispatched"])
            self.dispatched_params = {
                i: jax.tree.map(lambda x, i=i: x[i], disp) for i in range(n)}
            self.processed = int(meta["processed"])
            self.version = int(meta["version"])
            self.seq = int(meta["seq"])
            self.acc_window = [float(a) for a in meta["acc_window"]]


# ---------------------------------------------------------------------------
# top-level execution
# ---------------------------------------------------------------------------

def make_stepper(plan: ExperimentPlan, population: Population,
                 state: RunState, mesh: Optional["fleet.FleetMesh"] = None):
    """Build the record stepper a plan selects (engines constructed here
    pick up any installed obs tracer — call inside the session scope)."""
    if population.n_nodes != plan.spec.fleet.n_nodes:
        raise SpecError(
            f"population has {population.n_nodes} nodes but the plan was "
            f"compiled for fleet.n_nodes={plan.spec.fleet.n_nodes} — the "
            f"arrival budget and record cadence derive from the spec, so "
            f"a mismatched population would run the wrong experiment")
    tr = _obs.get_tracer()
    if tr.enabled:
        # ground truth for trace-only detection-quality reconstruction:
        # which nodes actually run the attack (analytics folds this into
        # the detect.verdict confusion matrix)
        tr.instant("fleet.population", n_nodes=population.n_nodes,
                   malicious=sorted(population.malicious_ids))
    if plan.engine == "fleet":
        eng = make_engine(plan, population, mesh=mesh)
        if plan.mode == "sync":
            return _SyncFleetStepper(plan, population, state, eng)
        if plan.mixing == "buffered":
            return _BufferedFleetStepper(plan, population, state, eng)
        return _AsyncFleetStepper(plan, population, state, eng)
    return _SequentialRunner(plan, population, state)


def execute(plan: ExperimentPlan, population: Population,
            state: RunState,
            session: Optional[_ObsSession] = None) -> List[RoundRecord]:
    """Run ``plan`` over ``population``, mutating ``state`` (records are
    appended to ``state.history``; params/key/residuals/accountant advance
    in place), so follow-on `execute` calls continue the run.  With a
    health-carrying obs ``session``, the probes are polled between
    records through the stepper's ``pre_step`` hook (the same seam the
    simulation service modulates traffic through)."""
    stepper = make_stepper(plan, population, state)
    if session is not None and session.health is not None:
        def _poll(st) -> None:
            session.poll_health(st.virtual_time(), len(state.history))
        stepper.pre_step = _poll
    while not stepper.done:
        stepper.step()
    stepper.finalize()
    return state.history


def run(plan: ExperimentPlan, population: Optional[Population] = None,
        sampler=None) -> RunReport:
    """Execute a compiled plan and return a structured `RunReport`.

    ``population`` defaults to `population.materialize(plan.spec)` (the
    declarative synthetic fleet); pass one explicitly to run the plan over
    real params/data.  ``sampler`` overrides the population's declared
    participation model.

    Plans carrying a `SimSpec` route through the always-on simulation
    service (`repro.sim.SimService`) — same report, plus checkpoint/
    traffic-trace/event-timeline behaviour along the way.
    """
    if plan.spec.sim is not None:
        from ..sim import SimService     # lazy: api must not import sim
        return SimService(plan, population=population, sampler=sampler).run()
    pop = population if population is not None else materialize(plan.spec)
    if sampler is not None:
        pop = dataclasses.replace(pop, sampler=sampler)
    state = init_state(plan, pop)
    session = _ObsSession(plan)
    streamed = session.history()
    if streamed is not None:
        state.history = streamed
    try:
        with session.scope():
            records = execute(plan, pop, state, session=session)
    except BaseException:
        session.finish(None)        # flush what streamed before the crash
        raise

    comm = sum(r.comm_time for r in records)
    comp = sum(r.comp_time for r in records)
    engine_name = ("fleet-mesh" if plan.mesh_devices is not None
                   else plan.engine)
    report = RunReport(
        mode=plan.mode, engine=engine_name, records=list(records),
        kappa=async_update.communication_efficiency(comm, comp),
        epsilon_spent=(state.accountant.epsilon(plan.spec.privacy.delta)
                       if state.accountant is not None else 0.0),
        final_accuracy=records[-1].accuracy if records else 0.0,
        detections=detection_log(records),
        spec=plan.spec.to_dict(),
        net=state.net,
        final_params=state.params)
    session.finish(report)
    return report
