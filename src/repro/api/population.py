"""Materialize a `FleetSpec` into an executable population.

A `Population` is everything the runner needs that is *not* declarative:
model params, loss/accuracy callables, per-node data shards, eval sets and
the materialized `NodeProfile`.  `materialize(spec)` builds one from the
spec's synthetic-data section (the same generator the scenario builders
and the sequential trainer use, seeded identically); callers with real
data construct a `Population` directly and hand it to `run.run` — the
declarative spec then describes the regime while the population carries
the arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np

from ..data import make_federated_image_data
from ..fleet.engine import (AvailabilityTrace, ClientSampler, NodeProfile,
                            UniformSampler)
from ..models.cnn import cnn_accuracy, cnn_loss, init_cnn
from ..models.mlp import init_mlp, mlp_accuracy, mlp_loss
from .spec import ExperimentSpec


@dataclass
class Population:
    """A concrete fleet: params, callables, data, system profile."""
    params: Any
    loss_fn: Callable
    acc_fn: Callable
    node_data: Sequence[Tuple[np.ndarray, np.ndarray]]
    test_data: Tuple[np.ndarray, np.ndarray]
    cloud_test: Tuple[np.ndarray, np.ndarray]
    profile: NodeProfile
    sampler: Optional[ClientSampler] = None
    malicious_ids: Tuple[int, ...] = ()

    @property
    def n_nodes(self) -> int:
        return len(self.node_data)


def default_sampler(spec: ExperimentSpec) -> Optional[ClientSampler]:
    """The participation model the spec declares: an availability/churn
    trace, a uniform 'm of K' cohort, or None (full participation)."""
    f = spec.fleet
    if f.availability < 1.0:
        return AvailabilityTrace(probs=np.full(f.n_nodes, f.availability),
                                 seed=spec.seed)
    if f.cohort_frac < 1.0:
        return UniformSampler(max(1, int(round(f.cohort_frac * f.n_nodes))),
                              seed=spec.seed)
    return None


def materialize(spec: ExperimentSpec) -> Population:
    """`FleetSpec` -> `Population` on synthetic federated image data.

    Deterministic in ``spec.seed``: the data partition, the model init and
    the lognormal compute profile all derive from it, so two materialize
    calls of the same spec are identical.
    """
    f = spec.fleet
    atk = f.attack
    n_malicious = int(round(atk.malicious_frac * f.n_nodes))
    node_data, test, cloud, malicious = make_federated_image_data(
        spec.seed, n_nodes=f.n_nodes, n_malicious=n_malicious,
        n_train=f.samples_per_node * f.n_nodes, n_test=f.n_test,
        n_cloud_test=f.n_cloud_test, hw=f.hw, n_classes=f.n_classes,
        flip_src=atk.flip_src, flip_dst=atk.flip_dst,
        iid=f.iid, dirichlet_alpha=f.dirichlet_alpha,
        attack_kind=atk.kind, placement=atk.placement,
        trigger_frac=atk.trigger_frac, trigger_label=atk.trigger_label,
        trigger_size=atk.trigger_size, trigger_value=atk.trigger_value)

    key = jax.random.PRNGKey(spec.seed)
    if f.model == "cnn":
        params = init_cnn(key, in_hw=f.hw)
        loss_fn, acc_fn = cnn_loss, cnn_accuracy
    else:
        params = init_mlp(key, in_dim=f.hw[0] * f.hw[1])
        loss_fn, acc_fn = mlp_loss, mlp_accuracy

    p = f.profile
    profile = NodeProfile.lognormal(
        f.n_nodes, p.base_compute_s, p.heterogeneity, p.bandwidth_bps,
        seed=spec.seed, straggler_frac=p.straggler_frac,
        straggler_slowdown=p.straggler_slowdown)
    if atk.kind == "sybil" and malicious:
        # a sybil cohort is one adversary behind many identities: identical
        # compute pins its clones' arrivals to the same async window, so
        # the colluding copies land (and collude) together
        comp = profile.compute_s.copy()
        comp[list(malicious)] = p.base_compute_s
        profile = NodeProfile(compute_s=comp,
                              bandwidth_bps=profile.bandwidth_bps)
    return Population(params=params, loss_fn=loss_fn, acc_fn=acc_fn,
                      node_data=node_data, test_data=test, cloud_test=cloud,
                      profile=profile, sampler=default_sampler(spec),
                      malicious_ids=tuple(int(m) for m in malicious))
