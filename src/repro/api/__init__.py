"""Declarative experiment API: spec -> plan -> run.

One typed entry layer over the whole framework — population
(`FleetSpec`), schedule (`SchedulePolicy` + pluggable `WindowPolicy`),
privacy (`PrivacySpec`), communication (`CompressionSpec`), defense
(`DefenseSpec`) and placement (`Topology`) — compiled once
(`compile_plan`, with cross-field validation) and executed uniformly
(`run`, returning a JSON-round-trippable `RunReport`).

    from repro import api

    spec = api.ExperimentSpec(
        fleet=api.FleetSpec(n_nodes=50,
                            attack=api.AttackMix(malicious_frac=0.2)),
        schedule=api.SchedulePolicy(kind="async"),
        privacy=api.PrivacySpec(sigma=0.05),
        defense=api.DefenseSpec(detect=True),
        rounds=8)
    report = api.run(api.compile_plan(spec))
    print(report.final_accuracy, report.kappa, report.epsilon_spent)

(The pre-redesign `FederatedTrainer(FedConfig(...))` surface was a
deprecation shim over this layer and has been removed; the sequential
reference loops it wrapped live on as `Topology(kind="sequential")`.)
"""
from .plan import (BACKENDS, NET_CODECS, SCHEDULE_KINDS,  # noqa: F401
                   TOPOLOGY_KINDS, ExperimentPlan, SpecError, compile_plan)
from .population import (Population, default_sampler,  # noqa: F401
                         materialize)
from .report import (RoundRecord, RunReport,  # noqa: F401
                     append_json_records, detection_log, load_json_records,
                     replay_records)
from .run import (RunState, execute, init_state,  # noqa: F401
                  make_engine, make_stepper, run)
from ..obs.health import HealthSpec  # noqa: F401  (the ObsSpec.health axis)
from .spec import (ACCEPTED_SCHEMA_VERSIONS, SCHEMA_VERSION,  # noqa: F401
                   AttackMix, CompressionSpec, DefenseSpec, ExperimentSpec,
                   FleetSpec, NetworkSpec, NodeHeterogeneity, ObsSpec,
                   PrivacySpec, SchedulePolicy, SimEvent, SimSpec, Topology,
                   TrafficTrace, TrainSpec, apply_sim_event)
from .window import (AutoWindow, FixedWindow,  # noqa: F401
                     TargetArrivalsWindow, WindowPolicy,
                     window_policy_from_dict)
