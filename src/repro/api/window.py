"""Pluggable window policies for the asynchronous schedules.

The `AsyncFleetEngine` batches every arrival inside a virtual-time window
[t0, t0 + W).  How long W should be is a *scheduling policy*, not a number:
the parity-safe choice (min node compute time — no node can re-arrive
inside its own window, so event-loop arrival order is preserved) trades
throughput for exactness, while a load-aware window targets a fixed number
of arrivals per device dispatch.  Policies are declarative objects on
`SchedulePolicy.window` so new windowing strategies (the ROADMAP's
load-aware scheduling) land as policy classes instead of more config
fields.

A policy resolves to the engine's ``window=`` argument:

  * ``None``  — the engine's parity-safe auto window;
  * a float   — an explicit virtual-time window length in seconds.

Resolution happens at run time because the answer can depend on the
materialized fleet (per-node compute/bandwidth in `NodeProfile`).
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import ClassVar, Dict, Optional, Type

import numpy as np


class WindowPolicy:
    """Base class: maps a materialized fleet to a window length."""

    kind: ClassVar[str] = "base"

    def resolve(self, profile, bytes_per_node: float) -> Optional[float]:
        """Window length in virtual seconds, or None for the engine's
        parity-safe auto window.  ``profile`` is a `fleet.NodeProfile`."""
        raise NotImplementedError

    def to_dict(self) -> Dict:
        d = {"kind": self.kind}
        for f in fields(self):  # type: ignore[arg-type]
            d[f.name] = getattr(self, f.name)
        return d


_REGISTRY: Dict[str, Type[WindowPolicy]] = {}


def _register(cls: Type[WindowPolicy]) -> Type[WindowPolicy]:
    _REGISTRY[cls.kind] = cls
    return cls


def window_policy_from_dict(d: Dict) -> WindowPolicy:
    d = dict(d)
    kind = d.pop("kind", None)
    if kind not in _REGISTRY:
        raise ValueError(f"unknown window policy kind {kind!r}; have "
                         f"{sorted(_REGISTRY)}")
    return _REGISTRY[kind](**d)


@_register
@dataclass(frozen=True)
class AutoWindow(WindowPolicy):
    """Parity-safe conservative window: the engine picks the minimum node
    compute time, preserving the sequential event loop's arrival order
    exactly (the mode the sequential-parity tests run in)."""

    kind: ClassVar[str] = "auto"

    def resolve(self, profile, bytes_per_node: float) -> Optional[float]:
        return None


@_register
@dataclass(frozen=True)
class FixedWindow(WindowPolicy):
    """An explicit virtual-time window length in seconds."""

    seconds: float = 1.0
    kind: ClassVar[str] = "fixed"

    def resolve(self, profile, bytes_per_node: float) -> Optional[float]:
        return float(self.seconds)


@_register
@dataclass(frozen=True)
class TargetArrivalsWindow(WindowPolicy):
    """Load-aware windowing: size the window so ~``target_arrivals``
    updates land per device dispatch (the ROADMAP's
    target-arrivals-per-window item for the buffered mode).

    Each node re-arrives with period ``compute_i + upload_i`` once the
    pipeline is warm, so the fleet's steady-state arrival rate is
    Σ 1/(compute_i + bytes/bandwidth_i) and the window that catches
    ``target_arrivals`` of them is ``target / rate``.  Larger targets mean
    fewer, fatter dispatches — coarser than the conservative auto window
    by design (FedBuff-style buffered aggregation, where arrival order
    inside the buffer no longer matters).
    """

    target_arrivals: int = 8
    kind: ClassVar[str] = "target_arrivals"

    def resolve(self, profile, bytes_per_node: float) -> Optional[float]:
        comp = np.asarray(profile.compute_s, np.float64)
        bw = np.asarray(profile.bandwidth_bps, np.float64)
        period = comp + bytes_per_node / bw
        rate = float(np.sum(1.0 / period))
        return float(self.target_arrivals) / rate
