"""Declarative experiment specs: one typed object per framework axis.

The paper's framework is one system with four composable axes — schedule
(sync/async/buffered), privacy (ALDP), communication (DGC sparsify) and
defense (cloud-side detection) — plus a population and a placement.  An
`ExperimentSpec` states each axis once:

  * `FleetSpec`      — population: size, per-node heterogeneity
                       (`NodeHeterogeneity`), attack mix (`AttackMix`),
                       availability/cohort sampling, synthetic-data shape;
  * `SchedulePolicy` — sync | async | buffered, Eq. (6) α, staleness
                       weighting, and a pluggable `WindowPolicy`;
  * `PrivacySpec`    — ALDP noise multiplier (explicit, calibrated from
                       (ε, δ), or off);
  * `CompressionSpec`— DGC sparsified uploads;
  * `DefenseSpec`    — Alg. 2 detection threshold/warmup/window;
  * `NetworkSpec`    — `repro.net` wire codecs + virtual-time link
                       simulation (default: the analytic comm model);
  * `Topology`       — sequential reference loop | single-device fleet
                       engines | node-axis `FleetMesh` sharding;
  * `TrainSpec`      — node-local SGD hyperparameters;
  * `SimSpec`        — optional always-on-service axis: time-varying
                       `TrafficTrace`s, a `SimEvent` mutation timeline and
                       a checkpoint cadence (executed by `repro.sim`).

`plan.compile_plan` validates cross-field constraints once and lowers a
spec to an `ExperimentPlan`; `run.run` executes a plan.  Specs are plain
frozen dataclasses and JSON-round-trippable (`to_dict`/`from_dict`, with a
``schema_version`` field) so experiment definitions can live in files
instead of flag soup.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from ..obs.health import HealthSpec
from .window import AutoWindow, WindowPolicy, window_policy_from_dict

# v2: NetworkSpec axis + RoundRecord.bytes_source.  v3: ObsSpec axis.
# v4: the adversary zoo (AttackMix.kind + per-kind knobs, seeded-random
# malicious placement, FleetSpec.n_classes) and the trust-scored defense
# (DefenseSpec.kind + trust knobs).  v5: the simulation-service axis
# (ExperimentSpec.sim: traffic traces + event timeline + checkpoint
# cadence) and RunReport resume metadata.  v6: the fleet-health axis
# (ObsSpec.health: HealthSpec SLO probes + incident detection).  Older
# payloads are still accepted on read (health defaults to None — no
# probes); everything written is stamped v6.
SCHEMA_VERSION = 6
ACCEPTED_SCHEMA_VERSIONS = (1, 2, 3, 4, 5, 6)


# ---------------------------------------------------------------------------
# population
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NodeHeterogeneity:
    """Per-node system model: lognormal compute speeds around
    ``base_compute_s`` plus an optional straggler tail, uniform uplink
    bandwidth (matches `fleet.NodeProfile.lognormal`)."""
    base_compute_s: float = 1.0
    heterogeneity: float = 0.5          # lognormal sigma of node speeds
    bandwidth_bps: float = 12.5e6       # 100 Mbit/s edge uplink
    straggler_frac: float = 0.0
    straggler_slowdown: float = 10.0


@dataclass(frozen=True)
class AttackMix:
    """Adversary composition: ``malicious_frac`` of nodes run the attack
    selected by ``kind`` (the adversary zoo).

    ``kind="label_flip"`` — the paper's poisoning attack: flip labels
      ``flip_src`` -> ``flip_dst`` in the malicious nodes' local shards;
    ``kind="sybil"``      — colluding clones: every sybil trains the same
      flipped shard on an identical compute cadence (so their uploads land
      inside one async arrival window) and scales its poisoned delta by
      ``sybil_boost``;
    ``kind="backdoor"``   — trigger poisoning: ``trigger_frac`` of each
      malicious shard gets a ``trigger_size``² corner patch of
      ``trigger_value`` and label ``trigger_label`` (clean-label accuracy
      stays high — percentile detection is nearly blind to it);
    ``kind="adaptive"``   — detection-aware label flipper: a per-node
      throttle scales the poisoned delta down by ``adapt_poison_scale``
      whenever the cloud rejects the node, creeping back up on acceptance
      — hovering under the accuracy threshold;
    ``kind="ddos"``       — clean-data flash traffic: each malicious node
      injects ``ddos_uploads`` flood uploads per round/window into the
      shared uplink (`NetworkSpec.shared_uplink_bps`), starving honest
      transfers without ever uploading a detectable model.

    ``placement`` places the malicious ids: ``"random"`` draws them from a
    seeded stream (reproducible per spec seed); ``"first"`` keeps the
    legacy nodes ``0..k-1`` placement.
    """
    malicious_frac: float = 0.0
    flip_src: int = 1
    flip_dst: int = 7
    kind: str = "label_flip"
    sybil_boost: float = 3.0
    adapt_poison_scale: float = 0.5
    trigger_frac: float = 0.5
    trigger_label: int = 0
    trigger_size: int = 2
    trigger_value: float = 1.0
    ddos_uploads: int = 4
    placement: str = "random"


@dataclass(frozen=True)
class FleetSpec:
    """The node population and its synthetic federated dataset."""
    n_nodes: int = 10
    profile: NodeHeterogeneity = field(default_factory=NodeHeterogeneity)
    attack: AttackMix = field(default_factory=AttackMix)
    availability: float = 1.0       # per-round P(node reachable); <1 => churn
    cohort_frac: float = 1.0        # uniform 'm of K' sampling; <1 => sampled
    # synthetic data shape (materialized by `population.materialize`)
    model: str = "mlp"              # mlp | cnn
    hw: Tuple[int, int] = (8, 8)
    samples_per_node: int = 60
    n_test: int = 256
    n_cloud_test: int = 128
    iid: bool = True                # False => Dirichlet(alpha) partition
    dirichlet_alpha: float = 0.5
    n_classes: int = 10             # label alphabet (bounds flip/trigger ids)


# ---------------------------------------------------------------------------
# the four framework axes + placement
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SchedulePolicy:
    """When updates meet the global model.

    ``kind="sync"``     — FedAvg barrier rounds;
    ``kind="async"``    — Eq. (6) α-mix per arrival, in arrival order;
    ``kind="buffered"`` — FedBuff-style: one masked-mean Eq. (6) mix per
                          arrival window (pairs naturally with a
                          load-aware `WindowPolicy`).

    ``staleness_adaptive`` applies the FedAsync (τ+1)^-``staleness_a``
    discount: per arrival for ``kind="async"`` (`mix_stale`), and as
    per-update weights inside the buffered mean for ``kind="buffered"``
    (uniform weights ≡ the plain masked mean).
    """
    kind: str = "sync"
    alpha: float = 0.5                  # Eq. (6) mixing weight
    staleness_adaptive: bool = False    # FedAsync (τ+1)^-a weighting
    staleness_a: float = 0.5
    window: WindowPolicy = field(default_factory=AutoWindow)


@dataclass(frozen=True)
class PrivacySpec:
    """ALDP (§5.2): ``sigma=0`` disables noise (and the accountant);
    ``sigma=None`` calibrates the multiplier from (ε, δ) per Definition 2;
    an explicit ``sigma>0`` is used as-is."""
    sigma: Optional[float] = 0.0
    epsilon: float = 8.0
    delta: float = 1e-3
    clip_s: float = 1.0


@dataclass(frozen=True)
class CompressionSpec:
    """DGC gradient-accumulation uploads (§5.1): keep the top
    ``sparsify_ratio`` of delta magnitude, accumulate the rest locally."""
    sparsify_ratio: float = 1.0


@dataclass(frozen=True)
class DefenseSpec:
    """Cloud-side malicious-update detection (§5.4, Alg. 2).

    ``kind="percentile"`` keeps the paper's accuracy-percentile accept/
    reject gate.  ``kind="trust_weighted"`` layers per-node trust scores
    on top: each verdict moves a node's trust by an EWMA
    (``trust_eta``), and accepted updates are aggregated with
    trust/uncertainty weights — trust floored at ``trust_floor`` and
    discounted by ``uncertainty_scale`` × the node's accuracy deviation
    from the accepted cohort mean (a cheap per-update uncertainty
    proxy).  Requires ``detect=True``; trust state lives device-side in
    `FleetState.trust` (ring-compatible, shard-oblivious).
    """
    detect: bool = False
    detect_s: float = 80.0              # top-s percentile threshold
    detect_warmup: int = 4              # async: min arrivals before detecting
    detect_window: Optional[int] = None  # async ring; None => default_window
    kind: str = "percentile"            # percentile | trust_weighted
    trust_eta: float = 0.25             # EWMA step toward each verdict
    trust_floor: float = 0.05           # min aggregation weight for accepted
    uncertainty_scale: float = 4.0      # accuracy-deviation discount strength


@dataclass(frozen=True)
class NetworkSpec:
    """The `repro.net` transport layer: wire codec + link simulation.

    ``codec="analytic"`` (default) keeps the pre-net behaviour — upload
    bytes estimated by the shared analytic formula, per-node transfer
    times fixed at bytes/bandwidth — so existing trajectories are
    untouched.  Any real codec turns on byte-accurate accounting (every
    upload's measured nonzero count priced through the codec, summed into
    `RunReport.net` and the records' ``comm_bytes``) and the stochastic
    link model (per-node lognormal bandwidth scales, fixed latency,
    exponential jitter, MTU-packetized loss/retransmits, optional
    shared-uplink contention), which drives the async engines' node
    clocks — arrival order and window composition respond to the network.
    """
    codec: str = "analytic"         # analytic | dense_f32 | sparse_coo
                                    # | sparse_bitpack
    value_bits: int = 32            # 8|16: sparse_bitpack quantized values
    bandwidth_sigma: float = 0.0    # lognormal sigma of per-node uplink scale
    latency_s: float = 0.0          # fixed per-upload propagation latency
    jitter_s: float = 0.0           # exponential per-upload jitter scale
    loss_prob: float = 0.0          # per-packet loss probability
    mtu_bytes: int = 1500           # packet size for the loss model
    shared_uplink_bps: float = 0.0  # >0: uplink shared by concurrent uploads

    @property
    def enabled(self) -> bool:
        return self.codec != "analytic"


@dataclass(frozen=True)
class ObsSpec:
    """The `repro.obs` observability layer for one run.

    Default (disabled) is a strict no-op: no tracer is installed, no event
    is constructed, and the engines' jitted programs are byte-identical to
    an obs-less build — enabling observability is free until asked for,
    and asking for it never changes simulation results (only, with
    ``stage_timings``, host-side pipelining).

      * ``events_jsonl``  — stream every `TraceEvent` (window spans,
        arrival instants, detection verdicts, per-upload link events) to
        this path as crash-safe JSONL, plus a final metrics snapshot;
      * ``chrome_trace``  — write the run's events as Chrome
        ``trace_event`` JSON (Perfetto-loadable: nodes as tracks, windows
        as spans, arrivals as instants);
      * ``records_jsonl`` — stream each `RoundRecord` to this path as it
        is produced (instead of only the at-end `RunReport` dump); the
        stream replays back into the exact final report
        (`report.replay_records`);
      * ``stage_timings`` — `block_until_ready`-fenced spans around each
        host pipeline stage (build/device program/net draw+commit/eval).
        Off by default even when tracing: fencing serializes JAX's async
        dispatch, an intentional measurement-mode perf change;
      * ``health``        — optional `repro.obs.HealthSpec`: declarative
        SLO probes (straggler factor, per-record byte budget, detection
        reject-rate ceiling, occupancy floor) evaluated between records,
        emitting ``health.alert`` instants and ``health.incident`` spans
        into the same trace stream.  Requires ``enabled=True``; probes
        only *read* derived analytics and *write* events, so the
        simulation trajectory is untouched.
    """
    enabled: bool = False
    events_jsonl: Optional[str] = None
    chrome_trace: Optional[str] = None
    records_jsonl: Optional[str] = None
    stage_timings: bool = False
    health: Optional[HealthSpec] = None


@dataclass(frozen=True)
class Topology:
    """Where the simulation runs.

    ``kind="sequential"`` — the per-node/per-arrival reference loops
    (the seed implementation; slow, bit-exact ground truth);
    ``kind="single"``     — the cohort/window-batched fleet engines on one
    device; ``kind="mesh"`` — node axis sharded over ``devices`` local
    devices via `fleet.FleetMesh` (None = all local devices).
    """
    kind: str = "single"
    devices: Optional[int] = None
    backend: str = "reference"          # reference | pallas upload pipeline


@dataclass(frozen=True)
class TrainSpec:
    """Node-local minibatch SGD."""
    local_steps: int = 5
    batch_size: int = 16
    lr: float = 0.1


# ---------------------------------------------------------------------------
# the simulation-service axis (repro.sim)
# ---------------------------------------------------------------------------

TRACE_KINDS = ("diurnal", "flash_crowd", "outage")
SIM_EVENT_KINDS = ("attack", "defense", "network", "nodes")


@dataclass(frozen=True)
class TrafficTrace:
    """One time-varying traffic component, a pure function of virtual time.

    ``kind="diurnal"``     — fleet-wide sinusoidal bandwidth modulation:
      every node's effective uplink rate is scaled by
      ``1 - amplitude * (0.5 + 0.5 * sin(2π (t - phase_s) / period_s))``
      (peak load = deepest throttle);
    ``kind="flash_crowd"`` — during ``[t_start, t_start + duration_s)`` a
      contiguous regional block of ``node_frac`` of the fleet (starting at
      node ``floor(region_start * n)``, wrapping) has its uplink scaled by
      ``1 - amplitude`` (a crowd saturating the regional backhaul);
    ``kind="outage"``      — the same regional block is unreachable for
      the epoch: its nodes drop out of sync cohorts and their async
      arrivals are discarded/redispatched by the churn sampler.

    Traces compose multiplicatively (bandwidth) / conjunctively
    (availability), and being pure in ``t`` they are resume-safe by
    construction.
    """
    kind: str = "diurnal"
    period_s: float = 86400.0
    amplitude: float = 0.5
    phase_s: float = 0.0
    t_start: float = 0.0
    duration_s: float = 0.0
    node_frac: float = 1.0
    region_start: float = 0.0


@dataclass(frozen=True)
class SimEvent:
    """A scheduled mid-run mutation, applied between rounds/windows.

    ``at_round`` is the record index (sync round or async window-group)
    *before* which the event fires.  ``kind`` picks the spec slice:

      * ``"attack"``  — replace `AttackMix` fields (e.g. attack onset:
        ``{"malicious_frac": 0.5, "kind": "label_flip"}``; offset:
        ``{"malicious_frac": 0.0}``);
      * ``"defense"`` — replace `DefenseSpec` fields (defense toggles);
      * ``"network"`` — replace `NetworkSpec` fields (link-regime shifts);
      * ``"nodes"``   — membership churn: ``{"leave": [ids], "join":
        [ids]}`` (joins re-admit previously-left nodes).

    Payloads for the spec-slice kinds are re-validated by `compile_plan`
    at submission time: every cumulative mutation along the timeline must
    itself compile.
    """
    at_round: int = 1
    kind: str = "attack"
    payload: Dict = field(default_factory=dict)


@dataclass(frozen=True)
class SimSpec:
    """The always-on simulation service axis.

    Attaching a `SimSpec` routes `api.run` through `repro.sim.SimService`:
    the run becomes steppable, checkpoint/resumable (bit-exact), traffic-
    modulated (``traces``) and mutable mid-run (``events``).  The empty
    default mutates nothing — the service then reproduces the batch run
    exactly.
    """
    traces: Tuple[TrafficTrace, ...] = ()
    events: Tuple[SimEvent, ...] = ()
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0       # checkpoint every k records; 0 = manual


def apply_sim_event(spec: "ExperimentSpec", event: SimEvent) -> "ExperimentSpec":
    """The spec produced by one timeline event (pure; ``nodes`` events are
    membership-level and leave the spec untouched)."""
    payload = dict(event.payload)
    if event.kind == "attack":
        attack = dataclasses.replace(spec.fleet.attack, **payload)
        return dataclasses.replace(
            spec, fleet=dataclasses.replace(spec.fleet, attack=attack))
    if event.kind == "defense":
        return dataclasses.replace(
            spec, defense=dataclasses.replace(spec.defense, **payload))
    if event.kind == "network":
        return dataclasses.replace(
            spec, network=dataclasses.replace(spec.network, **payload))
    if event.kind == "nodes":
        return spec
    raise ValueError(f"unknown SimEvent kind {event.kind!r} "
                     f"(expected one of {SIM_EVENT_KINDS})")


# ---------------------------------------------------------------------------
# the whole experiment
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExperimentSpec:
    fleet: FleetSpec = field(default_factory=FleetSpec)
    schedule: SchedulePolicy = field(default_factory=SchedulePolicy)
    privacy: PrivacySpec = field(default_factory=PrivacySpec)
    compression: CompressionSpec = field(default_factory=CompressionSpec)
    defense: DefenseSpec = field(default_factory=DefenseSpec)
    network: NetworkSpec = field(default_factory=NetworkSpec)
    obs: ObsSpec = field(default_factory=ObsSpec)
    topology: Topology = field(default_factory=Topology)
    train: TrainSpec = field(default_factory=TrainSpec)
    sim: Optional[SimSpec] = None   # None => plain batch run
    rounds: int = 10        # sync rounds; async runs rounds*n_nodes arrivals
    seed: int = 0

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict:
        d = {"schema_version": SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, WindowPolicy):
                v = v.to_dict()
            elif dataclasses.is_dataclass(v):
                v = _section_to_dict(v)
            d[f.name] = v
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("schema_version", None)
        if version not in ACCEPTED_SCHEMA_VERSIONS:
            raise ValueError(
                f"ExperimentSpec schema_version {version!r} not in "
                f"supported {ACCEPTED_SCHEMA_VERSIONS}")
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            if f.name == "fleet":
                v = _fleet_from_dict(v)
            elif f.name == "schedule":
                v = _schedule_from_dict(v)
            elif f.name == "sim":
                v = _sim_from_dict(v)
            elif f.name == "obs":
                v = _obs_from_dict(v)
            elif f.name in _SECTION_TYPES:
                v = _SECTION_TYPES[f.name](**v)
            kw[f.name] = v
        return cls(**kw)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))


_SECTION_TYPES = {
    "privacy": PrivacySpec,
    "compression": CompressionSpec,
    "defense": DefenseSpec,
    "network": NetworkSpec,
    "obs": ObsSpec,
    "topology": Topology,
    "train": TrainSpec,
}


def _section_to_dict(v) -> Dict:
    """dataclasses.asdict, but tuples stay JSON-friendly lists and nested
    dataclasses recurse."""
    out = {}
    for f in dataclasses.fields(v):
        x = getattr(v, f.name)
        if isinstance(x, WindowPolicy):
            x = x.to_dict()
        elif dataclasses.is_dataclass(x):
            x = _section_to_dict(x)
        elif isinstance(x, tuple):
            x = [_section_to_dict(e) if dataclasses.is_dataclass(e) else e
                 for e in x]
        out[f.name] = x
    return out


def _fleet_from_dict(d: Dict) -> FleetSpec:
    d = dict(d)
    if "profile" in d:
        d["profile"] = NodeHeterogeneity(**d["profile"])
    if "attack" in d:
        d["attack"] = AttackMix(**d["attack"])
    if "hw" in d:
        d["hw"] = tuple(d["hw"])
    return FleetSpec(**d)


def _schedule_from_dict(d: Dict) -> SchedulePolicy:
    d = dict(d)
    if "window" in d and not isinstance(d["window"], WindowPolicy):
        d["window"] = window_policy_from_dict(d["window"])
    return SchedulePolicy(**d)


def _obs_from_dict(d) -> ObsSpec:
    if isinstance(d, ObsSpec):
        return d
    d = dict(d)
    h = d.get("health")
    if h is not None and not isinstance(h, HealthSpec):
        d["health"] = HealthSpec(**h)
    return ObsSpec(**d)


def _sim_from_dict(d) -> Optional[SimSpec]:
    if d is None or isinstance(d, SimSpec):
        return d
    d = dict(d)
    d["traces"] = tuple(
        t if isinstance(t, TrafficTrace) else TrafficTrace(**t)
        for t in d.get("traces", ()))
    d["events"] = tuple(
        e if isinstance(e, SimEvent) else SimEvent(**e)
        for e in d.get("events", ()))
    return SimSpec(**d)
