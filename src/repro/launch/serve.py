"""Batched serving driver: prefill a batch of prompts, then decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import decode_step, init_cache, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.replace(attn_chunk=min(cfg.attn_chunk, args.prompt_len))
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_audio_frames, cfg.d_model)), jnp.float32)

    cache_len = args.prompt_len + args.gen + (
        cfg.n_patches if cfg.family == "vlm" else 0)
    cache = init_cache(cfg, B, cache_len, dtype=jnp.float32)

    jpre = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))
    jdec = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    t0 = time.time()
    logits, cache = jpre(params, batch, cache)
    tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = jdec(params, tok, cache)
        tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s ({B*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode: {dt:.3f}s ({B*(args.gen-1)/max(dt,1e-9):.0f} tok/s)")
    print("sample generations (token ids):")
    for row in np.asarray(gen)[:2]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
