"""Run the full dry-run sweep: every (arch × shape × mesh) as a subprocess
(fresh jax per combo — the forced 512-device init must precede jax import).

  PYTHONPATH=src python -m repro.launch.dryrun_all --out results/dryrun [--multi-pod-too]

Resumable: combos with an existing JSON are skipped.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ("smollm-360m", "olmo-1b", "qwen1.5-0.5b", "codeqwen1.5-7b",
         "falcon-mamba-7b", "zamba2-1.2b", "whisper-large-v3",
         "qwen2-vl-72b", "llama4-scout-17b-a16e", "kimi-k2-1t-a32b")
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: str,
            step: str = "auto", timeout: int = 3600) -> dict:
    mesh = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}.{shape}.{mesh}" + ("" if step == "auto" else f".{step}")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--step", step, "--out", path]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    env = dict(os.environ)
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        ok = r.returncode == 0
    except subprocess.TimeoutExpired:
        ok = False
        r = None
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
    else:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
               "error": (r.stdout[-2000:] + r.stderr[-2000:]) if r else
               f"timeout after {timeout}s"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    rec["_wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--multi-pod-too", action="store_true")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = [False] + ([True] if args.multi_pod_too else [])
    total = ok = 0
    for multi in meshes:
        for arch in args.archs.split(","):
            for shape in args.shapes.split(","):
                rec = run_one(arch, shape, multi, args.out,
                              timeout=args.timeout)
                total += 1
                status = rec.get("status")
                ok += status in ("ok", "skipped")
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"[{ok}/{total}] {arch:24s} {shape:12s} "
                      f"{'2x16x16' if multi else '16x16':8s} {status:8s} "
                      f"dom={dom} wall={rec.get('_wall_s', '-')}s",
                      flush=True)
    print(f"done: {ok}/{total} ok")


if __name__ == "__main__":
    main()
