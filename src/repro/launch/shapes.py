"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model input.

``input_specs(cfg, shape_name, ...)`` returns the exact argument pytrees the
corresponding step function is lowered with — weak-type-correct, shardable,
and never allocated (ShapeDtypeStruct only).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.fed_step import FedStepConfig
from ..models import init_cache, init_params
from ..models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}

# long_500k decode for pure full-attention archs uses the sliding-window
# variant (see configs.registry.long_context_variant); whisper skips it.
LONG_SKIP = ("whisper-large-v3",)


def _lm_batch(cfg: ModelConfig, batch: int, seq: int, *, targets: bool,
              lead: Tuple[int, ...] = ()) -> dict:
    """Token batch structs with family extras (patch/frame stubs)."""
    s_text = seq
    out: dict = {}
    if cfg.family == "vlm":
        s_text = seq - cfg.n_patches
        out["patches"] = SDS(lead + (batch, cfg.n_patches, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        out["frames"] = SDS(lead + (batch, cfg.n_audio_frames, cfg.d_model),
                            jnp.dtype(cfg.compute_dtype))
    out["tokens"] = SDS(lead + (batch, s_text), jnp.int32)
    if targets:
        out["targets"] = SDS(lead + (batch, s_text), jnp.int32)
    return out


def fed_layout(shape: InputShape, n_nodes: int,
               local_steps: int) -> Tuple[int, int, int]:
    """(nodes, local_steps, per_node_batch) factorisation of global_batch."""
    per = shape.global_batch // (n_nodes * local_steps)
    assert per >= 1, (shape.global_batch, n_nodes, local_steps)
    return n_nodes, local_steps, per


def params_struct(cfg: ModelConfig, key=None):
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda kk: init_params(cfg, kk), k)


def cache_struct(cfg: ModelConfig, batch: int, cache_len: int):
    C = cache_len
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, C, dtype=jnp.bfloat16))


def input_specs(cfg: ModelConfig, shape_name: str, *,
                step: str = "auto", fcfg: Optional[FedStepConfig] = None
                ) -> dict:
    """Returns {"args": tuple_of_structs, "kind": str} for the step function.

    step: 'fed' | 'plain' (train shapes), 'auto' picks by shape kind.
    """
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        if step in ("auto", "fed"):
            assert fcfg is not None
            n, h, per = fed_layout(shape, fcfg.n_nodes, fcfg.local_steps)
            node_batches = _lm_batch(cfg, per, shape.seq_len, targets=True,
                                     lead=(n, h))
            eval_batch = _lm_batch(cfg, 2, min(shape.seq_len, 4096),
                                   targets=True)
            key = SDS((2,), jnp.uint32)
            return {"kind": "fed_train",
                    "args": (params_struct(cfg), node_batches, eval_batch, key)}
        batch = _lm_batch(cfg, shape.global_batch, shape.seq_len, targets=True)
        return {"kind": "plain_train",
                "args": (params_struct(cfg), batch)}
    if shape.kind == "prefill":
        batch = _lm_batch(cfg, shape.global_batch, shape.seq_len, targets=False)
        cache_len = min(shape.seq_len, cfg.sliding_window) \
            if cfg.sliding_window else shape.seq_len
        cache = cache_struct(cfg, shape.global_batch, cache_len)
        return {"kind": "prefill",
                "args": (params_struct(cfg), batch, cache)}
    # decode
    cache_len = min(shape.seq_len, cfg.sliding_window) \
        if cfg.sliding_window else shape.seq_len
    cache = cache_struct(cfg, shape.global_batch, cache_len)
    tokens = SDS((shape.global_batch, 1), jnp.int32)
    return {"kind": "decode",
            "args": (params_struct(cfg), tokens, cache)}
