"""End-to-end training driver.

Runs real (CPU-executable) training of any --arch (smoke variant by default;
full configs are for the dry-run mesh) in either mode:

  fed   — the paper's ALDPFL round: local steps -> ALDP -> detection -> α-mix
  plain — synchronous baseline (SFL)

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --mode fed --rounds 20 --nodes 4 --local-steps 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpointing import save_checkpoint
from ..configs import get_config, get_smoke_config
from ..core.fed_step import FedStepConfig
from ..data.synthetic import make_token_dataset
from ..models import init_params, loss_fn
from .steps import make_step


def make_batches(cfg, tokens: np.ndarray, lead_shape, seq: int, rng):
    """Sample token windows into the requested leading shape."""
    n_seq = int(np.prod(lead_shape))
    idx = rng.integers(0, tokens.shape[0], n_seq)
    toks = tokens[idx, :seq]
    tgts = tokens[idx, 1:seq + 1]
    batch = {"tokens": toks.reshape(lead_shape + (seq,)),
             "targets": tgts.reshape(lead_shape + (seq,))}
    if cfg.family == "vlm":
        batch["patches"] = rng.normal(
            0, 1, lead_shape + (cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        batch["frames"] = rng.normal(
            0, 1, lead_shape + (cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
    return jax.tree.map(jnp.asarray, batch)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--mode", default="fed", choices=("fed", "plain"))
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2, help="per node per step")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--sigma", type=float, default=1e-3)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--no-detect", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.replace(attn_chunk=min(cfg.attn_chunk, args.seq))
    rng = np.random.default_rng(0)
    data = make_token_dataset(0, 512, args.seq, cfg.vocab)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M mode={args.mode}")

    if args.mode == "fed":
        fcfg = FedStepConfig(n_nodes=args.nodes, local_steps=args.local_steps,
                             lr=args.lr, alpha=args.alpha, sigma=args.sigma,
                             detect=not args.no_detect)
        step = jax.jit(make_step(cfg, "fed_train", fcfg=fcfg))
        key = jax.random.PRNGKey(1)
        for r in range(args.rounds):
            nb = make_batches(cfg, data, (args.nodes, args.local_steps,
                                          args.batch), args.seq, rng)
            eb = make_batches(cfg, data, (2,), args.seq, rng)
            key, k = jax.random.split(key)
            t0 = time.time()
            params, m = step(params, nb, eb, k)
            print(f"round {r:3d} loss={float(m['loss']):.4f} "
                  f"acc={float(m['node_accuracies'].mean()):.3f} "
                  f"normal={int(m['n_normal'])}/{args.nodes} "
                  f"dt={time.time()-t0:.2f}s", flush=True)
    else:
        step = jax.jit(make_step(cfg, "plain_train", lr=args.lr))
        gb = args.nodes * args.local_steps * args.batch
        for r in range(args.rounds):
            b = make_batches(cfg, data, (gb,), args.seq, rng)
            t0 = time.time()
            params, l = step(params, b)
            print(f"step {r:3d} loss={float(l):.4f} dt={time.time()-t0:.2f}s",
                  flush=True)

    eb = make_batches(cfg, data, (8,), args.seq, rng)
    final_loss, metrics = loss_fn(params, cfg, eb)
    print(f"final eval: loss={float(final_loss):.4f} "
          f"acc={float(metrics['accuracy']):.3f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.rounds)
        print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
