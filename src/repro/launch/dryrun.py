import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh and extract roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod] [--step fed|plain|auto] --out out.json

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — which is why this is the only entry point that sets it.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from ..configs import get_config, long_context_variant      # noqa: E402
from ..core.fed_step import FedStepConfig                    # noqa: E402
from ..launch import roofline as rl                          # noqa: E402
from ..launch.hlo_cost import analyze_hlo_text               # noqa: E402
from ..launch.mesh import make_production_mesh               # noqa: E402
from ..launch.shapes import LONG_SKIP, SHAPES, input_specs   # noqa: E402
from ..launch.steps import arg_pspecs, dp_axes_for, make_step  # noqa: E402
from ..sharding.rules import shardings_for                   # noqa: E402


def resolve_config(arch: str, shape_name: str, ssm_chunk: int = 0,
                   seq_parallel: bool = False):
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if arch in LONG_SKIP:
            return None
        cfg = long_context_variant(cfg)
    if ssm_chunk and cfg.ssm is not None:
        import dataclasses
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    if seq_parallel:
        cfg = cfg.replace(seq_parallel=True)
    return cfg


def build_fcfg(cfg, mesh, local_steps: int = 4) -> FedStepConfig:
    import numpy as np
    n_nodes = int(np.prod([mesh.shape[a] for a in dp_axes_for(mesh)]))
    return FedStepConfig(n_nodes=n_nodes, local_steps=local_steps,
                         lr=1e-2, alpha=0.5, clip_s=1.0, sigma=1e-3,
                         detect=True, detect_s=80.0)


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               step: str = "auto", local_steps: int = 4,
               keep_hlo: bool = False, ssm_chunk: int = 0,
               seq_parallel: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "ok"}
    cfg = resolve_config(arch, shape_name, ssm_chunk, seq_parallel)
    if cfg is None:
        rec.update(status="skipped",
                   reason="encoder-decoder: 500k autoregressive transcript "
                          "decode has no serving analogue (DESIGN.md §5)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    shape = SHAPES[shape_name]

    fcfg = build_fcfg(cfg, mesh, local_steps) if shape.kind == "train" else None
    spec = input_specs(cfg, shape_name, step=step, fcfg=fcfg)
    kind, args = spec["kind"], spec["args"]
    rec["step_kind"] = kind
    dp = dp_axes_for(mesh)
    pspecs = arg_pspecs(cfg, kind, mesh, args)
    in_shardings = shardings_for(mesh, pspecs)
    step_fn = make_step(cfg, kind, fcfg=fcfg,
                        spmd_axes=dp if kind == "fed_train" else None,
                        param_shardings=(in_shardings[0]
                                         if kind == "plain_train" else None))

    from ..sharding.ctx import mesh_context
    t0 = time.time()
    with mesh_context(mesh, dp):
        jitted = jax.jit(step_fn, in_shardings=in_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    rec["timings"] = {"lower_s": round(t_lower, 2),
                      "compile_s": round(t_compile, 2)}

    # ---- memory ----
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        tot = (rec["memory"].get("argument_size_in_bytes", 0)
               + rec["memory"].get("temp_size_in_bytes", 0))
        rec["memory"]["per_device_total_gib"] = round(tot / n_dev / 2**30, 3)
    except Exception as e:                                   # pragma: no cover
        rec["memory"] = {"error": str(e)}

    # ---- XLA's own cost analysis (counts while bodies ONCE — raw ref) ----
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds", "utilization")}
    except Exception as e:                                   # pragma: no cover
        cost = {"error": str(e)}
    rec["cost_xla_raw"] = cost

    # ---- trip-count-corrected per-device cost from partitioned HLO ----
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    hc = analyze_hlo_text(hlo)
    rec["cost"] = {"flops": hc.flops, "bytes": hc.bytes,
                   "unknown_trip_counts": hc.unknown_trip_counts}
    rec["collectives"] = {"bytes_by_type": hc.coll_bytes,
                          "count_by_type": hc.coll_counts,
                          "total_bytes_per_device": int(hc.total_coll_bytes)}
    if keep_hlo:
        rec["hlo_lines"] = hlo.count("\n")

    # ---- roofline ----
    flops_dev = hc.flops
    bytes_dev = hc.bytes
    terms = rl.roofline_terms(flops_dev, bytes_dev, hc.total_coll_bytes)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = rl.model_flops(cfg, kind, tokens)
    rec["roofline"] = terms
    rec["roofline"]["model_flops_global"] = mf
    rec["roofline"]["attention_flops_global"] = rl.attention_flops(
        cfg, kind, shape.global_batch, shape.seq_len)
    hlo_flops_global = flops_dev * n_dev
    rec["roofline"]["hlo_flops_global"] = hlo_flops_global
    rec["roofline"]["useful_flops_ratio"] = (
        round(mf / hlo_flops_global, 4) if hlo_flops_global else None)

    # analytic HBM lower bound (TPU-fusion optimistic; XLA-CPU "bytes
    # accessed" above is the pessimistic upper bound)
    def _tree_bytes(t):
        return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t)))
    pb = _tree_bytes(args[0])
    cb = _tree_bytes(args[2]) if kind in ("prefill", "decode") else 0.0
    s_eff = shape.seq_len if kind != "decode" else 1
    act = (cfg.n_layers * shape.global_batch * s_eff * cfg.d_model * 2.0)
    logits_b = shape.global_batch * s_eff * cfg.vocab * 4.0
    frac = 1.0
    if cfg.family == "moe" and kind == "decode":
        frac = min(1.0, shape.global_batch * cfg.moe.top_k / cfg.moe.n_experts)
    mem_lb = rl.analytic_memory_bytes(
        kind, params_bytes=pb, cache_bytes=cb, act_ckpt_bytes=act,
        logits_bytes=logits_b, n_dev=n_dev, moe_expert_frac=frac)
    rec["roofline"]["memory_lb_s"] = mem_lb / rl.HBM_BW
    rec["roofline"]["params_bytes_global"] = pb
    rec["roofline"]["cache_bytes_global"] = cb
    dom_lb = {"compute_s": terms["compute_s"],
              "memory_s": rec["roofline"]["memory_lb_s"],
              "collective_s": terms["collective_s"]}
    rec["roofline"]["dominant_lb"] = max(dom_lb, key=dom_lb.get)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default="auto", choices=("auto", "fed", "plain"))
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    try:
        rec = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                         step=args.step, local_steps=args.local_steps,
                         ssm_chunk=args.ssm_chunk,
                         seq_parallel=args.seq_parallel)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    out = json.dumps(rec, indent=2, default=str)
    print(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
    if rec.get("status") == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
