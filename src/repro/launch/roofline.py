"""Roofline term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e):
  peak   = 197 TFLOP/s bf16 per chip
  hbm_bw = 819 GB/s per chip
  ici_bw = ~50 GB/s per link

  compute    = HLO_FLOPs / (chips × peak)
  memory     = HLO_bytes / (chips × hbm_bw)
  collective = collective_bytes / (chips × ici_bw)

XLA's `cost_analysis()` and the partitioned HLO are *per-device*; we scale by
the device count so the three terms use the spec's global-numerator form
(numerically identical to per-device / per-chip-bandwidth).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# e.g.  bf16[16,4096,7168]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Sums operand bytes of every collective op in (per-device) HLO text.

    Returns (bytes_by_type, count_by_type).
    """
    by_type: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        op = m.group(1)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        # operand shapes: everything inside the op's argument parens
        paren = ls.find("(", ls.find(op))
        if paren == -1:
            continue
        args = ls[paren:ls.find(")", paren) + 1]
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(args))
        by_type[base] = by_type.get(base, 0) + nbytes
        counts[base] = counts.get(base, 0) + 1
    return by_type, counts


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    """Seconds per step for each roofline term (per-device form)."""
    t_compute = flops_per_dev / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(t_compute, t_memory, t_coll)
    terms["bound_fraction"] = (t_compute / total) if total > 0 else 0.0
    return terms


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N_active·D for training, 2·N_active·D forward-only."""
    n_active = cfg.active_params()
    mult = 6.0 if shape_kind in ("train", "fed_train", "plain_train") else 2.0
    return mult * n_active * tokens


def attention_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Quadratic attention matmul flops (qkᵀ + pv), global, forward; ×3 for
    training. Sliding windows cap the effective context."""
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.derived_head_dim()
    d_att = cfg.n_heads * hd
    ctx = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = (cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0
    # causal-optimal: half the full S×ctx rectangle
    f = 2.0 * 2.0 * batch * seq * ctx * d_att * n_attn_layers * 0.5
    if shape_kind in ("train", "fed_train", "plain_train"):
        f *= 3.0
    return f


def analytic_memory_bytes(kind: str, *, params_bytes: float,
                          cache_bytes: float, act_ckpt_bytes: float,
                          logits_bytes: float, n_dev: int,
                          moe_expert_frac: float = 1.0) -> float:
    """Per-device HBM-traffic LOWER BOUND (perfect fusion assumption).

    XLA's "bytes accessed" counts every instruction boundary, which grossly
    overstates HBM traffic relative to a fusing TPU compiler; this bound
    counts only the irreducible traffic: parameter reads (+grad writes for
    training), KV/state cache read+write, activation checkpoints, logits.
    """
    pb = params_bytes * moe_expert_frac
    if kind in ("fed_train", "plain_train", "train"):
        total = 3.0 * params_bytes + 2.0 * act_ckpt_bytes + logits_bytes
    elif kind == "prefill":
        total = pb + cache_bytes + act_ckpt_bytes + logits_bytes
    else:  # decode
        total = pb + 2.0 * cache_bytes + logits_bytes
    return total / n_dev
