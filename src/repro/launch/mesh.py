"""Production meshes. Importing this module never touches jax device state;
meshes are built inside functions only."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            "under launch/dryrun.py which forces 512 host devices")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    import jax
    from jax.sharding import Mesh
    n = data * model
    devices = jax.devices()
    assert len(devices) >= n, (len(devices), n)
    return Mesh(np.asarray(devices[:n]).reshape(data, model), ("data", "model"))
