"""Step functions (train / fed-train / prefill / decode) bound to a config,
plus the sharding assignment used by both the dry-run and real launchers."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.fed_step import FedStepConfig, fed_train_step
from ..models import decode_step, loss_fn, prefill
from ..models.config import ModelConfig
from ..optim import SGD
from ..sharding import (batch_pspec, cache_pspecs, fed_batch_pspec,
                        param_pspecs)

BIG_ARCHS = ("kimi-k2-1t-a32b", "qwen2-vl-72b")   # FSDP over (pod, data)


def fsdp_axes_for(cfg: ModelConfig, mesh) -> tuple:
    axes = ("pod", "data") if (cfg.name in BIG_ARCHS and "pod" in mesh.shape) \
        else ("data",)
    return tuple(a for a in axes if a in mesh.shape)


def dp_axes_for(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def make_step(cfg: ModelConfig, kind: str, *,
              fcfg: Optional[FedStepConfig] = None, lr: float = 1e-2,
              spmd_axes=None, param_shardings=None):
    """Returns step_fn(*args) matching launch.shapes.input_specs(kind)."""
    model_loss = lambda p, b: loss_fn(p, cfg, b)

    if kind == "fed_train":
        acc_fn = lambda p, b: loss_fn(p, cfg, b)[1]["accuracy"]

        def step(params, node_batches, eval_batch, key):
            return fed_train_step(params, node_batches, eval_batch, key,
                                  loss_fn=model_loss, acc_fn=acc_fn,
                                  fcfg=fcfg, spmd_axes=spmd_axes)
        return step

    if kind == "plain_train":
        opt = SGD(lr=lr)

        def step(params, batch):
            (l, aux), g = jax.value_and_grad(model_loss, has_aux=True)(params, batch)
            if param_shardings is not None:
                # pin grads to the param sharding => one reduce-scatter-class
                # sync per tensor instead of repeated in-loop all-reduces
                g = jax.lax.with_sharding_constraint(g, param_shardings)
            params, _ = opt.update(params, g, ())
            return params, l
        return step

    if kind == "prefill":
        def step(params, batch, cache):
            return prefill(params, cfg, batch, cache)
        return step

    if kind == "decode":
        def step(params, tokens, cache):
            return decode_step(params, cfg, tokens, cache)
        return step

    raise ValueError(kind)


def arg_pspecs(cfg: ModelConfig, kind: str, mesh, args) -> Tuple:
    """PartitionSpecs for the step args (same structure as args)."""
    fsdp = fsdp_axes_for(cfg, mesh)
    dp = dp_axes_for(mesh)
    if kind == "fed_train":
        params, node_batches, eval_batch, key = args
        return (param_pspecs(mesh, params, fsdp),
                fed_batch_pspec(mesh, node_batches, dp),
                jax.tree.map(lambda _: jax.sharding.PartitionSpec(), eval_batch),
                jax.sharding.PartitionSpec())
    if kind == "plain_train":
        params, batch = args
        return (param_pspecs(mesh, params, fsdp),
                batch_pspec(mesh, batch, dp))
    if kind == "prefill":
        params, batch, cache = args
        return (param_pspecs(mesh, params, fsdp),
                batch_pspec(mesh, batch, dp),
                cache_pspecs(mesh, cache, dp))
    if kind == "decode":
        params, tokens, cache = args
        return (param_pspecs(mesh, params, fsdp),
                batch_pspec(mesh, tokens, dp),
                cache_pspecs(mesh, cache, dp))
    raise ValueError(kind)
