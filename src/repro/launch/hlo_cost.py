"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE, which
under-counts scanned layer stacks / local-step loops by the trip count — and
the printed HLO does not annotate operand types inline, so naive regexes
cannot size collectives either. This module parses the HLO text properly:

  * builds a per-computation symbol table (instruction -> result shape(s));
  * walks the call graph from ENTRY, multiplying while bodies by their
    ``backend_config known_trip_count`` (and falling back to 1 with a
    warning flag when unknown);
  * FLOPs: dot (2·prod(result)·prod(contracting)) and convolution
    (2·prod(result)·prod(kernel)/out_features) — the MXU work. Elementwise
    flops are not counted (they ride the memory term);
  * bytes: Σ over instructions of operand + result bytes (XLA's own
    "bytes accessed" convention), fusion boundaries only;
  * collective bytes: Σ operand bytes per collective op, by type.

All numbers are PER DEVICE (the input is the per-device partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\](?:\{[^}]*\})?")


def _shapes_in(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    tot = 0
    for dt, shape in _shapes_in(type_str):
        n = 1
        for d in shape:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)   # name -> result type


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    unknown_trip_counts: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        self.unknown_trip_counts += other.unknown_trip_counts

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([\w\-]+)\(")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _parse_instr(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, result_type, opcode, rest-after-opcode-paren) or None.

    Handles tuple result types with arbitrary nesting, e.g.
      %w = (s32[], (bf16[2,3]{1,0}, f32[4])) while(%t), ...
    """
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":            # tuple type: balanced scan
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        rtype = line[i:j + 1]
        i = j + 1
    else:                                    # scalar/array type token
        tm = re.match(r"[a-z]\w*\[[^\]]*\](?:\{[^}]*\})?", line[i:])
        if not tm:
            return None
        rtype = tm.group(0)
        i += tm.end()
    om = _OPCODE.match(line[i:])
    if not om:
        return None
    opcode = om.group(1)
    rest = line[i + om.end():]
    return name, rtype, opcode, rest
_CALLS = re.compile(r'(?:body|calls|to_apply)=%?([\w.\-]+)')
_COND = re.compile(r'condition=%?([\w.\-]+)')
_OPERAND = re.compile(r'%([\w.\-]+)')


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith(("//", "#")):
            continue
        if not line.startswith((" ", "\t")) and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        parsed = _parse_instr(line)
        if not parsed:
            continue
        name, rtype, opcode, rest = parsed
        # operand names: up to the closing paren at depth 0
        depth, i = 1, 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[:i - 1], rest[i:]
        operands = _OPERAND.findall(operand_str)
        ins = Instr(name, rtype, opcode, operands, attrs, line)
        cur.instrs.append(ins)
        cur.table[name] = rtype
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, shape in _shapes_in(ins.result_type):
        for d in shape:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_elems   # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_type = comp.table.get(ins.operands[0], "")
    shapes = _shapes_in(lhs_type)
    if not shapes:
        return 2.0 * out_elems
    lhs_shape = shapes[0][1]
    k = 1
    for cd in cdims:
        if cd < len(lhs_shape):
            k *= lhs_shape[cd]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, shape in _shapes_in(ins.result_type):
        for d in shape:
            out_elems *= d
    if len(ins.operands) >= 2:
        rhs = _shapes_in(comp.table.get(ins.operands[1], ""))
        if rhs:
            kshape = rhs[0][1]
            kprod = 1
            for d in kshape:
                kprod *= d
            # kernel flops per output element ≈ prod(kernel)/out_features
            of = kshape[-1] if kshape else 1
            return 2.0 * out_elems * max(kprod // max(of, 1), 1)
    return 2.0 * out_elems


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    return sum(_bytes_of(comp.table.get(op, "")) for op in ins.operands)


_PARAM_NUM = re.compile(r"parameter\((\d+)\)")
_SLICING = ("dynamic-slice", "slice", "gather")


def _fusion_operand_bytes(ins: Instr, comp: Computation,
                          comps: Dict[str, "Computation"]) -> int:
    """Bytes read by a fusion: operands that are only SLICED inside the fused
    computation contribute their sliced size, not the full array (otherwise
    scan loops that dynamic-slice their stacked xs every iteration get
    charged O(trip²) traffic)."""
    m = _CALLS.search(ins.attrs)
    inner = comps.get(m.group(1)) if m else None
    if inner is None:
        return _operand_bytes(ins, comp)
    param_by_idx = {}
    for i2 in inner.instrs:
        if i2.opcode == "parameter":
            pm = _PARAM_NUM.search(i2.line)
            if pm:
                param_by_idx[int(pm.group(1))] = i2.name
    total = 0
    for idx, opname in enumerate(ins.operands):
        full = _bytes_of(comp.table.get(opname, ""))
        pname = param_by_idx.get(idx)
        if pname is None:
            total += full
            continue
        uses = [u for u in inner.instrs if pname in u.operands]
        if uses and all(u.opcode in _SLICING for u in uses):
            sliced = sum(_bytes_of(u.result_type) for u in uses)
            total += min(full, sliced)
        else:
            total += full
    return total


def analyze(comps: Dict[str, Computation], name: str,
            memo: Dict[str, Cost], *, inside_fusion: bool = False) -> Cost:
    key = name + ("@f" if inside_fusion else "")
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    cost = Cost()
    if comp is None:
        memo[key] = cost
        return cost
    for ins in comp.instrs:
        op = ins.opcode
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            continue
        if op == "while":
            m = _TRIP.search(ins.attrs)
            trips = int(m.group(1)) if m else 1
            body = _CALLS.search(ins.attrs)
            cond = _COND.search(ins.attrs)
            if not m:
                cost.unknown_trip_counts += 1
            if body:
                cost.add(analyze(comps, body.group(1), memo), trips)
            if cond:
                cost.add(analyze(comps, cond.group(1), memo), trips)
            continue
        if op in ("call", "conditional"):
            for target in _CALLS.findall(ins.attrs):
                cost.add(analyze(comps, target, memo))
            continue
        if op == "fusion":
            m = _CALLS.search(ins.attrs)
            if m:
                inner = analyze(comps, m.group(1), memo, inside_fusion=True)
                cost.flops += inner.flops
            if not inside_fusion:
                cost.bytes += (_fusion_operand_bytes(ins, comp, comps)
                               + _bytes_of(ins.result_type))
            continue
        base = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base:
            nb = _operand_bytes(ins, comp)
            cost.coll_bytes[base] = cost.coll_bytes.get(base, 0.0) + nb
            cost.coll_counts[base] = cost.coll_counts.get(base, 0.0) + 1
            cost.bytes += nb + _bytes_of(ins.result_type)
            continue
        if op.endswith("-done") or op in ("send", "recv", "send-done",
                                          "recv-done", "partition-id",
                                          "replica-id"):
            continue
        if op == "dot":
            cost.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            cost.flops += _conv_flops(ins, comp)
        if not inside_fusion:
            # Slicing/indexing ops only touch the sliced region, not the full
            # operand — counting whole operands would inflate scan loops
            # (which dynamic-slice their stacked xs every iteration) by
            # O(trip_count). Matches XLA's own bytes-accessed convention.
            if op in ("dynamic-slice", "slice", "gather"):
                cost.bytes += 2.0 * _bytes_of(ins.result_type)
            elif op in ("dynamic-update-slice", "scatter", "scatter-add"):
                upd = (_bytes_of(comp.table.get(ins.operands[-1], ""))
                       if ins.operands else 0)
                cost.bytes += 2.0 * upd
            else:
                cost.bytes += _operand_bytes(ins, comp) + _bytes_of(ins.result_type)
    memo[key] = cost
    return cost


def analyze_hlo_text(text: str) -> Cost:
    comps, entry = parse_hlo(text)
    if entry is None:
        return Cost()
    return analyze(comps, entry, {})
