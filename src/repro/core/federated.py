"""FederatedTrainer: the paper's four schemes on real (host-level) nodes.

Implements SFL (sync FedAvg), AFL (async, Eq. 6), SLDPFL (sync + LDP) and
ALDPFL (the paper's framework: async + LDP + detection + accumulation) over
K simulated edge nodes with heterogeneous compute speeds.

Asynchrony is simulated with an event queue: each node trains from the global
model version it last received and its update arrives after its (heterogeneous)
compute time; the cloud mixes it immediately (Eq. 6) without waiting for other
nodes. The simulated clock gives the paper's running-time comparison (Fig. 7b)
and κ = Comm/(Comp+Comm) (Eq. 5); training math runs in JAX (jitted local SGD).

Both scheme families route through `repro.fleet` by default: the
synchronous ones (sfl/sldpfl) through the cohort-batched `FleetEngine` (one
device dispatch per round instead of K), the asynchronous ones
(afl/aldpfl) through the window-batched `AsyncFleetEngine` (one dispatch
per virtual-time arrival window instead of per arrival), each with a
per-node PRNG chain identical to the sequential reference paths (kept under
`cfg.use_fleet=False` and tested equivalent in tests/test_fleet.py and
tests/test_async_fleet.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import accumulator as accum
from . import aldp, async_update, detection
from .accountant import MomentsAccountant


@dataclass
class FedConfig:
    mode: str = "aldpfl"            # sfl | afl | sldpfl | aldpfl
    n_nodes: int = 10
    rounds: int = 20
    local_steps: int = 10           # minibatch SGD steps per round per node
    batch_size: int = 64
    lr: float = 0.05
    alpha: float = 0.5              # Eq. (6) mixing
    staleness_adaptive: bool = False
    # ALDP
    clip_s: float = 1.0
    epsilon: float = 8.0
    delta: float = 1e-3
    sigma: Optional[float] = None   # None => calibrated from (epsilon, delta)
    # detection
    detect: bool = True
    detect_s: float = 80.0
    detect_warmup: int = 4          # async: min arrivals before detecting
    detect_window: Optional[int] = None  # async window; None => max(n_nodes, 4)
    # communication model
    sparsify_ratio: float = 1.0     # <1 => gradient accumulation container
    bandwidth_bytes_per_s: float = 12.5e6   # 100 Mbit/s edge uplink
    base_compute_s: float = 1.0
    heterogeneity: float = 0.5      # lognormal sigma of node speeds
    use_fleet: bool = True          # sync path: batched FleetEngine vs
                                    # the sequential per-node reference loop
    fleet_mesh: Optional[int] = None  # shard the fleet node axis over this
                                    # many local devices (shard_map'd rounds/
                                    # windows); None = single-device engines.
                                    # Requires use_fleet=True.
    seed: int = 0

    def detection_window(self) -> int:
        """Length of the async sliding accuracy window (was a magic
        expression inline in the event loop)."""
        return self.detect_window if self.detect_window is not None \
            else detection.default_window(self.n_nodes)

    def noise_multiplier(self) -> float:
        """σ for the configured mode; explicitly 0.0 for the no-noise
        modes (sfl/afl) — callers must not construct privacy accountants
        for a zero-noise run."""
        if self.mode in ("sfl", "afl"):
            return 0.0
        return self.sigma if self.sigma is not None else \
            aldp.sigma_for_epsilon(self.epsilon, self.delta)


@dataclass
class RoundRecord:
    t: float
    version: int
    accuracy: float
    comm_bytes: float
    comp_time: float
    comm_time: float
    n_rejected: int


class FederatedTrainer:
    """Runs one of the paper's four schemes on K host-simulated nodes.

    Args:
      init_params: global model params pytree.
      loss_fn: (params, batch{x,y}) -> (loss, metrics)
      acc_fn: (params, x, y) -> scalar accuracy (cloud-side test quality).
      node_data: list of (x, y) arrays per node (possibly label-flipped).
      test_data: (x, y) for global accuracy reporting.
      cloud_test: (x, y) the cloud's detection testing dataset (§5.4).
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 test_data: Tuple[np.ndarray, np.ndarray],
                 cloud_test: Tuple[np.ndarray, np.ndarray],
                 cfg: FedConfig):
        if cfg.fleet_mesh is not None and not cfg.use_fleet:
            raise ValueError(
                "FedConfig.fleet_mesh shards the fleet engines' node axis "
                "and requires use_fleet=True; the sequential reference "
                "paths cannot run sharded")
        self.cfg = cfg
        self.params = init_params
        self.loss_fn = loss_fn
        self._acc_fn_raw = acc_fn
        self.acc_fn = jax.jit(acc_fn)
        self.node_data = [(jnp.asarray(x), jnp.asarray(y)) for x, y in node_data]
        self.test_data = (jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))
        self.cloud_test = (jnp.asarray(cloud_test[0]), jnp.asarray(cloud_test[1]))
        self.rng = np.random.default_rng(cfg.seed)
        self.key = jax.random.PRNGKey(cfg.seed)
        self.sigma = cfg.noise_multiplier()
        self.n_params = sum(x.size for x in jax.tree.leaves(init_params))
        # no-noise runs spend no privacy budget: no accountant at all (the
        # old sentinel `sigma or 1e9` made epsilon_spent depend on a bogus σ)
        self.accountant = (MomentsAccountant(self.sigma, 1.0)
                           if self.sigma > 0 else None)
        self.history: List[RoundRecord] = []
        self.residuals = [accum.init_residual(init_params)
                          for _ in range(cfg.n_nodes)]
        # heterogeneous node speeds (lognormal around base_compute_s)
        self.node_time = cfg.base_compute_s * np.exp(
            self.rng.normal(0.0, cfg.heterogeneity, cfg.n_nodes))
        self._local_train = jax.jit(partial(self._local_train_impl, loss_fn,
                                            cfg.local_steps, cfg.lr,
                                            cfg.batch_size))

    # -- jitted node-local SGD ------------------------------------------------
    @staticmethod
    def _local_train_impl(loss_fn, steps, lr, bs, params, x, y, key):
        n = x.shape[0]

        def body(carry, k):
            p, = carry
            idx = jax.random.randint(k, (bs,), 0, n)
            batch = {"x": x[idx], "y": y[idx]}
            g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
            p = jax.tree.map(lambda a, b: a - lr * b, p, g)
            return (p,), None

        keys = jax.random.split(key, steps)
        (p,), _ = jax.lax.scan(body, (params,), keys)
        return p

    # -- per-node upload pipeline --------------------------------------------
    def _node_update(self, node: int, start_params) -> Tuple[dict, float, float]:
        """Local train -> delta -> [accumulate/sparsify] -> [ALDP] -> ω_new.

        Returns (uploaded model ω_new, upload_bytes, node accuracy on the
        cloud testing dataset)."""
        cfg = self.cfg
        x, y = self.node_data[node]
        self.key, k1, k2 = jax.random.split(self.key, 3)
        local = self._local_train(start_params, x, y, k1)
        delta = jax.tree.map(lambda a, b: a - b, local, start_params)

        if cfg.sparsify_ratio < 1.0:
            delta, self.residuals[node], _ = accum.accumulate_and_sparsify(
                self.residuals[node], delta, cfg.sparsify_ratio)
            bytes_up = accum.upload_bytes(delta, cfg.sparsify_ratio)
        else:
            bytes_up = self.n_params * 4

        if self.sigma > 0:
            delta, _ = aldp.aldp_perturb(delta, k2, self.sigma, cfg.clip_s)
            self.accountant.step()  # accountant exists whenever sigma > 0

        omega_new = jax.tree.map(lambda a, b: a + b, start_params, delta)
        acc = float(self.acc_fn(omega_new, *self.cloud_test))
        return omega_new, bytes_up, acc

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    # -- schemes ---------------------------------------------------------------
    def run(self) -> List[RoundRecord]:
        if self.cfg.mode in ("sfl", "sldpfl"):
            return self._run_sync()
        return self._run_async()

    def _comm_time(self, nbytes: float) -> float:
        return nbytes / self.cfg.bandwidth_bytes_per_s

    def _run_sync(self) -> List[RoundRecord]:
        """Synchronous FedAvg (barrier per round).

        Default path is the cohort-batched `repro.fleet.FleetEngine` (one
        device dispatch per round); `cfg.use_fleet=False` keeps the original
        per-node reference loop, which the engine is tested against.
        """
        if self.cfg.use_fleet:
            return self._run_sync_fleet()
        return self._run_sync_sequential()

    def _fleet_mesh(self):
        """The opt-in node mesh (`cfg.fleet_mesh` devices), or None."""
        if self.cfg.fleet_mesh is None:
            return None
        from ..fleet import FleetMesh  # deferred: fleet depends on repro.core
        return FleetMesh.create(self.cfg.fleet_mesh)

    def _fleet_engine(self):
        """Build a FleetEngine faithful to this trainer: same per-node PRNG
        chain (key_mode="sequential"), same residual/clock state."""
        from .. import fleet  # deferred: fleet depends on repro.core
        cfg = self.cfg
        fcfg = fleet.FleetConfig(
            local_steps=cfg.local_steps, batch_size=cfg.batch_size,
            lr=cfg.lr, alpha=cfg.alpha, clip_s=cfg.clip_s, sigma=self.sigma,
            detect=cfg.detect, detect_s=cfg.detect_s,
            sparsify_ratio=cfg.sparsify_ratio, key_mode="sequential",
            backend="reference", seed=cfg.seed)
        profile = fleet.NodeProfile(
            compute_s=self.node_time,
            bandwidth_bps=np.full(cfg.n_nodes, cfg.bandwidth_bytes_per_s))
        eng = fleet.FleetEngine(
            self.params, self.loss_fn, self._acc_fn_raw, self.node_data,
            self.test_data, self.cloud_test, fcfg, profile=profile,
            sampler=fleet.FullParticipation(), mesh=self._fleet_mesh())
        eng.load_state(fleet.stack_trees(self.residuals), self.key)
        return eng

    def _run_sync_fleet(self) -> List[RoundRecord]:
        cfg = self.cfg
        eng = self._fleet_engine()
        for r in range(cfg.rounds):
            rec = eng.run_round()
            if self.accountant is not None:
                self.accountant.step(cfg.n_nodes)
            self.params = eng.params
            self.history.append(RoundRecord(
                rec.t, r, rec.accuracy, rec.comm_bytes, rec.comp_time,
                rec.comm_time, rec.n_rejected))
        # hand node-local state back so follow-on runs stay faithful
        self.key = jax.device_get(eng.state.chain_key)
        from ..fleet import unstack_tree
        self.residuals = unstack_tree(eng.export_residuals(), cfg.n_nodes)
        return self.history

    def _run_sync_sequential(self) -> List[RoundRecord]:
        cfg = self.cfg
        clock = 0.0
        for r in range(cfg.rounds):
            uploads, accs, nbytes = [], [], 0.0
            for node in range(cfg.n_nodes):
                w, b, a = self._node_update(node, self.params)
                uploads.append(w)
                accs.append(a)
                nbytes += b
            accs = jnp.asarray(accs)
            if cfg.detect:
                mask, _ = detection.detect(accs, cfg.detect_s)
            else:
                mask = jnp.ones(cfg.n_nodes, bool)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *uploads)
            omega_new = detection.masked_mean(stacked, mask)
            self.params = async_update.mix(self.params, omega_new, cfg.alpha)
            comp = float(np.max(self.node_time))          # barrier: slowest node
            comm = self._comm_time(nbytes / cfg.n_nodes)  # parallel uplinks
            clock += comp + comm
            self.history.append(RoundRecord(
                clock, r, self.global_accuracy(), nbytes, comp, comm,
                int(cfg.n_nodes - mask.sum())))
        return self.history

    def _run_async(self) -> List[RoundRecord]:
        """Asynchronous: Eq. (6) mix on every arrival.

        Default path is the window-batched `repro.fleet.AsyncFleetEngine`
        in parity mode (auto window + sequential mixing + the trainer's
        PRNG chain); `cfg.use_fleet=False` keeps the original per-arrival
        event loop, which the engine is tested against.
        """
        if self.cfg.use_fleet:
            return self._run_async_fleet()
        return self._run_async_sequential()

    def _async_fleet_engine(self):
        """Build an AsyncFleetEngine faithful to this trainer: same node
        clocks, same per-arrival PRNG chain, same detection window."""
        from .. import fleet  # deferred: fleet depends on repro.core
        cfg = self.cfg
        fcfg = fleet.AsyncFleetConfig(
            local_steps=cfg.local_steps, batch_size=cfg.batch_size,
            lr=cfg.lr, alpha=cfg.alpha, clip_s=cfg.clip_s, sigma=self.sigma,
            detect=cfg.detect, detect_s=cfg.detect_s,
            sparsify_ratio=cfg.sparsify_ratio, key_mode="sequential",
            backend="reference", seed=cfg.seed,
            window=None, mixing="sequential",
            staleness_adaptive=cfg.staleness_adaptive,
            detect_warmup=cfg.detect_warmup,
            detect_window=cfg.detection_window())
        profile = fleet.NodeProfile(
            compute_s=self.node_time,
            bandwidth_bps=np.full(cfg.n_nodes, cfg.bandwidth_bytes_per_s))
        eng = fleet.AsyncFleetEngine(
            self.params, self.loss_fn, self._acc_fn_raw, self.node_data,
            self.test_data, self.cloud_test, fcfg, profile=profile,
            mesh=self._fleet_mesh())
        eng.load_state(fleet.stack_trees(self.residuals), self.key)
        return eng

    def _run_async_fleet(self) -> List[RoundRecord]:
        cfg = self.cfg
        eng = self._async_fleet_engine()
        total = cfg.rounds * cfg.n_nodes
        processed = 0
        # one RoundRecord per n_nodes arrivals, exactly like the event loop
        # (downstream benchmarks normalize by len(history)): windows are
        # capped so they never straddle a record boundary — a cap only
        # truncates the arrival prefix, so the processed order is unchanged
        span_bytes = span_comp = span_comm = 0.0
        span_rejected = 0
        while processed < total:
            boundary = cfg.n_nodes - processed % cfg.n_nodes
            rec = eng.run_window(max_arrivals=boundary, evaluate=False)
            processed += rec.n_processed
            if self.accountant is not None:
                self.accountant.step(rec.n_processed)
            self.params = eng.params
            span_bytes += rec.comm_bytes
            span_comp += rec.comp_time
            span_comm += rec.comm_time
            span_rejected += rec.n_rejected
            if processed % cfg.n_nodes == 0:
                self.history.append(RoundRecord(
                    rec.t, rec.version, self.global_accuracy(), span_bytes,
                    span_comp, span_comm, span_rejected))
                span_bytes = span_comp = span_comm = 0.0
                span_rejected = 0
        # hand node-local state back so follow-on runs stay faithful
        self.key = jax.device_get(eng.state.chain_key)
        from ..fleet import unstack_tree
        self.residuals = unstack_tree(eng.export_residuals(), cfg.n_nodes)
        return self.history

    def _run_async_sequential(self) -> List[RoundRecord]:
        """The per-arrival event-queue reference loop."""
        cfg = self.cfg
        version = 0
        # (arrival_time, node, dispatched_version, seq) heap
        events = []
        for node in range(cfg.n_nodes):
            heapq.heappush(events, (self.node_time[node], node, 0, node))
        dispatched_params = {n: self.params for n in range(cfg.n_nodes)}
        total_updates = cfg.rounds * cfg.n_nodes
        acc_window: List[float] = []
        seq = cfg.n_nodes
        processed = 0
        # per-record accumulators: a RoundRecord spans n_nodes arrivals, so
        # traffic/time must be summed over the span, not the last arrival
        span_bytes = span_comp = span_comm = 0.0
        span_rejected = 0
        while processed < total_updates:
            t, node, v_disp, _ = heapq.heappop(events)
            w, b, a = self._node_update(node, dispatched_params[node])
            comm = self._comm_time(b)
            t_arrive = t + comm
            acc_window.append(a)
            acc_window = acc_window[-cfg.detection_window():]
            rejected = 0
            if cfg.detect and len(acc_window) >= cfg.detect_warmup:
                accs = jnp.asarray(acc_window)
                thr = detection.detection_threshold(accs, cfg.detect_s)
                if a <= float(thr):
                    rejected = 1
            if not rejected:
                staleness = version - v_disp
                if cfg.staleness_adaptive:
                    self.params = async_update.mix_stale(
                        self.params, w, cfg.alpha, staleness)
                else:
                    self.params = async_update.mix(self.params, w, cfg.alpha)
                version += 1
            processed += 1
            span_bytes += b
            span_comp += float(self.node_time[node])
            span_comm += comm
            span_rejected += rejected
            # redispatch node with the fresh global model
            dispatched_params[node] = self.params
            heapq.heappush(events,
                           (t_arrive + self.node_time[node], node, version, seq))
            seq += 1
            if processed % cfg.n_nodes == 0:
                self.history.append(RoundRecord(
                    t_arrive, version, self.global_accuracy(), span_bytes,
                    span_comp, span_comm, span_rejected))
                span_bytes = span_comp = span_comm = 0.0
                span_rejected = 0
        return self.history

    # -- reporting --------------------------------------------------------------
    def kappa(self) -> float:
        """Eq. (5) over the whole run."""
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)

    def epsilon_spent(self) -> float:
        """Privacy spent so far; exactly 0 for no-noise runs (no accountant)."""
        if self.accountant is None:
            return 0.0
        return self.accountant.epsilon(self.cfg.delta)
