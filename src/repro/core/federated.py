"""FederatedTrainer: the legacy entry point, now a shim over `repro.api`.

Implements SFL (sync FedAvg), AFL (async, Eq. 6), SLDPFL (sync + LDP) and
ALDPFL (the paper's framework: async + LDP + detection + accumulation) over
K simulated edge nodes with heterogeneous compute speeds.

.. deprecated::
    `FederatedTrainer(FedConfig(...)).run()` is a compatibility shim: the
    `FedConfig` is lowered to a declarative `repro.api.ExperimentSpec`
    (`api.plan_from_fed_config`) and executed by `api.execute` — the same
    runner behind ``api.run(api.compile_plan(spec))``.  The lowering is
    exact (tested bit-equal-to-float-close for all four modes in
    tests/test_api.py), and `run()` emits a single `DeprecationWarning`.
    New code should use the spec -> plan -> run surface directly; see
    README "The experiment API".

The four execution paths the old trainer branched over (sync/async ×
sequential reference loop / fleet engines, selected by ``use_fleet`` and
``fleet_mesh``) live in `repro.api.run` now — the spec's `Topology` picks
them.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import accumulator as accum
from . import aldp, detection
from .accountant import MomentsAccountant

_VALID_MODES = ("sfl", "afl", "sldpfl", "aldpfl")


@dataclass
class FedConfig:
    mode: str = "aldpfl"            # sfl | afl | sldpfl | aldpfl
    n_nodes: int = 10
    rounds: int = 20
    local_steps: int = 10           # minibatch SGD steps per round per node
    batch_size: int = 64
    lr: float = 0.05
    alpha: float = 0.5              # Eq. (6) mixing
    staleness_adaptive: bool = False
    # ALDP
    clip_s: float = 1.0
    epsilon: float = 8.0
    delta: float = 1e-3
    sigma: Optional[float] = None   # None => calibrated from (epsilon, delta)
    # detection
    detect: bool = True
    detect_s: float = 80.0
    detect_warmup: int = 4          # async: min arrivals before detecting
    detect_window: Optional[int] = None  # async window; None => max(n_nodes, 4)
    # communication model
    sparsify_ratio: float = 1.0     # <1 => gradient accumulation container
    bandwidth_bytes_per_s: float = 12.5e6   # 100 Mbit/s edge uplink
    base_compute_s: float = 1.0
    heterogeneity: float = 0.5      # lognormal sigma of node speeds
    use_fleet: bool = True          # sync path: batched FleetEngine vs
                                    # the sequential per-node reference loop
    fleet_mesh: Optional[int] = None  # shard the fleet node axis over this
                                    # many local devices (shard_map'd rounds/
                                    # windows); None = single-device engines.
                                    # Requires use_fleet=True.
    seed: int = 0

    def validate(self) -> None:
        """Cross-field validation, surfaced by the `repro.api` redesign.

        The old trainer accepted several silently-broken combinations —
        an unknown ``mode`` fell through to the async branch, a
        ``fleet_mesh`` with ``use_fleet=False`` had nothing to shard,
        out-of-range knobs failed deep inside a jitted round.  All of
        them are explicit errors now (see tests/test_api.py)."""
        if self.mode not in _VALID_MODES:
            raise ValueError(f"FedConfig.mode {self.mode!r} is not one of "
                             f"{_VALID_MODES}")
        if self.fleet_mesh is not None and not self.use_fleet:
            raise ValueError(
                "FedConfig.fleet_mesh shards the fleet engines' node axis "
                "and requires use_fleet=True; the sequential reference "
                "paths cannot run sharded")
        if self.fleet_mesh is not None and self.fleet_mesh < 1:
            raise ValueError(f"FedConfig.fleet_mesh must be >= 1, got "
                             f"{self.fleet_mesh}")
        if self.n_nodes < 1 or self.rounds < 1:
            raise ValueError(f"FedConfig needs n_nodes >= 1 and rounds >= 1, "
                             f"got n_nodes={self.n_nodes}, "
                             f"rounds={self.rounds}")
        if self.local_steps < 1 or self.batch_size < 1:
            raise ValueError(f"FedConfig needs local_steps >= 1 and "
                             f"batch_size >= 1, got {self.local_steps}, "
                             f"{self.batch_size}")
        if self.lr <= 0:
            raise ValueError(f"FedConfig.lr must be > 0, got {self.lr}")
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"FedConfig.alpha must be in [0, 1], got "
                             f"{self.alpha}")
        if not 0.0 < self.sparsify_ratio <= 1.0:
            raise ValueError(f"FedConfig.sparsify_ratio must be in (0, 1], "
                             f"got {self.sparsify_ratio}")
        if not 0.0 < self.detect_s < 100.0:
            raise ValueError(f"FedConfig.detect_s is a percentile in "
                             f"(0, 100), got {self.detect_s}")
        if self.detect_warmup < 1:
            raise ValueError(f"FedConfig.detect_warmup must be >= 1, got "
                             f"{self.detect_warmup}")
        if self.detect_window is not None and self.detect_window < 1:
            raise ValueError(f"FedConfig.detect_window must be >= 1, got "
                             f"{self.detect_window}")
        if self.sigma is not None and self.sigma < 0:
            raise ValueError(f"FedConfig.sigma must be >= 0, got "
                             f"{self.sigma}")
        if self.sigma is None and self.mode in ("sldpfl", "aldpfl") and \
                not (self.epsilon > 0 and 0.0 < self.delta < 1.0):
            raise ValueError(
                f"FedConfig.sigma=None calibrates the noise multiplier "
                f"from (epsilon, delta); need epsilon > 0 and delta in "
                f"(0, 1), got ({self.epsilon}, {self.delta})")
        if self.clip_s <= 0:
            raise ValueError(f"FedConfig.clip_s must be > 0, got "
                             f"{self.clip_s}")
        if self.bandwidth_bytes_per_s <= 0 or self.base_compute_s <= 0:
            raise ValueError(
                f"FedConfig.bandwidth_bytes_per_s and base_compute_s must "
                f"be > 0, got {self.bandwidth_bytes_per_s}, "
                f"{self.base_compute_s}")
        if self.heterogeneity < 0:
            raise ValueError(f"FedConfig.heterogeneity must be >= 0, got "
                             f"{self.heterogeneity}")

    def detection_window(self) -> int:
        """Length of the async sliding accuracy window (was a magic
        expression inline in the event loop)."""
        return self.detect_window if self.detect_window is not None \
            else detection.default_window(self.n_nodes)

    def noise_multiplier(self) -> float:
        """σ for the configured mode; explicitly 0.0 for the no-noise
        modes (sfl/afl) — callers must not construct privacy accountants
        for a zero-noise run."""
        if self.mode in ("sfl", "afl"):
            return 0.0
        return self.sigma if self.sigma is not None else \
            aldp.sigma_for_epsilon(self.epsilon, self.delta)


@dataclass
class RoundRecord:
    t: float
    version: int
    accuracy: float
    comm_bytes: float
    comp_time: float
    comm_time: float
    n_rejected: int
    # how comm_bytes was produced: "analytic" (the closed-form values +
    # indices estimate) or "encoded" (repro.net wire-codec byte counts) —
    # keeps mixed trajectories in results/*.json interpretable
    bytes_source: str = "analytic"


class FederatedTrainer:
    """Runs one of the paper's four schemes on K host-simulated nodes.

    Args:
      init_params: global model params pytree.
      loss_fn: (params, batch{x,y}) -> (loss, metrics)
      acc_fn: (params, x, y) -> scalar accuracy (cloud-side test quality).
      node_data: list of (x, y) arrays per node (possibly label-flipped).
      test_data: (x, y) for global accuracy reporting.
      cloud_test: (x, y) the cloud's detection testing dataset (§5.4).

    Deprecated — a compatibility shim over `repro.api`; see the module
    docstring.
    """

    def __init__(self, init_params, loss_fn: Callable, acc_fn: Callable,
                 node_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 test_data: Tuple[np.ndarray, np.ndarray],
                 cloud_test: Tuple[np.ndarray, np.ndarray],
                 cfg: FedConfig):
        cfg.validate()
        self.cfg = cfg
        self.params = init_params
        self.loss_fn = loss_fn
        self._acc_fn_raw = acc_fn
        self.acc_fn = jax.jit(acc_fn)
        self.node_data = [(jnp.asarray(x), jnp.asarray(y)) for x, y in node_data]
        self.test_data = (jnp.asarray(test_data[0]), jnp.asarray(test_data[1]))
        self.cloud_test = (jnp.asarray(cloud_test[0]), jnp.asarray(cloud_test[1]))
        self.rng = np.random.default_rng(cfg.seed)
        self.key = jax.random.PRNGKey(cfg.seed)
        self.sigma = cfg.noise_multiplier()
        self.n_params = sum(x.size for x in jax.tree.leaves(init_params))
        # no-noise runs spend no privacy budget: no accountant at all (the
        # old sentinel `sigma or 1e9` made epsilon_spent depend on a bogus σ)
        self.accountant = (MomentsAccountant(self.sigma, 1.0)
                           if self.sigma > 0 else None)
        self.history: List[RoundRecord] = []
        self.residuals = [accum.init_residual(init_params)
                          for _ in range(cfg.n_nodes)]
        # heterogeneous node speeds (lognormal around base_compute_s)
        self.node_time = cfg.base_compute_s * np.exp(
            self.rng.normal(0.0, cfg.heterogeneity, cfg.n_nodes))

    def global_accuracy(self) -> float:
        return float(self.acc_fn(self.params, *self.test_data))

    # -- the shim ------------------------------------------------------------
    def run(self) -> List[RoundRecord]:
        """Lower `self.cfg` to an `ExperimentPlan` and execute it with this
        trainer's params/data/state aliased in, so trajectories (and the
        handed-back PRNG chain/residuals) match the pre-redesign trainer
        exactly."""
        warnings.warn(
            "FederatedTrainer is deprecated: use the repro.api surface — "
            "report = api.run(api.compile_plan(spec)) — or lower an "
            "existing FedConfig with api.plan_from_fed_config(cfg). "
            "See README 'Migrating from FedConfig'.",
            DeprecationWarning, stacklevel=2)
        from .. import api
        from ..fleet import NodeProfile

        cfg = self.cfg
        plan = api.plan_from_fed_config(cfg)
        pop = api.Population(
            params=self.params, loss_fn=self.loss_fn,
            acc_fn=self._acc_fn_raw, node_data=self.node_data,
            test_data=self.test_data, cloud_test=self.cloud_test,
            profile=NodeProfile(
                compute_s=self.node_time,
                bandwidth_bps=np.full(cfg.n_nodes,
                                      cfg.bandwidth_bytes_per_s)))
        state = api.RunState(params=self.params, key=self.key,
                             residuals=self.residuals,
                             accountant=self.accountant,
                             history=self.history)
        api.execute(plan, pop, state)
        self.params = state.params
        self.key = state.key
        self.residuals = state.residuals
        return self.history

    # -- reporting --------------------------------------------------------------
    def kappa(self) -> float:
        """Eq. (5) over the whole run."""
        from . import async_update
        comm = sum(r.comm_time for r in self.history)
        comp = sum(r.comp_time for r in self.history)
        return async_update.communication_efficiency(comm, comp)

    def epsilon_spent(self) -> float:
        """Privacy spent so far; exactly 0 for no-noise runs (no accountant)."""
        if self.accountant is None:
            return 0.0
        return self.accountant.epsilon(self.cfg.delta)
