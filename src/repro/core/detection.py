"""Cloud-side malicious-node detection — paper §5.4, Algorithm 2.

The cloud evaluates every uploaded sub-model on a held-out testing dataset,
collects the accuracy set 𝒜, sets the threshold Thr to the top-s percentile
of 𝒜, and marks nodes with A_j > Thr as normal. Only normal nodes'
updates are aggregated. Larger s ⇒ stricter threshold ⇒ lower attack success
rate (paper Fig. 6a) at some accuracy cost (Fig. 6b); the paper operates at
s = 80.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def detection_threshold(accuracies: jnp.ndarray, s: float) -> jnp.ndarray:
    """Thr ← top-s% of 𝒜 (the s-th percentile of the accuracy set)."""
    return jnp.percentile(accuracies.astype(jnp.float32), s)


def detect(accuracies: jnp.ndarray, s: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (normal_mask (N,) bool, threshold).

    Algorithm 2 lines 7–14: A_j > Thr ⇒ normal. Guard: if the strict
    comparison would reject every node (all accuracies equal), fall back to
    `>=` so aggregation never divides by zero.
    """
    thr = detection_threshold(accuracies, s)
    mask = accuracies > thr
    mask = jnp.where(mask.any(), mask, accuracies >= thr)
    return mask, thr


def masked_mean(trees, mask: jnp.ndarray):
    """Aggregate node updates over normal nodes only (Alg. 2 line 16).

    `trees` is a pytree whose leaves have a leading node axis N;
    `mask` (N,) bool.
    """
    w = mask.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)

    def agg(x):
        wf = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * wf).sum(0) / denom

    return jax.tree.map(agg, trees)


def evaluate_nodes(node_params, eval_fn: Callable, *eval_args) -> jnp.ndarray:
    """vmap a per-model accuracy function over the stacked node models."""
    return jax.vmap(lambda p: eval_fn(p, *eval_args))(node_params)
