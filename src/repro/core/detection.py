"""Cloud-side malicious-node detection — paper §5.4, Algorithm 2.

The cloud evaluates every uploaded sub-model on a held-out testing dataset,
collects the accuracy set 𝒜, sets the threshold Thr to the top-s percentile
of 𝒜, and marks nodes with A_j > Thr as normal. Only normal nodes'
updates are aggregated. Larger s ⇒ stricter threshold ⇒ lower attack success
rate (paper Fig. 6a) at some accuracy cost (Fig. 6b); the paper operates at
s = 80.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def detection_threshold(accuracies: jnp.ndarray, s: float) -> jnp.ndarray:
    """Thr ← top-s% of 𝒜 (the s-th percentile of the accuracy set)."""
    return jnp.percentile(accuracies.astype(jnp.float32), s)


def detect(accuracies: jnp.ndarray, s: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (normal_mask (N,) bool, threshold).

    Algorithm 2 lines 7–14: A_j > Thr ⇒ normal. Guard: if the strict
    comparison would reject every node (all accuracies equal), fall back to
    `>=` so aggregation never divides by zero.
    """
    thr = detection_threshold(accuracies, s)
    mask = accuracies > thr
    mask = jnp.where(mask.any(), mask, accuracies >= thr)
    return mask, thr


def masked_mean(trees, mask: jnp.ndarray):
    """Aggregate node updates over normal nodes only (Alg. 2 line 16).

    `trees` is a pytree whose leaves have a leading node axis N;
    `mask` (N,) bool.
    """
    w = mask.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)

    def agg(x):
        wf = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * wf).sum(0) / denom

    return jax.tree.map(agg, trees)


def masked_weighted_mean(trees, mask: jnp.ndarray, weights: jnp.ndarray):
    """Weighted aggregate over normal nodes: Σ w_i x_i / Σ w_i with w
    zeroed outside ``mask``.  With uniform weights this reduces to
    `masked_mean` bit-for-bit (the FedBuff-staleness parity contract,
    pinned in tests/test_net.py): the masked weight sum equals the
    participant count, so numerator and denominator are the same ops.
    """
    w = mask.astype(jnp.float32) * weights.astype(jnp.float32)
    total = w.sum()
    denom = jnp.where(total > 0, total, 1.0)

    def agg(x):
        wf = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * wf).sum(0) / denom

    return jax.tree.map(agg, trees)


def detect_fell_back(accuracies, thr, valid=None) -> bool:
    """Did `detect`'s all-equal guard fire?  True when no (valid) node
    cleared the strict ``A > Thr`` comparison — the state in which the
    fallback marks *every* node normal, including known-malicious ones.
    Host-side companion to `detect`/the engines' fused detection: pure
    numpy on fetched metrics, used to emit the ``detect.fallback`` obs
    counter (a detection-aware attacker forces exactly this state early
    in training)."""
    accs = np.asarray(accuracies)
    strict = accs > np.asarray(thr)
    if valid is not None:
        strict = strict & np.asarray(valid, bool)
    return not bool(strict.any())


# ---------------------------------------------------------------------------
# trust scores (defense.kind="trust_weighted")
#
# Per-node trust is an EWMA over detection verdicts: each accepted update
# moves trust toward 1, each rejection toward 0 (step `eta`); nodes that
# don't participate keep their score.  Aggregation weights are the trust
# scores floored at `floor` and discounted by an uncertainty proxy — the
# node's accuracy deviation from the accepted cohort mean (cheap, already
# computed, and large exactly when an update is unlike its peers).  All
# (N,)-shaped elementwise ops: shard-oblivious under the mesh engines'
# node-axis shard_map, and ring-compatible with the detection state.
# ---------------------------------------------------------------------------

def trust_update(trust: jnp.ndarray, accepted: jnp.ndarray,
                 seen: jnp.ndarray, eta: float) -> jnp.ndarray:
    """EWMA trust step: trust += eta·(verdict − trust) for nodes ``seen``
    this round/window (verdict 1 if accepted, 0 if rejected); everyone
    else keeps their score."""
    target = accepted.astype(jnp.float32)
    stepped = trust + float(eta) * (target - trust)
    return jnp.where(seen, stepped, trust)


def trust_weights(trust: jnp.ndarray, accuracies: jnp.ndarray,
                  mask: jnp.ndarray, floor: float, uncertainty_scale: float,
                  ref: jnp.ndarray | None = None) -> jnp.ndarray:
    """Aggregation weights for `masked_weighted_mean`: floored trust,
    discounted by uncertainty ∝ |A_j − ref| (ref defaults to the accepted
    cohort's mean accuracy; mesh callers pass the globally-reduced ref so
    every shard discounts against the same anchor)."""
    if ref is None:
        m = mask.astype(jnp.float32)
        ref = ((accuracies.astype(jnp.float32) * m).sum()
               / jnp.maximum(m.sum(), 1.0))
    dev = jnp.abs(accuracies.astype(jnp.float32) - ref)
    unc = 1.0 + float(uncertainty_scale) * dev
    return jnp.maximum(trust, float(floor)) / unc


def staleness_weights(taus: jnp.ndarray, a: float) -> jnp.ndarray:
    """FedAsync polynomial staleness discount (τ+1)^-a per update — the
    per-update weights the buffered (FedBuff-style) mean applies when
    `SchedulePolicy.staleness_adaptive` is on."""
    return (1.0 + jnp.maximum(taus, 0).astype(jnp.float32)) ** (-float(a))


def evaluate_nodes(node_params, eval_fn: Callable, *eval_args) -> jnp.ndarray:
    """vmap a per-model accuracy function over the stacked node models."""
    return jax.vmap(lambda p: eval_fn(p, *eval_args))(node_params)


# ---------------------------------------------------------------------------
# streaming detection window (asynchronous Alg. 2)
#
# The asynchronous schemes have no cohort barrier, so the accuracy set 𝒜 is
# a sliding window of the most recent arrivals. The sequential trainer kept
# it as a Python list (`acc_window`); the fleet engines keep it device-side
# as a fixed-size ring buffer: NaN marks never-written slots, `count` is the
# total number of pushes (write cursor = count % window).
# ---------------------------------------------------------------------------

def default_window(n_nodes: int) -> int:
    """Default async sliding-window length: one full fleet pass, floored so
    tiny fleets still collect enough accuracies to threshold. The single
    source for `api.compile_plan`'s detect-window resolution and the
    scenario builders."""
    return max(n_nodes, 4)


def ring_init(window: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Empty ring of capacity `window` + zero push counter."""
    return (jnp.full((window,), jnp.nan, jnp.float32),
            jnp.zeros((), jnp.int32))


def ring_push(ring: jnp.ndarray, count: jnp.ndarray, value: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Append one accuracy, overwriting the oldest once the ring is full."""
    pos = jnp.mod(count, ring.shape[0])
    return ring.at[pos].set(jnp.asarray(value, jnp.float32)), count + 1


def ring_threshold(ring: jnp.ndarray, count: jnp.ndarray, s: float
                   ) -> jnp.ndarray:
    """Thr ← top-s% of the occupied ring slots (NaN slots excluded); the
    window is unordered for a percentile, so this equals
    `detection_threshold` over the trainer's `acc_window` list."""
    occupied = jnp.arange(ring.shape[0]) < count
    return jnp.nanpercentile(jnp.where(occupied, ring, jnp.nan), s)


def ring_detect(ring: jnp.ndarray, count: jnp.ndarray, acc: jnp.ndarray,
                s: float, warmup: int) -> jnp.ndarray:
    """One async detection step: is the arrival with cloud accuracy `acc`
    rejected? Matches the sequential event loop: the arrival's own accuracy
    is already in the window, detection only kicks in after `warmup`
    accuracies are *held* (the occupancy min(count, window), exactly
    `len(acc_window)` in the event loop — so a warmup larger than the
    window disables detection on both paths), and A ≤ Thr ⇒ malicious."""
    thr = ring_threshold(ring, count, s)
    held = jnp.minimum(count, ring.shape[0])
    return (held >= warmup) & (acc <= thr)
