"""Local gradient accumulation + magnitude-first upload — paper §5.1.

"we prefer to upload gradients with large values … small gradient updates are
accumulated in the gradient accumulation container" — the DGC-style scheme
(Lin et al. 2018) the paper adopts. Each node keeps a residual pytree; at
upload time the combined (residual + new gradient) tensor is split into a
sparse large-magnitude part (uploaded) and a small-magnitude part (kept).

The Pallas kernel `repro.kernels.sparsify` implements the fused
threshold+accumulate pass for TPU; this module is the jnp reference and the
pytree-level orchestration.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)


def leaf_threshold(combined: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """The DGC magnitude cutoff for one leaf: |value| quantile at 1−ratio.

    Single source of the threshold rule — the batched Pallas backend
    (`repro.fleet.engine`) uses the same cutoff with a `>=` keep test, so
    both paths stay in lockstep by construction.
    """
    flat = jnp.abs(combined.reshape(-1)).astype(jnp.float32)
    return jnp.quantile(flat, 1.0 - ratio)


def sparsify_leaf(combined: jnp.ndarray, ratio: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the top-`ratio` fraction by |value|; rest becomes the residual."""
    if ratio >= 1.0:
        return combined, jnp.zeros_like(combined)
    thr = leaf_threshold(combined, ratio)
    mask = jnp.abs(combined) >= thr
    upload = jnp.where(mask, combined, 0)
    residual = jnp.where(mask, 0, combined)
    return upload, residual


def accumulate_and_sparsify(residual, grad, ratio: float):
    """Returns (upload_tree, new_residual_tree, upload_fraction).

    upload_tree is dense-with-zeros (the sparse gradient); on a real wire it
    would be sent as (indices, values) — `upload_bytes` reports that size.
    """
    combined = jax.tree.map(
        lambda r, g: r + g.astype(jnp.float32), residual, grad)
    pairs = jax.tree.map(lambda c: sparsify_leaf(c, ratio), combined)
    upload = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_residual = jax.tree.map(lambda p: p[1], pairs,
                                is_leaf=lambda x: isinstance(x, tuple))
    nnz = sum(jnp.sum(u != 0) for u in jax.tree.leaves(upload))
    total = sum(u.size for u in jax.tree.leaves(upload))
    return upload, new_residual, nnz / total


def upload_bytes(tree, ratio: float, bytes_per_value: int = 4,
                 bytes_per_index: int = 4) -> int:
    """Analytic wire size of a sparsified upload (values + indices) —
    delegates to the shared `repro.net` fallback so this and
    `fleet.stages.bytes_per_node` can never drift (tests/test_net.py pins
    both).  Byte-accurate measured accounting lives in `repro.net`."""
    from ..net.codecs import analytic_upload_bytes
    total = sum(x.size for x in jax.tree.leaves(tree))
    return analytic_upload_bytes(total, ratio, bytes_per_value,
                                 bytes_per_index)
