"""The paper's contribution: ALDP + async update + malicious-node detection."""
from .accountant import MomentsAccountant                      # noqa: F401
from .aldp import (aldp_perturb, add_gaussian_noise,           # noqa: F401
                   clip_by_global_norm, epsilon_for_sigma, global_norm,
                   sigma_for_epsilon)
from .async_update import (communication_efficiency, mix,      # noqa: F401
                           mix_delta, mix_stale, mix_stale_sequence,
                           staleness_alpha)
from .detection import (detect, detection_threshold, masked_mean,  # noqa: F401
                        ring_detect, ring_init, ring_push, ring_threshold)
from .fed_step import FedStepConfig, fed_train_step, plain_train_step  # noqa: F401
