"""Asynchronous model-update scheme — paper §5.1 (Eq. 6) and §5.3.

The cloud mixes every arriving (possibly stale) node model into the global
model:   ω_t = α·ω_{t−1} + (1−α)·ω_new,   α ∈ (0,1).

α trades convergence rate against the additive variance term (Theorem 6);
the paper finds α = 0.5 optimal (following Xie et al., FedAsync). We also
provide the FedAsync polynomial staleness-adaptive α, which the paper's
buffer/scheduler design implies for heavily delayed updates.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mix(global_tree, new_tree, alpha: float | jnp.ndarray):
    """Eq. (6): ω ← α·ω + (1−α)·ω_new (leafwise convex combination)."""
    return jax.tree.map(
        lambda g, n: (alpha * g.astype(jnp.float32)
                      + (1.0 - alpha) * n.astype(jnp.float32)).astype(g.dtype),
        global_tree, new_tree)


def mix_delta(global_tree, delta_tree, alpha: float | jnp.ndarray):
    """Delta form: ω ← ω + (1−α)·Δ (equivalent when Δ = ω_new − ω)."""
    return jax.tree.map(
        lambda g, d: (g.astype(jnp.float32)
                      + (1.0 - alpha) * d.astype(jnp.float32)).astype(g.dtype),
        global_tree, delta_tree)


def staleness_alpha(alpha: float, staleness: jnp.ndarray | int,
                    a: float = 0.5) -> jnp.ndarray:
    """FedAsync polynomial staleness weighting: α_eff = α·(τ+1)^(−a).

    Returns the *mixing weight of the new model*, i.e. use
    ω ← (1 − α_eff)·ω + α_eff·ω_new with α_eff = (1−α)·(τ+1)^(−a) so that a
    fresh update (τ=0) reproduces Eq. (6) exactly.
    """
    return (1.0 - alpha) * (jnp.asarray(staleness, jnp.float32) + 1.0) ** (-a)


def mix_stale(global_tree, new_tree, alpha: float, staleness, a: float = 0.5):
    w_new = staleness_alpha(alpha, staleness, a)
    return jax.tree.map(
        lambda g, n: ((1.0 - w_new) * g.astype(jnp.float32)
                      + w_new * n.astype(jnp.float32)).astype(g.dtype),
        global_tree, new_tree)


def mix_stale_sequence(global_tree, new_trees, staleness: jnp.ndarray,
                       alpha: float, a: float = 0.5,
                       gate: Optional[jnp.ndarray] = None):
    """Fold a stack of arrivals into the global model in arrival order.

    A `lax.scan` of :func:`mix_stale` over the leading (arrival) axis of
    `new_trees` — the device-side equivalent of the async event loop's
    one-mix-per-arrival sequence, tested equal to sequentially applied
    `mix_stale`. (`AsyncFleetEngine`'s window fold interleaves this same
    gated mixing scan with streaming detection and version tracking; this
    standalone form is the reference for it and the public building block.)
    `staleness` (C,) is each arrival's τ; `gate` (C,) bool skips masked
    arrivals (default: all on).

    Returns (final_tree, per-arrival snapshots with leading axis C).
    """
    if gate is None:
        gate = jnp.ones(staleness.shape, bool)

    def body(g, inp):
        nt, tau, on = inp
        mixed = mix_stale(g, nt, alpha, tau, a)
        g = jax.tree.map(lambda m, p: jnp.where(on, m, p), mixed, g)
        return g, g

    return jax.lax.scan(body, global_tree, (new_trees, staleness, gate))


def communication_efficiency(comm_time: float, comp_time: float) -> float:
    """Eq. (5): κ = Comm / (Comp + Comm)."""
    denom = comm_time + comp_time
    return comm_time / denom if denom > 0 else 0.0
