"""Datacenter mapping of the paper's round: one jitted SPMD `fed_train_step`.

An "edge node" is one slice of the (pod, data) mesh axes. One federated round:

  1. each node runs `local_steps` of node-local SGD (vmap over the node axis
     of a lax.scan — no cross-node collective is emitted during local steps,
     which is exactly the paper's communication saving);
  2. per-node delta is clipped at S and perturbed with N(0, σ²S²) using a
     node-local PRNG key (ALDP, Eq. 8);
  3. the cloud tests every node model on a held-out batch and keeps the
     top-s% (malicious-node detection, Alg. 2);
  4. masked mean over nodes (the single gradient all-reduce of the round) and
     the α-mix server update (Eq. 6).

`plain_train_step` is the SFL baseline (per-step data-parallel update) used
for the paper-faithful baseline/technique roofline comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import aldp, detection


@dataclass(frozen=True)
class FedStepConfig:
    n_nodes: int = 16          # must equal prod of mesh axes the node dim spans
    local_steps: int = 4
    lr: float = 1e-2
    alpha: float = 0.5         # Eq. (6)
    clip_s: float = 1.0
    sigma: float = 1e-3        # noise multiplier (0 disables ALDP)
    detect: bool = True
    detect_s: float = 80.0


def _local_sgd(loss_fn: Callable, steps: int, lr: float, params, batches, key):
    """batches: pytree with leading (steps, ...) axis. Returns (params, mean loss)."""
    keys = jax.random.split(key, steps)

    def body(p, inp):
        batch, _k = inp
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        p = jax.tree.map(lambda a, b: (a - lr * b.astype(a.dtype)).astype(a.dtype),
                         p, g)
        return p, loss

    params, losses = jax.lax.scan(body, params, (batches, keys))
    return params, losses.mean()


def fed_train_step(global_params, node_batches, eval_batch, key, *,
                   loss_fn: Callable, acc_fn: Optional[Callable],
                   fcfg: FedStepConfig,
                   spmd_axes=None) -> Tuple[object, dict]:
    """One federated round as a single SPMD program.

    Args:
      global_params: the global model ω_t.
      node_batches: pytree, leaves (n_nodes, local_steps, per_node_batch, ...);
        the node axis should be sharded over the (pod, data) mesh axes.
      eval_batch: the cloud's testing batch (replicated) for Alg. 2;
        ignored when fcfg.detect is False or acc_fn is None.
      key: PRNG key; folded per node for the LDP noise.
      loss_fn: (params, batch) -> (loss, aux_metrics).
      acc_fn: (params, eval_batch) -> scalar accuracy in [0, 1].

    Returns (ω_{t+1}, metrics).
    """
    N = fcfg.n_nodes
    node_keys = jax.random.split(key, N)
    # spmd_axes: the mesh axes the node dim is sharded over — keeps every
    # per-node intermediate sharded on the node axis through the whole round
    vmap = partial(jax.vmap, spmd_axis_name=spmd_axes) if spmd_axes else jax.vmap

    # --- 1. local training on every node (no cross-node collectives) -------
    def one_node(batches, k):
        return _local_sgd(loss_fn, fcfg.local_steps, fcfg.lr,
                          global_params, batches, k)

    from ..sharding import ctx as shard_ctx  # noqa: E402 (cycle-free)
    with shard_ctx.suspended():   # node axis is sharded via spmd_axis_name
        node_params, node_losses = vmap(one_node, in_axes=(0, 0))(
            node_batches, node_keys)

    # --- 2. ALDP: per-node clip + Gaussian noise (Eq. 8) -------------------
    deltas = jax.tree.map(
        lambda np_, gp: np_ - gp[None].astype(np_.dtype), node_params,
        global_params)

    def perturb(delta, k):
        clipped, nrm = aldp.clip_by_global_norm(delta, fcfg.clip_s)
        if fcfg.sigma > 0:
            clipped = aldp.add_gaussian_noise(clipped, k, fcfg.sigma,
                                              fcfg.clip_s)
        return clipped, nrm

    deltas, delta_norms = vmap(perturb)(deltas, node_keys)

    # --- 3. cloud-side malicious-node detection (Alg. 2) -------------------
    if fcfg.detect and acc_fn is not None:
        # Build ALL node models as one stacked tree (node axis stays sharded
        # via spmd_axis_name). An indexed node_model(i) gather would force an
        # all-reduce of the full stacked deltas per node — measured 48% of
        # the round's collective bytes on kimi-k2 (EXPERIMENTS.md §Perf).
        node_models = jax.tree.map(
            lambda g, d: g[None].astype(d.dtype) + d, global_params, deltas)
        with shard_ctx.suspended():
            accs = vmap(lambda p: acc_fn(p, eval_batch))(node_models)
        mask, thr = detection.detect(accs, fcfg.detect_s)
    else:
        accs = jnp.zeros((N,), jnp.float32)
        mask = jnp.ones((N,), bool)
        thr = jnp.zeros((), jnp.float32)

    # --- 4. masked mean over nodes (THE all-reduce) + α-mix (Eq. 6) --------
    mean_delta = detection.masked_mean(deltas, mask)
    new_params = jax.tree.map(
        lambda g, d: (g.astype(jnp.float32)
                      + (1.0 - fcfg.alpha) * d).astype(g.dtype),
        global_params, mean_delta)

    metrics = {
        "loss": node_losses.mean(),
        "node_losses": node_losses,
        "delta_norm_mean": delta_norms.mean(),
        "node_accuracies": accs,
        "detect_threshold": thr,
        "n_normal": mask.sum(),
    }
    return new_params, metrics


# ---------------------------------------------------------------------------
# SFL baseline: standard synchronous data-parallel step
# ---------------------------------------------------------------------------

def plain_train_step(params, opt_state, batch, *, loss_fn: Callable,
                     optimizer) -> Tuple[object, object, dict]:
    """One synchronous step: grads all-reduced every step (the paper's SFL)."""
    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    params, opt_state = optimizer.update(params, grads, opt_state)
    metrics = {"loss": loss}
    if isinstance(aux, dict):
        metrics.update({k: v for k, v in aux.items()
                        if jnp.ndim(v) == 0})
    return params, opt_state, metrics
