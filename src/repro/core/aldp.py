"""Asynchronous Local Differential Privacy (ALDP) mechanism — paper §5.2.

The node-side perturbation of Eq. (8):

    Δω̄ᵏ = Δωᵏ / max(1, ‖Δωᵏ‖₂ / S)        (clip at sensitivity S)
    upload(Δω̄ᵏ + N(0, σ²S²))               (Gaussian mechanism, node-local)

All functions operate on parameter pytrees. The noise key must be node-local
(fold in the node id) so perturbation happens "on the edge node" — the cloud
never sees an unperturbed delta (node-level LDP, the paper's point of
difference vs server-side DP).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, clip_s: float) -> Tuple[object, jnp.ndarray]:
    """Eq. (8) clipping: tree / max(1, ‖tree‖₂/S). Returns (clipped, norm)."""
    nrm = global_norm(tree)
    scale = 1.0 / jnp.maximum(1.0, nrm / clip_s)
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), nrm


def add_gaussian_noise(tree, key, sigma: float, clip_s: float):
    """Adds N(0, (σS)²) independently to every coordinate."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (x + sigma * clip_s * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype))
        for x, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def aldp_perturb(tree, key, sigma: float, clip_s: float):
    """Full node-side ALDP: clip at S then add N(0, σ²S²). Returns
    (perturbed_tree, pre_clip_norm)."""
    clipped, nrm = clip_by_global_norm(tree, clip_s)
    return add_gaussian_noise(clipped, key, sigma, clip_s), nrm


def sigma_for_epsilon(epsilon: float, delta: float) -> float:
    """Single-release Gaussian mechanism calibration (Definition 2):
    ε = (Δf/σ̃)·√(2 log(1.25/δ)) with sensitivity Δf = S and σ̃ = σS
    ⇒ noise multiplier σ = √(2 log(1.25/δ)) / ε."""
    return math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def epsilon_for_sigma(sigma: float, delta: float) -> float:
    """Inverse of :func:`sigma_for_epsilon` (single release)."""
    return math.sqrt(2.0 * math.log(1.25 / delta)) / sigma
