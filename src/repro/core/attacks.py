"""Attacks the framework defends against — paper §3.3 + the adversary zoo.

* Label-flipping (data poisoning): malicious nodes change all labels of a
  source class to a target class in their local data (paper: MNIST '1'→'7',
  CIFAR 'dog'→'cat').
* Backdoor/trigger poisoning: a small corner patch stamped on a fraction of
  the malicious shards with the labels rewritten to a target class — the
  clean task barely moves, but triggered inputs are misclassified.
* Gradient-leakage (DLG, Zhu et al. 2019): a malicious cloud reconstructs a
  node's training batch from its uploaded gradients by gradient matching
  (Eq. 4). Used here to evaluate the ALDP defence: reconstruction quality
  (MSE / attack success rate) vs noise multiplier σ.

The poisoning success metrics (`flip_success_rate`,
`backdoor_success_rate`) measure the attacker's objective directly on held
-out data, which is what `benchmarks/attack_matrix.py` grids over.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Label-flipping (poisoning)
# ---------------------------------------------------------------------------

def flip_labels(labels: jnp.ndarray, src: int, dst: int) -> jnp.ndarray:
    """Change every label `src` to `dst` (the paper's attack)."""
    return jnp.where(labels == src, dst, labels)


# ---------------------------------------------------------------------------
# Backdoor/trigger poisoning
# ---------------------------------------------------------------------------

def stamp_trigger(x: np.ndarray, size: int = 2,
                  value: float = 1.0) -> np.ndarray:
    """Stamp a ``size``×``size`` trigger patch of ``value`` into the
    top-left corner of every image in ``x`` ((..., H, W, C) float array);
    returns a copy."""
    out = np.array(x, copy=True)
    out[..., :size, :size, :] = value
    return out


def flip_success_rate(forward: Callable, params, x: np.ndarray,
                      y: np.ndarray, src: int, dst: int) -> float:
    """Label-flip attacker objective on held-out data: the fraction of
    true-``src`` samples the model now assigns to ``dst``."""
    x = jnp.asarray(x)
    sel = np.asarray(y) == src
    if not sel.any():
        return 0.0
    pred = np.asarray(jnp.argmax(forward(params, x[np.where(sel)[0]]), -1))
    return float((pred == dst).mean())


def backdoor_success_rate(forward: Callable, params, x: np.ndarray,
                          y: np.ndarray, trigger_label: int,
                          trigger_size: int = 2,
                          trigger_value: float = 1.0) -> float:
    """Backdoor attacker objective: the fraction of non-target-class
    held-out samples that flip to ``trigger_label`` once the trigger is
    stamped on them."""
    sel = np.asarray(y) != trigger_label
    if not sel.any():
        return 0.0
    xt = stamp_trigger(np.asarray(x)[sel], size=trigger_size,
                       value=trigger_value)
    pred = np.asarray(jnp.argmax(forward(params, jnp.asarray(xt)), -1))
    return float((pred == trigger_label).mean())


# ---------------------------------------------------------------------------
# Gradient leakage (DLG) and the ASR metric
# ---------------------------------------------------------------------------

def _grad_match_loss(loss_fn: Callable, params, dummy_x, dummy_logits_y,
                     true_grads) -> jnp.ndarray:
    """‖∇L(F(W, X'); Y') − g‖² with soft labels (DLG uses softmax(Y'))."""
    y_soft = jax.nn.softmax(dummy_logits_y)

    def soft_loss(p):
        return loss_fn(p, dummy_x, y_soft)

    g = jax.grad(soft_loss)(params)
    return sum(jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
               for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(true_grads)))


def dlg_attack(loss_fn: Callable, params, true_grads, x_shape, n_classes: int,
               key, steps: int = 200, lr: float = 0.1
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run DLG: optimize (X', Y') to match the observed gradients (Eq. 4).

    loss_fn(params, x, y_soft) -> scalar (soft-label cross entropy).
    Adam on the gradient-match objective (plain GD stalls — the original DLG
    uses L-BFGS). Returns (reconstructed_x, match_loss_history).
    """
    kx, ky = jax.random.split(key)
    dummy_x = jax.random.normal(kx, x_shape, jnp.float32) * 0.1
    dummy_y = jax.random.normal(ky, (x_shape[0], n_classes), jnp.float32) * 0.1
    state = {"x": dummy_x, "y": dummy_y,
             "mx": jnp.zeros_like(dummy_x), "vx": jnp.zeros_like(dummy_x),
             "my": jnp.zeros_like(dummy_y), "vy": jnp.zeros_like(dummy_y),
             "t": jnp.zeros((), jnp.float32)}
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(st):
        val, (gx, gy) = jax.value_and_grad(_grad_match_loss, argnums=(2, 3))(
            loss_fn, params, st["x"], st["y"], true_grads)
        t = st["t"] + 1.0

        def adam(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + eps), m, v

        x, mx, vx = adam(st["x"], gx, st["mx"], st["vx"])
        y, my, vy = adam(st["y"], gy, st["my"], st["vy"])
        return {"x": x, "y": y, "mx": mx, "vx": vx, "my": my, "vy": vy,
                "t": t}, val

    hist = []
    for _ in range(steps):
        state, val = step(state)
        hist.append(val)
    return state["x"], jnp.stack(hist)


def reconstruction_mse(x_true: jnp.ndarray, x_rec: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.square(x_true.astype(jnp.float32) -
                               x_rec.astype(jnp.float32)))


def attack_success_rate(x_true: jnp.ndarray, x_rec: jnp.ndarray,
                        mse_threshold: float = 0.05) -> jnp.ndarray:
    """ASR (Definition 7): fraction of samples reconstructed below an MSE
    threshold — 'successfully reconstructed training data'."""
    per = jnp.mean(jnp.square(x_true.astype(jnp.float32) -
                              x_rec.astype(jnp.float32)),
                   axis=tuple(range(1, x_true.ndim)))
    return (per < mse_threshold).mean()
