"""Moments accountant (Abadi et al. 2016) via Rényi DP composition.

Tracks the privacy loss of repeated (possibly subsampled) Gaussian-mechanism
releases — the paper uses this to "evaluate δ given ε, σ and K" (§5.2).

Implementation: integer-order RDP of the subsampled Gaussian mechanism
(Mironov/Wang; the same formula TF-Privacy uses for integer α), composed
linearly over steps, converted with ε(δ) = min_α [ RDP(α) + log(1/δ)/(α−1) ].
Pure numpy (host-side bookkeeping, no tracing needed).
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence

import numpy as np

DEFAULT_ORDERS = tuple(range(2, 64)) + (128, 256, 512)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def rdp_gaussian(sigma: float, alpha: int) -> float:
    """RDP of the (unsampled) Gaussian mechanism with noise multiplier σ."""
    return alpha / (2.0 * sigma ** 2)


def rdp_subsampled_gaussian(q: float, sigma: float, alpha: int) -> float:
    """Integer-α RDP of the Poisson-subsampled Gaussian mechanism."""
    if q == 0:
        return 0.0
    if q >= 1.0:
        return rdp_gaussian(sigma, alpha)
    # log( sum_k C(alpha,k) (1-q)^(alpha-k) q^k exp(k(k-1)/(2 sigma^2)) )
    terms = []
    for k in range(alpha + 1):
        log_t = (_log_comb(alpha, k) + (alpha - k) * math.log1p(-q)
                 + k * math.log(q) + k * (k - 1) / (2.0 * sigma ** 2))
        terms.append(log_t)
    m = max(terms)
    log_sum = m + math.log(sum(math.exp(t - m) for t in terms))
    return log_sum / (alpha - 1)


def eps_from_rdp(rdp: Sequence[float], orders: Sequence[int], delta: float) -> float:
    eps = [r + math.log(1.0 / delta) / (a - 1) for r, a in zip(rdp, orders)]
    return max(min(eps), 0.0)


class MomentsAccountant:
    """Accumulates RDP over training rounds; queries ε(δ) or δ(ε).

    Args:
      sigma: noise multiplier (noise stddev = sigma * clip_S).
      sampling_rate: per-round probability a given node/example participates
        (paper: m/K nodes sampled per round).
    """

    def __init__(self, sigma: float, sampling_rate: float = 1.0,
                 orders: Iterable[int] = DEFAULT_ORDERS):
        if sigma <= 0:
            raise ValueError(
                f"MomentsAccountant needs sigma > 0 (got {sigma}); a "
                "zero-noise run spends no privacy budget — don't construct "
                "an accountant for it.")
        self.sigma = float(sigma)
        self.q = float(sampling_rate)
        self.orders = tuple(orders)
        self._rdp = np.zeros(len(self.orders))
        self.steps = 0

    def step(self, n: int = 1) -> None:
        inc = np.array([rdp_subsampled_gaussian(self.q, self.sigma, a)
                        for a in self.orders])
        self._rdp += n * inc
        self.steps += n

    def epsilon(self, delta: float) -> float:
        if self.steps == 0:
            return 0.0
        return eps_from_rdp(self._rdp, self.orders, delta)

    def delta(self, epsilon: float) -> float:
        """Smallest δ achieving the target ε under the accumulated RDP."""
        if self.steps == 0:
            return 0.0
        log_deltas = [(a - 1) * (r - epsilon) for r, a in zip(self._rdp, self.orders)]
        return float(min(1.0, math.exp(min(log_deltas))))
