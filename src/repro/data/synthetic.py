"""Synthetic datasets (offline container: no MNIST/CIFAR files).

`make_image_dataset` builds an MNIST/CIFAR-shaped classification problem:
each class has a smooth random prototype image; samples are
prototype + Gaussian noise. A small CNN reaches >90% accuracy in a few
hundred SGD steps, label-flipping measurably poisons it, and DLG can
reconstruct samples from gradients — all the properties the paper's
experiments need.

`make_token_dataset` builds an order-2 Markov language-modelling task for the
LLM-family smoke tests (learnable: a transformer quickly beats uniform).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _smooth(rng: np.random.Generator, hw: Tuple[int, int], ch: int,
            k: int = 5) -> np.ndarray:
    img = rng.normal(size=(hw[0] + k - 1, hw[1] + k - 1, ch))
    kern = np.ones((k, k)) / (k * k)
    out = np.zeros((hw[0], hw[1], ch))
    for c in range(ch):
        for i in range(hw[0]):
            for j in range(hw[1]):
                out[i, j, c] = (img[i:i + k, j:j + k, c] * kern).sum()
    return out


def make_image_dataset(seed: int, n: int, hw: Tuple[int, int] = (28, 28),
                       ch: int = 1, n_classes: int = 10,
                       noise: float = 0.35):
    """Returns (x (n,H,W,C) float32 in [0,1], y (n,) int32)."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth(rng, hw, ch) for _ in range(n_classes)])
    protos = (protos - protos.min()) / (np.ptp(protos) + 1e-9)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = protos[y] + rng.normal(0, noise, size=(n, hw[0], hw[1], ch))
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return x, y


def make_token_dataset(seed: int, n_seq: int, seq_len: int, vocab: int):
    """Order-2 Markov chain over the vocab; returns tokens (n,S+1) int32."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each (a) maps to a few likely successors
    n_succ = min(4, vocab)
    succ = rng.integers(0, vocab, size=(vocab, n_succ))
    seqs = np.zeros((n_seq, seq_len + 1), dtype=np.int32)
    state = rng.integers(0, vocab, size=n_seq)
    for t in range(seq_len + 1):
        seqs[:, t] = state
        choice = rng.integers(0, n_succ, size=n_seq)
        jump = rng.random(n_seq) < 0.1
        state = np.where(jump, rng.integers(0, vocab, size=n_seq),
                         succ[state, choice])
    return seqs


def partition_iid(n: int, n_nodes: int, seed: int = 0):
    """Random equal split; returns list of index arrays."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return np.array_split(perm, n_nodes)


def partition_dirichlet(labels: np.ndarray, n_nodes: int, alpha: float = 0.5,
                        seed: int = 0):
    """Non-IID split: per-class Dirichlet allocation across nodes."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_node = [[] for _ in range(n_nodes)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_nodes)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for node, part in enumerate(np.split(idx, cuts)):
            idx_by_node[node].append(part)
    return [np.concatenate(parts) for parts in idx_by_node]
