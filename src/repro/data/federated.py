"""Federated data assembly: per-node shards + label-flipping adversaries."""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..core.attacks import flip_labels
from .synthetic import make_image_dataset, partition_dirichlet, partition_iid


def make_federated_image_data(
        seed: int, n_nodes: int, n_malicious: int, *,
        n_train: int = 4000, n_test: int = 1000, n_cloud_test: int = 500,
        hw: Tuple[int, int] = (28, 28), ch: int = 1, n_classes: int = 10,
        flip_src: int = 1, flip_dst: int = 7, iid: bool = True,
        dirichlet_alpha: float = 0.5):
    """Returns (node_data, test, cloud_test, malicious_ids).

    The first ``n_malicious`` nodes flip labels src->dst in their local data
    (the paper's label-flipping attack: MNIST '1'→'7').
    """
    x, y = make_image_dataset(seed, n_train + n_test + n_cloud_test,
                              hw=hw, ch=ch, n_classes=n_classes)
    x_tr, y_tr = x[:n_train], y[:n_train]
    x_te, y_te = x[n_train:n_train + n_test], y[n_train:n_train + n_test]
    x_ct, y_ct = x[n_train + n_test:], y[n_train + n_test:]

    if iid:
        parts = partition_iid(n_train, n_nodes, seed)
    else:
        parts = partition_dirichlet(y_tr, n_nodes, dirichlet_alpha, seed)

    malicious = list(range(n_malicious))
    node_data = []
    for node, idx in enumerate(parts):
        xn, yn = x_tr[idx], y_tr[idx]
        if node in malicious:
            yn = np.asarray(flip_labels(yn, flip_src, flip_dst))
        node_data.append((xn, yn))
    return node_data, (x_te, y_te), (x_ct, y_ct), malicious
