"""Federated data assembly: per-node shards + the data-level adversaries.

`make_federated_image_data` builds the fleet's shards and poisons the
malicious ones according to the attack kind:

  * ``label_flip`` / ``adaptive`` — the paper's src->dst label flip
    (adaptive differs only engine-side, via the detection-aware throttle);
  * ``sybil``    — every sybil trains an identical copy of the first
    sybil's flipped shard (colluding clones push the same poisoned
    direction);
  * ``backdoor`` — a ``trigger_size``² corner patch of ``trigger_value``
    stamped on ``trigger_frac`` of each malicious shard, labels rewritten
    to ``trigger_label`` (clean-task accuracy barely moves);
  * ``ddos``     — shards stay clean: the attack lives entirely in the
    transport layer.

Malicious placement is seeded-random by request (``placement="random"``,
set-based membership, reproducible per seed) or the legacy first-k nodes
(``placement="first"``, the default here for byte-compatibility with
existing direct callers).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from ..core.attacks import flip_labels, stamp_trigger
from .synthetic import make_image_dataset, partition_dirichlet, partition_iid

ATTACK_KINDS = ("label_flip", "sybil", "backdoor", "adaptive", "ddos")


def select_malicious(seed: int, n_nodes: int, n_malicious: int,
                     placement: str = "random") -> List[int]:
    """The malicious node ids: a seeded draw without replacement
    (``"random"``) or the legacy first-k (``"first"``).  Sorted, so
    membership tests and shard assembly are order-stable."""
    n_malicious = max(0, min(int(n_malicious), int(n_nodes)))
    if n_malicious == 0:
        return []
    if placement == "first":
        return list(range(n_malicious))
    if placement != "random":
        raise ValueError(f"placement must be 'random' or 'first', got "
                         f"{placement!r}")
    rng = np.random.default_rng(
        np.random.SeedSequence([int(seed), int(n_nodes), 0xAD]))
    ids = rng.choice(n_nodes, size=n_malicious, replace=False)
    return sorted(int(i) for i in ids)


def _poison_backdoor(x: np.ndarray, y: np.ndarray, *, rng, frac: float,
                     label: int, size: int, value: float):
    """Trigger-stamp a seeded ``frac`` of the shard: corner patch +
    relabel."""
    n = y.shape[0]
    k = max(1, int(round(frac * n))) if n else 0
    if k == 0:
        return x, y
    idx = rng.choice(n, size=k, replace=False)
    x = x.copy()
    y = y.copy()
    x[idx] = stamp_trigger(x[idx], size=size, value=value)
    y[idx] = label
    return x, y


def make_federated_image_data(
        seed: int, n_nodes: int, n_malicious: int, *,
        n_train: int = 4000, n_test: int = 1000, n_cloud_test: int = 500,
        hw: Tuple[int, int] = (28, 28), ch: int = 1, n_classes: int = 10,
        flip_src: int = 1, flip_dst: int = 7, iid: bool = True,
        dirichlet_alpha: float = 0.5, attack_kind: str = "label_flip",
        placement: str = "first", trigger_frac: float = 0.5,
        trigger_label: int = 0, trigger_size: int = 2,
        trigger_value: float = 1.0):
    """Returns (node_data, test, cloud_test, malicious_ids)."""
    if attack_kind not in ATTACK_KINDS:
        raise ValueError(f"attack_kind {attack_kind!r} not in {ATTACK_KINDS}")
    x, y = make_image_dataset(seed, n_train + n_test + n_cloud_test,
                              hw=hw, ch=ch, n_classes=n_classes)
    x_tr, y_tr = x[:n_train], y[:n_train]
    x_te, y_te = x[n_train:n_train + n_test], y[n_train:n_train + n_test]
    x_ct, y_ct = x[n_train + n_test:], y[n_train + n_test:]

    if iid:
        parts = partition_iid(n_train, n_nodes, seed)
    else:
        parts = partition_dirichlet(y_tr, n_nodes, dirichlet_alpha, seed)

    malicious = select_malicious(seed, n_nodes, n_malicious,
                                 placement=placement)
    mal_set = frozenset(malicious)
    node_data = []
    for node, idx in enumerate(parts):
        xn, yn = x_tr[idx], y_tr[idx]
        if node in mal_set and attack_kind != "ddos":
            if attack_kind == "backdoor":
                rng = np.random.default_rng(
                    np.random.SeedSequence([int(seed), int(node), 0xBD]))
                xn, yn = _poison_backdoor(
                    xn, yn, rng=rng, frac=trigger_frac, label=trigger_label,
                    size=trigger_size, value=trigger_value)
            else:
                yn = np.asarray(flip_labels(yn, flip_src, flip_dst))
        node_data.append((xn, yn))
    if attack_kind == "sybil" and malicious:
        # colluding clones: identical shards => identical poisoned deltas
        x0, y0 = node_data[malicious[0]]
        for m in malicious[1:]:
            node_data[m] = (x0.copy(), y0.copy())
    return node_data, (x_te, y_te), (x_ct, y_ct), malicious
