from .synthetic import (make_image_dataset, make_token_dataset,   # noqa: F401
                        partition_dirichlet, partition_iid)
from .federated import make_federated_image_data                  # noqa: F401
