"""Fused Mamba1 selective scan — Pallas TPU kernel.

§Perf pair 3 (EXPERIMENTS.md) showed falcon-mamba's training memory term is
dominated by the XLA scan's materialisation of the (B, c, d_inner, N)
decay/input tensors. This kernel is the structural fix: the SSM state h
(block_d, N) lives in VMEM scratch across the *sequential* L-grid dimension,
decays and input terms are built on-core per tile, and only x-sized inputs
and y-sized outputs ever touch HBM — the h_all tensor never exists.

Layout: x, dt (B, L, D); Bm, Cm (B, L, N); A (D, N); grid (B, D/bd, L/bl)
with L innermost (sequential ⇒ carry persists).

    h_t = exp(dt_t · A) ∘ h_{t-1} + (dt_t · x_t) ⊗ B_t
    y_t = (h_t · C_t) + D ∘ x_t        (D-residual applied by the caller)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            bl: int, nl: int):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)              # (bd,)
        dtt = dt_ref[0, t].astype(jnp.float32)            # (bd,)
        bt = b_ref[0, t].astype(jnp.float32)              # (N,)
        ct = c_ref[0, t].astype(jnp.float32)              # (N,)
        decay = jnp.exp(dtt[:, None] * a)                 # (bd, N)
        h = decay * h + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t] = (h @ ct).astype(y_ref.dtype)        # (bd,)
        return h

    h = jax.lax.fori_loop(0, bl, step, h_ref[...])
    h_ref[...] = h

    @pl.when(il == nl - 1)
    def _finish():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(x: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray,
                   Cm: jnp.ndarray, A: jnp.ndarray, *, block_l: int = 128,
                   block_d: int = 256, interpret: bool = True):
    """x, dt (B, L, D); Bm, Cm (B, L, N); A (D, N).

    Returns (y (B, L, D), h_final (B, D, N)). The caller applies the D-skip
    (`y + D*x`) and gating, matching `repro.models.ssm.mamba1_fwd` internals.
    """
    B, L, D = x.shape
    N = A.shape[1]
    bl = min(block_l, L)
    bd = min(block_d, D)
    nl = -(-L // bl)
    nd = -(-D // bd)
    pad_l = nl * bl - L
    pad_d = nd * bd - D
    if pad_l or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_l), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_l), (0, pad_d)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_l), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_l), (0, 0)))
        A = jnp.pad(A, ((0, pad_d), (0, 0)))

    kernel = functools.partial(_kernel, bl=bl, nl=nl)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nd, nl),
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # x
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # dt
            pl.BlockSpec((1, bl, N), lambda b, d, l: (b, l, 0)),    # B
            pl.BlockSpec((1, bl, N), lambda b, d, l: (b, l, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, l: (d, 0)),          # A
        ],
        out_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, l: (b, l, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, l: (b, d, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nl * bl, nd * bd), x.dtype),
            jax.ShapeDtypeStruct((B, nd * bd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
    return y[:, :L, :D], h[:, :D]
