"""Jitted public wrappers around the Pallas kernels.

`attention_pallas` adapts the model layout (B, S, H, D) to the kernel layout;
`aldp_perturb_pallas` applies the fused clip+noise kernel across a parameter
pytree (one flat pass per leaf, node-seeded); `sparsify_pallas` runs the DGC
container update on a pytree with a given keep-ratio.

All wrappers take `interpret=` (True = CPU-validatable; False = real TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.aldp import global_norm
from .flash_attention import flash_attention
from .ldp_noise import ldp_perturb_flat
from .sparsify import sparsify_flat


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                     interpret: bool = True):
    """Model layout: q (B, S, H, D); k, v (B, S, KV, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention(qt, kt, vt, causal=causal, window=window,
                        interpret=interpret)
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("sigma", "clip_s", "interpret"))
def aldp_perturb_pallas(tree, seed: jnp.ndarray, *, sigma: float,
                        clip_s: float, interpret: bool = True):
    """Pytree clip-at-S + Gaussian noise, fused per leaf (Eq. 8)."""
    nrm = global_norm(tree)
    scale = 1.0 / jnp.maximum(1.0, nrm / clip_s)
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1)
        pert = ldp_perturb_flat(flat, seed + i * 7919, scale, sigma, clip_s,
                                interpret=interpret)
        out.append(pert.reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out), nrm


@partial(jax.jit, static_argnames=("ratio", "interpret"))
def sparsify_pallas(grad_tree, residual_tree, *, ratio: float,
                    interpret: bool = True) -> Tuple[object, object]:
    """DGC container update at keep-`ratio` (threshold from |combined|
    quantile, computed in jnp; the elementwise pass is the fused kernel)."""
    g_leaves, treedef = jax.tree.flatten(grad_tree)
    r_leaves = jax.tree.leaves(residual_tree)
    combined_abs = jnp.concatenate(
        [jnp.abs(g.reshape(-1).astype(jnp.float32) +
                 r.reshape(-1).astype(jnp.float32))
         for g, r in zip(g_leaves, r_leaves)])
    thr = jnp.quantile(combined_abs, 1.0 - ratio) if ratio < 1.0 else \
        jnp.zeros((), jnp.float32)
    ups, news = [], []
    for g, r in zip(g_leaves, r_leaves):
        up, nr = sparsify_flat(g.reshape(-1), r.reshape(-1), thr,
                               interpret=interpret)
        ups.append(up.reshape(g.shape))
        news.append(nr.reshape(r.shape))
    return jax.tree.unflatten(treedef, ups), jax.tree.unflatten(treedef, news)
