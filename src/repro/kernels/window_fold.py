"""Arrival-ordered window fold — Pallas TPU kernel.

The async engines' sequential window fold (Eq. (6)/`mix_stale` in arrival
order, `async_engine.make_window_folds`) is a `lax.scan` whose carry is the
whole parameter vector: every arrival reads the running params + its omega
from HBM and writes the new params + a per-arrival snapshot back (~4P of
traffic per arrival).  The detection ring / staleness / version bookkeeping
in that scan is all scalar work, so the fold splits exactly in two:

  1. a scalar *control scan* (in the engine) over (accuracy, staleness,
     arrival) that pushes the detection ring and emits, per arrival, a gate
     bit and the two mix coefficients (a_i, b_i) such that
     params_i = gate_i ? a_i·params_{i-1} + b_i·omega_i : params_{i-1}
     — (α, 1−α) for Eq. (6), ((1−w), w) with w = (1−α)(τ+1)^−a for the
     FedAsync staleness-adaptive mix;
  2. this kernel: grid (param_block, arrival) with arrivals innermost, so
     each param block stays resident in VMEM as the running accumulator
     across the whole window — per arrival it reads one omega block and
     writes one snapshot block (~2P per arrival, and the carry never
     round-trips HBM).

The per-arrival snapshots are still produced (they are the redispatch
payload — each processed node receives the model right after its own
arrival), but the running carry is not materialized per step.

Parity: bit-equal to the reference scan for float32 params — same
multiply/add expression `a·params + b·omega`, same `where(gate, ...)`
selection (a gated-off arrival leaves params bitwise untouched).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ldp_noise import LANE


def _fold_kernel(gate_ref, a_ref, b_ref, p_ref, om_ref, seq_ref, out_ref):
    i = pl.program_id(1)                 # arrival (innermost: the out_ref
                                         # block is the resident accumulator)
    @pl.when(i == 0)
    def _init():
        out_ref[...] = p_ref[...]
    cur = out_ref[...]
    new = a_ref[i] * cur + b_ref[i] * om_ref[0]
    cur = jnp.where(gate_ref[i] != 0, new, cur)
    seq_ref[0] = cur
    out_ref[...] = cur


def window_fold_fleet(p_flat: jnp.ndarray, om_flat: jnp.ndarray,
                      gates: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, *,
                      block_rows: int = 256, interpret: bool = True):
    """Fold a window of arrivals into the flattened global params.

    p_flat (N,) f32 params; om_flat (C, N) f32 per-arrival node models in
    arrival order; gates (C,) bool/int mix gates (False = rejected or
    padded slot, params pass through bitwise); a, b (C,) f32 coefficients
    on (params, omega) per arrival.

    Returns (final params (N,), per-arrival snapshots (C, N)).
    """
    c, n = om_flat.shape
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    p = jnp.pad(p_flat.astype(jnp.float32), (0, pad)).reshape(rows_total,
                                                              cols)
    om = jnp.pad(om_flat.astype(jnp.float32),
                 ((0, 0), (0, pad))).reshape(c, rows_total, cols)
    if pad_r:
        p = jnp.pad(p, ((0, pad_r), (0, 0)))
        om = jnp.pad(om, ((0, 0), (0, pad_r), (0, 0)))

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    seq, final = pl.pallas_call(
        _fold_kernel,
        grid=(nb, c),
        in_specs=[
            smem, smem, smem,
            pl.BlockSpec((block_rows, cols), lambda j, i: (j, 0)),
            pl.BlockSpec((1, block_rows, cols), lambda j, i: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, cols), lambda j, i: (i, j, 0)),
            pl.BlockSpec((block_rows, cols), lambda j, i: (j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(om.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)],
        interpret=interpret,
    )(gates.astype(jnp.int32), a.astype(jnp.float32),
      b.astype(jnp.float32), p, om)
    return final.reshape(-1)[:n], seq.reshape(c, -1)[:, :n]


def window_fold_reference(p_flat: jnp.ndarray, om_flat: jnp.ndarray,
                          gates: jnp.ndarray, a: jnp.ndarray,
                          b: jnp.ndarray):
    """Pure-jnp mirror of `window_fold_fleet` (a lax.scan) — the fallback
    and parity oracle; bit-equal for f32 inputs."""

    def body(cur, inp):
        om_i, g_i, a_i, b_i = inp
        new = a_i * cur + b_i * om_i
        cur = jnp.where(g_i, new, cur)
        return cur, cur

    final, seq = jax.lax.scan(
        body, p_flat.astype(jnp.float32),
        (om_flat.astype(jnp.float32), gates.astype(bool),
         a.astype(jnp.float32), b.astype(jnp.float32)))
    return final, seq
