"""Blocked causal GQA flash attention — Pallas TPU kernel.

TPU-native adaptation of the attention hot loop (dominates prefill_32k):
q/k/v tiles live in VMEM (BlockSpec below), the kv axis is the innermost
*sequential* grid dimension so the online-softmax accumulators persist in
VMEM scratch across kv steps, and fully-masked kv blocks are skipped with
``pl.when`` (causal/sliding-window block skipping — the structural win over
the jnp reference, which masks but still computes).

Layout: q (B, H, Sq, D), k/v (B, KV, Sk, D), GQA via h -> h // (H // KV).
Block sizes default to MXU/VPU-aligned (128, 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, causal: bool, window: int,
            sq: int, sk: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level relevance: skip blocks that are entirely masked
    first_q = iq * bq
    last_q = iq * bq + bq - 1
    first_k = ik * bk
    last_k = ik * bk + bk - 1
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, first_k <= last_q)
    if window > 0:
        relevant = jnp.logical_and(relevant, last_k > first_q - window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = kpos < sk
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B, H, Sq, D); k, v (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pad_q = nq * bq - Sq
    pad_k = nk * bk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        sq=Sq, sk=Sk, scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom l
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
