"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B, H, Sq, D); k, v (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def ldp_perturb_flat_ref(flat: jnp.ndarray, clip_scale: jnp.ndarray,
                         noise: jnp.ndarray | None, sigma: float,
                         clip_s: float) -> jnp.ndarray:
    """Deterministic part of the LDP kernel: scale + (given) noise."""
    out = flat.astype(jnp.float32) * clip_scale
    if noise is not None and sigma > 0:
        out = out + sigma * clip_s * noise
    return out.astype(flat.dtype)


def sparsify_flat_ref(grad: jnp.ndarray, residual: jnp.ndarray,
                      threshold: jnp.ndarray):
    c = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    keep = jnp.abs(c) >= threshold
    return (jnp.where(keep, c, 0.0).astype(grad.dtype),
            jnp.where(keep, 0.0, c).astype(residual.dtype))


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray,
                 Cm: jnp.ndarray, A: jnp.ndarray):
    """Sequential Mamba2 (scalar-per-head decay) oracle.

    x (B,L,H,P); dt (B,L,H); Bm, Cm (B,L,N); A (H,).
    Returns (y (B,L,H,P), h (B,H,P,N))."""
    B, L, H, P = x.shape
    N = Bm.shape[2]

    def step(h, inp):
        xt, dtt, bt, ct = inp            # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A[None])   # (B,H)
        dx = dtt[..., None] * xt         # (B,H,P)
        h = decay[..., None, None] * h + \
            jnp.einsum("bn,bhp->bhpn", bt, dx)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


def selective_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray,
                       Cm: jnp.ndarray, A: jnp.ndarray):
    """Sequential Mamba1 scan oracle. Shapes as kernels.selective_scan."""
    B, L, D = x.shape

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (B,D),(B,D),(B,N),(B,N)
        decay = jnp.exp(dtt[..., None] * A[None])        # (B,D,N)
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((B, D, A.shape[1]), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h
