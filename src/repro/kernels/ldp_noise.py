"""Fused ALDP perturbation — Pallas TPU kernel.

The paper's node-side hot loop (Eq. 8) is three memory-bound passes in naive
form: scale-by-clip, sample Gaussian noise, add. This kernel fuses them into
a single HBM pass over the flattened gradient: each (rows × 1024) VMEM block
is scaled by the precomputed clip factor and perturbed with Gaussian noise
generated on-core (pltpu PRNG + Box–Muller), so noise never touches HBM.

The global L2 norm is a separate reduction pass (unavoidable data dependency:
the clip scale needs the whole-tensor norm before any output element).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024


def _hash_uniform(seed: jnp.ndarray, stream: int, shape) -> jnp.ndarray:
    """Counter-based uniform(0,1) from a murmur3-finalizer hash of the
    per-element index — pure u32 VPU ops, identical on CPU interpret and TPU.
    (pltpu.prng_random_bits has no CPU-interpret lowering in this jax build.)
    """
    rows = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    x = rows * jnp.uint32(shape[1]) + cols
    x = x + seed.astype(jnp.uint32) * jnp.uint32(2654435761)
    x = x + jnp.uint32((stream * 0x9E3779B9) & 0xFFFFFFFF)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) / jnp.float32(1 << 24)


def _kernel(seed_ref, scale_ref, g_ref, o_ref, *, sigma_s: float,
            block_rows: int):
    pid = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32) * scale_ref[0]
    if sigma_s > 0.0:
        shape = g.shape
        blk_seed = seed_ref[0] + pid * 7919
        # Box–Muller from two independent uniform draws
        u1 = jnp.maximum(_hash_uniform(blk_seed, 1, shape), 1e-12)
        u2 = _hash_uniform(blk_seed, 2, shape)
        r = jnp.sqrt(-2.0 * jnp.log(u1))
        theta = (2.0 * math.pi) * u2
        g = g + sigma_s * r * jnp.cos(theta)
    o_ref[...] = g.astype(o_ref.dtype)


def _fleet_kernel(seed_ref, scale_ref, g_ref, o_ref, *, sigma_s: float):
    """Node-batched variant: grid (node, block); per-node seed/scale in SMEM.

    Per-block seeding matches the flat kernel (`seed + block*7919`) so each
    node's output is bit-identical to a standalone `ldp_perturb_flat` call
    with that node's seed.
    """
    node = pl.program_id(0)
    pid = pl.program_id(1)
    g = g_ref[0].astype(jnp.float32) * scale_ref[node]
    if sigma_s > 0.0:
        shape = g.shape
        blk_seed = seed_ref[node] + pid * 7919
        u1 = jnp.maximum(_hash_uniform(blk_seed, 1, shape), 1e-12)
        u2 = _hash_uniform(blk_seed, 2, shape)
        r = jnp.sqrt(-2.0 * jnp.log(u1))
        theta = (2.0 * math.pi) * u2
        g = g + sigma_s * r * jnp.cos(theta)
    o_ref[0] = g.astype(o_ref.dtype)


def ldp_perturb_flat(flat: jnp.ndarray, seed: jnp.ndarray,
                     clip_scale: jnp.ndarray, sigma: float, clip_s: float,
                     *, block_rows: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """flat (N,) float; seed () int32; clip_scale () float32 = 1/max(1,‖g‖/S).

    Returns clip_scale·flat + N(0, (σS)²) with the same shape/dtype.
    """
    n = flat.shape[0]
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    x = jnp.pad(flat, (0, pad)).reshape(rows_total, cols)
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        x = jnp.pad(x, ((0, pad_r), (0, 0)))

    kernel = functools.partial(_kernel, sigma_s=float(sigma) * float(clip_s),
                               block_rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, flat.dtype),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.int32), clip_scale.reshape(1).astype(jnp.float32), x)
    return out.reshape(-1)[:n]


def ldp_perturb_fleet(flat: jnp.ndarray, seeds: jnp.ndarray,
                      clip_scales: jnp.ndarray, sigma: float, clip_s: float,
                      *, block_rows: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Whole-cohort ALDP pass: one kernel launch perturbs every node's delta.

    flat (K, N) stacked per-node deltas; seeds (K,) int32 (must be distinct
    per node — node-local noise); clip_scales (K,) f32 = 1/max(1,‖g_k‖/S).
    Returns clip_scales[:,None]·flat + N(0, (σS)²), shape/dtype preserved.
    """
    k, n = flat.shape
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    x = jnp.pad(flat, ((0, 0), (0, pad))).reshape(k, rows_total, cols)
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        x = jnp.pad(x, ((0, 0), (0, pad_r), (0, 0)))

    kernel = functools.partial(_fleet_kernel,
                               sigma_s=float(sigma) * float(clip_s))
    out = pl.pallas_call(
        kernel,
        grid=(k, nb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, flat.dtype),
        interpret=interpret,
    )(seeds.astype(jnp.int32), clip_scales.astype(jnp.float32), x)
    return out.reshape(k, -1)[:, :n]
