"""Fused Mamba2 SSD chunk scan — Pallas TPU kernel (zamba2 family).

The XLA path (`repro.models.ssm.mamba2_fwd`) materialises per-chunk decay
matrices and carries chunk states through HBM. Here the running state
h (bh, P, N) lives in VMEM scratch across the sequential chunk-grid
dimension; the intra-chunk SSD matmuls (scores = C·Bᵀ masked by the decay
kernel) and the inter-chunk state propagation happen on-core, so HBM sees
only x-sized inputs and y-sized outputs.

Shapes: x (B, L, H, P); dt (B, L, H); Bm, Cm (B, L, N) (n_groups == 1,
broadcast over heads); A (H,). Grid (B, H/bh, L/c), L innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            c: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                 # (bh,)
    dt = dt_ref[0].astype(jnp.float32)                 # (c, bh)
    x = x_ref[0].astype(jnp.float32)                   # (c, bh, P)
    Bm = b_ref[0].astype(jnp.float32)                  # (c, N)
    Cm = c_ref[0].astype(jnp.float32)                  # (c, N)

    la = dt * a[None, :]                               # (c, bh) log decay
    lcum = jnp.cumsum(la, axis=0)                      # (c, bh)
    dx = dt[..., None] * x                             # (c, bh, P)

    # intra-chunk (diagonal) term: masked decay kernel × scores
    scores = Cm @ Bm.T                                 # (c, c) group-shared
    decay = jnp.exp(lcum[:, None, :] - lcum[None, :, :])   # (c_t, c_s, bh)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    M = jnp.where(tri[..., None], decay * scores[..., None], 0.0)
    y = jnp.einsum("tsh,shp->thp", M, dx)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                     # (bh, P, N)
    y += jnp.einsum("tn,hpn->thp", Cm, h) * jnp.exp(lcum)[..., None]

    # state update
    tail = jnp.exp(lcum[-1:, :] - lcum)                # (c, bh)
    h_ref[...] = (jnp.exp(lcum[-1])[:, None, None] * h
                  + jnp.einsum("sn,shp->hpn", Bm, dx * tail[..., None]))
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray,
             Cm: jnp.ndarray, A: jnp.ndarray, *, chunk: int = 128,
             block_h: int = 8, interpret: bool = True):
    """Returns (y (B, L, H, P), h_final (B, H, P, N)).

    Caller applies the D-skip and gated norm (`models.ssm.mamba2_fwd`)."""
    B, L, H, P = x.shape
    N = Bm.shape[2]
    c = min(chunk, L)
    bh = min(block_h, H)
    nc = -(-L // c)
    nh = -(-H // bh)
    pad_l = nc * c - L
    pad_h = nh * bh - H
    if pad_l or pad_h:
        x = jnp.pad(x, ((0, 0), (0, pad_l), (0, pad_h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_l), (0, pad_h)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_l), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_l), (0, 0)))
        A = jnp.pad(A, (0, pad_h))

    kernel = functools.partial(_kernel, c=c, nc=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, c, bh, P), lambda b, h_, l: (b, l, h_, 0)),  # x
            pl.BlockSpec((1, c, bh), lambda b, h_, l: (b, l, h_)),        # dt
            pl.BlockSpec((1, c, N), lambda b, h_, l: (b, l, 0)),          # B
            pl.BlockSpec((1, c, N), lambda b, h_, l: (b, l, 0)),          # C
            pl.BlockSpec((bh,), lambda b, h_, l: (h_,)),                  # A
        ],
        out_specs=[
            pl.BlockSpec((1, c, bh, P), lambda b, h_, l: (b, l, h_, 0)),
            pl.BlockSpec((1, bh, P, N), lambda b, h_, l: (b, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc * c, nh * bh, P), x.dtype),
            jax.ShapeDtypeStruct((B, nh * bh, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
    return y[:, :L, :H], h[:, :H]
