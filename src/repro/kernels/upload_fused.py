"""Fused upload-pipeline megakernel — Pallas TPU kernel.

The per-arrival upload pipeline (`fleet.stages.upload_pipeline`) used to be
a dispatch chain over the flattened (C, P) cohort: a DGC sparsify kernel per
leaf (`kernels.sparsify`), a standalone nonzero-count kernel
(`kernels.wire_bytes`) so `repro.net` can price the wire message, a jnp
norm reduction for the ALDP clip scale, and the clip+noise kernel
(`kernels.ldp_noise`) — ~9 HBM passes over the cohort plus the flatten /
concat glue between them.  This kernel fuses the whole thing into ONE pass:

  read (delta, residual) block -> combined = delta + residual
    -> keep = |combined| >= per-leaf DGC threshold       (§4.1)
    -> upload  = keep ? combined : 0;  residual' = keep ? 0 : combined
    -> nnz    += count(upload != 0)     (post-sparsify, pre-noise — the
                                         sparse coordinate set the wire
                                         codecs price)
    -> upload  = clip_scale * upload + N(0, (sigma·S)^2)  (§4.2, Eq. 10)
  write (upload, residual', nnz)

so wire-byte counting is free and noise never touches HBM.  Two reductions
stay outside by data dependency: the per-leaf quantile *threshold* needs a
sort over the whole leaf, and the clip scale needs the post-sparsify global
L2 norm before any output element — both run as one jnp pre-pass over the
`combined` cohort in `fleet.stages`.

Parity contract (tested in tests/test_upload_fused.py): bit-equal to the
unfused `sparsify_fleet` -> `nnz_fleet` -> `ldp_perturb_fleet` chain — same
block decomposition, same per-block seeding (`seed + block·7919`), same
counter-based Box–Muller streams — and float-close to the reference jnp
pipeline at sigma=0.  Like the reference pipeline, noise is applied to
*every* coordinate (the documented dense-noise simulation artifact); the
nnz output prices the intended sparse wire message.

Grid is (node, block): shard-oblivious — every output depends only on its
own node row, so the mesh engines call this inside `shard_map` unchanged.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ldp_noise import LANE, _hash_uniform


def _fused_kernel(*refs, sigma_s: float, apply_ldp: bool, do_sparsify: bool,
                  need_nnz: bool, block_rows: int,
                  boundaries: Tuple[int, ...]):
    """One (1, block_rows, LANE) block of one node through the whole upload
    pipeline.  The ref list is built to match the wrapper's dynamic
    in_specs/out_specs (features compiled out drop their refs entirely, so
    e.g. a no-noise program carries no seed/scale operands)."""
    it = iter(refs)
    thr_ref = next(it) if do_sparsify else None          # (C, L) SMEM
    seed_ref = next(it) if sigma_s > 0.0 else None       # (C,)  SMEM
    scale_ref = next(it) if apply_ldp else None          # (C,)  SMEM
    g_ref = next(it)
    r_ref = next(it) if do_sparsify else None
    up_ref = next(it)
    newr_ref = next(it) if do_sparsify else None
    nnz_ref = next(it) if need_nnz else None

    node = pl.program_id(0)
    blk = pl.program_id(1)
    g = g_ref[0].astype(jnp.float32)
    if do_sparsify:
        c = g + r_ref[0].astype(jnp.float32)
        shape = c.shape
        # per-element threshold: leaf l covers flat positions
        # boundaries[l] <= p < boundaries[l+1] (static leaf layout)
        rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
        p = (blk * block_rows + rows) * shape[1] + cols
        thr = jnp.full(shape, thr_ref[node, 0], jnp.float32)
        for leaf in range(1, len(boundaries)):
            thr = jnp.where(p >= boundaries[leaf], thr_ref[node, leaf], thr)
        keep = jnp.abs(c) >= thr
        up = jnp.where(keep, c, 0.0)
        newr_ref[0] = jnp.where(keep, 0.0, c).astype(newr_ref.dtype)
    else:
        up = g
    if need_nnz:
        cnt = jnp.sum(up != 0.0).astype(jnp.int32)
        @pl.when(blk == 0)
        def _init():
            nnz_ref[0, 0] = 0
        nnz_ref[0, 0] += cnt
    if apply_ldp:
        up = up * scale_ref[node]
        if sigma_s > 0.0:
            shape = up.shape
            blk_seed = seed_ref[node] + blk * 7919
            u1 = jnp.maximum(_hash_uniform(blk_seed, 1, shape), 1e-12)
            u2 = _hash_uniform(blk_seed, 2, shape)
            r = jnp.sqrt(-2.0 * jnp.log(u1))
            theta = (2.0 * math.pi) * u2
            up = up + sigma_s * r * jnp.cos(theta)
    up_ref[0] = up.astype(up_ref.dtype)


def _pad_cohort(a: jnp.ndarray, rows_total: int, nb: int, block_rows: int,
                cols: int) -> jnp.ndarray:
    k, n = a.shape
    x = jnp.pad(a, ((0, 0), (0, rows_total * cols - n))
                ).reshape(k, rows_total, cols)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        x = jnp.pad(x, ((0, 0), (0, pad_r), (0, 0)))
    return x


def upload_fused_fleet(flat: jnp.ndarray,
                       residuals: Optional[jnp.ndarray],
                       thresholds: Optional[jnp.ndarray],
                       seeds: Optional[jnp.ndarray],
                       clip_scales: Optional[jnp.ndarray],
                       sigma: float, clip_s: float, *,
                       boundaries: Sequence[int] = (0,),
                       need_nnz: bool = False,
                       block_rows: int = 256, interpret: bool = True):
    """Whole-cohort fused upload pipeline: one kernel launch for every
    node's sparsify + nnz + clip + noise.

    flat (C, N) stacked per-node deltas (flattened cohort layout);
    residuals (C, N) DGC residuals, or None to skip sparsification
    (ratio >= 1); thresholds (C, L) per-node per-leaf DGC cutoffs (None iff
    residuals is None); seeds (C,) int32 node-distinct noise seeds;
    clip_scales (C,) f32 = 1/max(1, ‖upload_k‖/S), or None to skip the
    ALDP stage entirely (sigma == 0 — matching the reference pipeline,
    which leaves the deltas untouched rather than clipping noiselessly);
    boundaries: static start offset of each leaf in the flat layout.

    Returns (upload (C, N), residual' (C, N) or None, nnz (C,) i32 or
    None) — bit-equal to the unfused sparsify/nnz/ldp kernel chain.
    """
    k, n = flat.shape
    cols = LANE
    rows_total = -(-n // cols)
    nb = -(-rows_total // block_rows)
    do_sparsify = residuals is not None
    apply_ldp = clip_scales is not None
    sigma_s = float(sigma) * float(clip_s) if apply_ldp else 0.0

    args, in_specs = [], []
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    blkspec = pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0))
    if do_sparsify:
        args.append(thresholds.astype(jnp.float32))
        in_specs.append(smem)
    if sigma_s > 0.0:
        args.append(seeds.astype(jnp.int32))
        in_specs.append(smem)
    if apply_ldp:
        args.append(clip_scales.astype(jnp.float32))
        in_specs.append(smem)
    x = _pad_cohort(flat, rows_total, nb, block_rows, cols)
    args.append(x)
    in_specs.append(blkspec)
    if do_sparsify:
        args.append(_pad_cohort(residuals, rows_total, nb, block_rows, cols))
        in_specs.append(blkspec)

    out_specs = [blkspec]
    out_shape = [jax.ShapeDtypeStruct(x.shape, flat.dtype)]
    if do_sparsify:
        out_specs.append(blkspec)
        out_shape.append(jax.ShapeDtypeStruct(x.shape, residuals.dtype))
    if need_nnz:
        out_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, 1), jnp.int32))

    kernel = functools.partial(
        _fused_kernel, sigma_s=sigma_s, apply_ldp=apply_ldp,
        do_sparsify=do_sparsify, need_nnz=need_nnz, block_rows=block_rows,
        boundaries=tuple(int(b) for b in boundaries))
    outs = pl.pallas_call(
        kernel, grid=(k, nb), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*args)
    outs = list(outs)
    up = outs.pop(0).reshape(k, -1)[:, :n]
    newr = outs.pop(0).reshape(k, -1)[:, :n] if do_sparsify else None
    nnz = outs.pop(0).reshape(k) if need_nnz else None
    return up, newr, nnz


# ---------------------------------------------------------------------------
# jnp mirror — the interpret-mode-safe fallback and the parity oracle
# ---------------------------------------------------------------------------

def block_noise(k: int, n: int, seeds: jnp.ndarray, sigma_s: float, *,
                block_rows: int = 256) -> jnp.ndarray:
    """The kernel's per-block counter-based Box–Muller noise, vectorized in
    plain jnp over the same padded (rows, LANE) layout: element e of block b
    of node i draws from hash(seeds[i] + b·7919, stream, e) exactly as the
    in-kernel generator does.  Returns the (k, n) noise the kernel adds."""
    cols = LANE
    rows_total = -(-n // cols)
    r = jnp.arange(rows_total, dtype=jnp.int32)
    blk = r // block_rows
    in_blk = (r % block_rows).astype(jnp.uint32)
    col = jnp.arange(cols, dtype=jnp.uint32)
    # in-block element index, matching the kernel's broadcasted_iota layout
    x_idx = in_blk[:, None] * jnp.uint32(cols) + col[None, :]
    blk_seed = (seeds.astype(jnp.int32)[:, None, None]
                + blk[None, :, None] * 7919)

    def hash_u(stream: int) -> jnp.ndarray:
        x = x_idx[None] + blk_seed.astype(jnp.uint32) * jnp.uint32(2654435761)
        x = x + jnp.uint32((stream * 0x9E3779B9) & 0xFFFFFFFF)
        x = x ^ (x >> 16)
        x = x * jnp.uint32(0x7FEB352D)
        x = x ^ (x >> 15)
        x = x * jnp.uint32(0x846CA68B)
        x = x ^ (x >> 16)
        return (x >> 8).astype(jnp.float32) / jnp.float32(1 << 24)

    u1 = jnp.maximum(hash_u(1), 1e-12)
    u2 = hash_u(2)
    noise = sigma_s * jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(
        (2.0 * math.pi) * u2)
    return noise.reshape(k, -1)[:, :n]


def spread_thresholds(thresholds: jnp.ndarray, boundaries: Sequence[int],
                      n: int) -> jnp.ndarray:
    """(C, L) per-leaf thresholds -> (C, N) per-element thresholds under the
    static leaf layout `boundaries` (start offsets, leaf L ends at n)."""
    ends = list(boundaries[1:]) + [n]
    return jnp.concatenate(
        [jnp.broadcast_to(thresholds[:, i:i + 1],
                          (thresholds.shape[0], ends[i] - int(b)))
         for i, b in enumerate(boundaries)], axis=1)


def upload_fused_reference(flat, residuals, thresholds, seeds, clip_scales,
                           sigma: float, clip_s: float, *,
                           boundaries: Sequence[int] = (0,),
                           need_nnz: bool = False, block_rows: int = 256):
    """Pure-jnp mirror of `upload_fused_fleet` — same signature and the
    same noise (replaying the kernel's blockwise hash streams bit-exactly).
    Sparsify/nnz outputs are bit-equal; the noised upload may differ by
    ~1 ulp where XLA contracts the kernel's scale-multiply + noise-add
    into an FMA."""
    k, n = flat.shape
    g = flat.astype(jnp.float32)
    newr = None
    if residuals is not None:
        c = g + residuals.astype(jnp.float32)
        keep = jnp.abs(c) >= spread_thresholds(thresholds, boundaries, n)
        up = jnp.where(keep, c, 0.0)
        newr = jnp.where(keep, 0.0, c).astype(residuals.dtype)
    else:
        up = g
    nnz = jnp.sum(up != 0.0, axis=1).astype(jnp.int32) if need_nnz else None
    if clip_scales is not None:
        up = up * clip_scales.astype(jnp.float32)[:, None]
        sigma_s = float(sigma) * float(clip_s)
        if sigma_s > 0.0:
            up = up + block_noise(k, n, seeds, sigma_s,
                                  block_rows=block_rows)
    return up.astype(flat.dtype), newr, nnz
