"""Fused DGC sparsify + residual accumulate — Pallas TPU kernel.

The paper's gradient-accumulation container (§5.1): combined = residual + g;
elements with |combined| >= threshold are uploaded, the rest stay in the
residual. Naively that is 4 HBM passes (add, compare, two selects); the
kernel does one read of (g, residual) and one write of (upload, residual').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024


def _kernel(thr_ref, g_ref, r_ref, up_ref, newr_ref):
    c = g_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    keep = jnp.abs(c) >= thr_ref[0]
    up_ref[...] = jnp.where(keep, c, 0.0).astype(up_ref.dtype)
    newr_ref[...] = jnp.where(keep, 0.0, c).astype(newr_ref.dtype)


def _fleet_kernel(thr_ref, g_ref, r_ref, up_ref, newr_ref):
    """Node-batched variant: grid (node, block); per-node threshold in SMEM."""
    node = pl.program_id(0)
    c = g_ref[0].astype(jnp.float32) + r_ref[0].astype(jnp.float32)
    keep = jnp.abs(c) >= thr_ref[node]
    up_ref[0] = jnp.where(keep, c, 0.0).astype(up_ref.dtype)
    newr_ref[0] = jnp.where(keep, 0.0, c).astype(newr_ref.dtype)


def sparsify_flat(grad: jnp.ndarray, residual: jnp.ndarray,
                  threshold: jnp.ndarray, *, block_rows: int = 256,
                  interpret: bool = True):
    """grad, residual (N,); threshold () f32 -> (upload (N,), residual' (N,))."""
    n = grad.shape[0]
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    g = jnp.pad(grad, (0, pad)).reshape(rows_total, cols)
    r = jnp.pad(residual, (0, pad)).reshape(rows_total, cols)
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        g = jnp.pad(g, ((0, pad_r), (0, 0)))
        r = jnp.pad(r, ((0, pad_r), (0, 0)))

    up, newr = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(g.shape, grad.dtype),
                   jax.ShapeDtypeStruct(g.shape, residual.dtype)],
        interpret=interpret,
    )(threshold.reshape(1).astype(jnp.float32), g, r)
    return up.reshape(-1)[:n], newr.reshape(-1)[:n]


def sparsify_fleet(grads: jnp.ndarray, residuals: jnp.ndarray,
                   thresholds: jnp.ndarray, *, block_rows: int = 256,
                   interpret: bool = True):
    """Whole-cohort DGC pass: one kernel launch for every node's upload split.

    grads, residuals (K, N); thresholds (K,) f32 — per-node magnitude cutoffs.
    Returns (uploads (K, N), residuals' (K, N)). Grid is (node, block) so the
    cohort shares a single device program instead of K dispatches.
    """
    k, n = grads.shape
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    g = jnp.pad(grads, ((0, 0), (0, pad))).reshape(k, rows_total, cols)
    r = jnp.pad(residuals, ((0, 0), (0, pad))).reshape(k, rows_total, cols)
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        g = jnp.pad(g, ((0, 0), (0, pad_r), (0, 0)))
        r = jnp.pad(r, ((0, 0), (0, pad_r), (0, 0)))

    up, newr = pl.pallas_call(
        _fleet_kernel,
        grid=(k, nb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(g.shape, grads.dtype),
                   jax.ShapeDtypeStruct(g.shape, residuals.dtype)],
        interpret=interpret,
    )(thresholds.astype(jnp.float32), g, r)
    return (up.reshape(k, -1)[:, :n], newr.reshape(k, -1)[:, :n])
