"""Node-batched wire accounting — Pallas TPU kernel.

`repro.net` prices every upload from its nonzero count (sparse codecs
encode exactly the nonzero coordinates).  Counting nonzeros over a stacked
(K, P) cohort naively reads the whole cohort once per reduction step; this
kernel mirrors the `sparsify.py` fleet idiom — grid (node, block), one
VMEM pass per block — and accumulates each node's count into a revisited
(K, 1) output block, so the whole cohort is priced in a single launch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024


def _fleet_kernel(g_ref, out_ref):
    """Grid (node, block): out[node] accumulates the block's nonzero count
    (zero padding contributes nothing by construction)."""
    blk = pl.program_id(1)
    cnt = jnp.sum(g_ref[0] != 0.0).astype(jnp.int32)

    @pl.when(blk == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    out_ref[0, 0] = out_ref[0, 0] + cnt


def nnz_fleet(flat: jnp.ndarray, *, block_rows: int = 256,
              interpret: bool = True) -> jnp.ndarray:
    """Per-node nonzero counts of a stacked cohort in one kernel launch.

    flat (K, N) — stacked flattened uploads.  Returns (K,) int32.
    """
    k, n = flat.shape
    cols = LANE
    rows_total = -(-n // cols)
    pad = rows_total * cols - n
    g = jnp.pad(flat, ((0, 0), (0, pad))).reshape(k, rows_total, cols)
    nb = -(-rows_total // block_rows)
    pad_r = nb * block_rows - rows_total
    if pad_r:
        g = jnp.pad(g, ((0, 0), (0, pad_r), (0, 0)))

    out = pl.pallas_call(
        _fleet_kernel,
        grid=(k, nb),
        in_specs=[
            pl.BlockSpec((1, block_rows, cols), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.int32),
        interpret=interpret,
    )(g)
    return out.reshape(k)
