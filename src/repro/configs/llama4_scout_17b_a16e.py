"""Llama-4-Scout-17B-16E backbone. 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192, vocab=202048, MoE 16 experts top-1 (+shared), early fusion
(multimodal embeddings stubbed). [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
        moe=MoEConfig(n_experts=16, top_k=1, d_expert=8192, n_shared=1),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        # capacity_factor 8: at smoke scale (T=32, E=4) a factor-2 capacity
        # sits at the dropping edge, and capacity drops are batch-context
        # dependent — they break prefill/decode vs full-forward equivalence
        moe=MoEConfig(n_experts=4, top_k=1, d_expert=256, n_shared=1,
                      capacity_factor=8.0),
        remat=False,
    )
