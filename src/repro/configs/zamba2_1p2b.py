"""Zamba2-1.2B. 38 Mamba2 blocks d_model=2048 with a SHARED full-attention
block (32H, kv=32, d_ff=8192) applied every 6 layers; ssm_state=64.
[arXiv:2411.15242]
"""
from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000,
        ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, chunk=128),
        attn_every=6,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
        ssm=SSMConfig(kind="mamba2", d_state=16, head_dim=32, chunk=8),
        attn_every=2, remat=False,
    )
