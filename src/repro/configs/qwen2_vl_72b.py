"""Qwen2-VL-72B backbone. 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution (vision encoder STUBBED as
precomputed patch embeddings). [arXiv:2409.12191]
"""
from ..models.config import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
        rope_mode="mrope", n_patches=1024, patch_grid=(32, 32),
        qkv_bias=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=512, vocab=512,
        rope_mode="mrope", n_patches=16, patch_grid=(4, 4), qkv_bias=True,
        remat=False,
    )
