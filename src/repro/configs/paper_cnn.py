"""The paper's own experimental model: CNN (2 conv + 1 FC) on MNIST/CIFAR-
shaped data, 10 edge nodes (3 malicious), lr=0.001, B=128 (paper §6.1)."""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class PaperCNNConfig:
    dataset: str = "mnist"       # "mnist" (28x28x1) | "cifar" (32x32x3)
    n_nodes: int = 10
    n_malicious: int = 3
    lr: float = 1e-3
    batch_size: int = 128
    flip_src: int = 1            # MNIST '1' -> '7'
    flip_dst: int = 7
    epsilon: float = 8.0
    delta: float = 1e-3
    alpha: float = 0.5
    detect_s: float = 80.0

    @property
    def hw(self) -> Tuple[int, int]:
        return (28, 28) if self.dataset == "mnist" else (32, 32)

    @property
    def channels(self) -> int:
        return 1 if self.dataset == "mnist" else 3


def config() -> PaperCNNConfig:
    return PaperCNNConfig()
