"""Qwen1.5-0.5B. 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B]
"""
from ..models.config import ModelConfig

ARCH_ID = "qwen1.5-0.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936, qkv_bias=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512, qkv_bias=True,
        remat=False,
    )
