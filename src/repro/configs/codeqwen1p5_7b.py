"""CodeQwen1.5-7B. 32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416,
qwen1.5 arch (QKV bias). [hf:Qwen/CodeQwen1.5-7B]
"""
from ..models.config import ModelConfig

ARCH_ID = "codeqwen1.5-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=13440, vocab=92416, qkv_bias=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512, qkv_bias=True,
        remat=False,
    )
