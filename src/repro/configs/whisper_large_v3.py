"""Whisper-large-v3 backbone. 32L enc + 32L dec, d_model=1280 20H d_ff=5120
vocab=51866 — encoder-decoder; mel+conv frontend STUBBED as 1500 precomputed
frame embeddings. LayerNorm + GELU per the original. [arXiv:2212.04356]
"""
from ..models.config import ModelConfig

ARCH_ID = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio", n_layers=32, encoder_layers=32,
        d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
        norm="layernorm", mlp="gelu", n_audio_frames=1500, qkv_bias=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio", n_layers=2, encoder_layers=2,
        d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
        norm="layernorm", mlp="gelu", n_audio_frames=24, qkv_bias=True,
        remat=False,
    )
