"""SmolLM-360M. 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152 —
llama-arch small. [hf:HuggingFaceTB/SmolLM-360M]
"""
from ..models.config import ModelConfig

ARCH_ID = "smollm-360m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=32, d_model=960,
        n_heads=15, n_kv_heads=5, d_ff=2560, vocab=49152,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=240,
        n_heads=3, n_kv_heads=1, d_ff=512, vocab=512, remat=False,
    )
