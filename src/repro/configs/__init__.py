from .registry import (ARCH_IDS, get_config, get_smoke_config,   # noqa: F401
                        long_context_variant)
