"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from ..models.config import ModelConfig
from . import (codeqwen1p5_7b, falcon_mamba_7b, kimi_k2_1t_a32b,
               llama4_scout_17b_a16e, olmo_1b, qwen1p5_0p5b, qwen2_vl_72b,
               smollm_360m, whisper_large_v3, zamba2_1p2b)

_MODULES = {
    m.ARCH_ID: m for m in (
        kimi_k2_1t_a32b, qwen2_vl_72b, zamba2_1p2b, qwen1p5_0p5b,
        whisper_large_v3, codeqwen1p5_7b, llama4_scout_17b_a16e,
        falcon_mamba_7b, olmo_1b, smollm_360m)
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """The sub-quadratic variant used for the long_500k shape.

    SSM/hybrid archs are already O(1)-state in decode; attention archs get a
    sliding window (ring-buffer KV cache of ``window`` tokens). Hybrid archs
    additionally window their shared attention block.
    """
    if cfg.family == "ssm":
        return cfg
    return cfg.replace(sliding_window=window)
