"""Kimi K2 — trillion-param MoE. 61L d_model=7168 64H (GQA kv=8) expert
d_ff=2048, vocab=163840, MoE 384 experts top-8 (+1 shared). [arXiv:2501.kimi2]
"""
from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, n_shared=1,
                      capacity_factor=2.0),
        remat=False,
    )
