"""OLMo-1B. 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304 —
non-parametric LayerNorm. [arXiv:2402.00838]
"""
from ..models.config import ModelConfig

ARCH_ID = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304,
        norm="nonparam_ln",
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512, norm="nonparam_ln",
        remat=False,
    )
