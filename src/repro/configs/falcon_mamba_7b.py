"""Falcon-Mamba-7B. 64L d_model=4096 attention-free Mamba1, ssm_state=16,
vocab=65024. [arXiv:2410.05355]
"""
from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "falcon-mamba-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm", n_layers=64, d_model=4096,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024, rope_mode="none",
        # chunk=512 from the §Perf sweep: per-chunk loop overheads amortise
        # (memory term 131s -> 88s vs chunk=128); <6% beyond 512. bf16 scan
        # elements halve scan traffic at 0.13% relative logit error.
        ssm=SSMConfig(kind="mamba1", d_state=16, chunk=512,
                      scan_dtype="bfloat16"),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm", n_layers=2, d_model=256,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=512, rope_mode="none",
        ssm=SSMConfig(kind="mamba1", d_state=8, chunk=8), remat=False,
    )
