"""The always-on simulation service: a compiled `ExperimentPlan` driven
one record at a time, with checkpoint/resume, traffic-trace modulation,
and mid-run `SimEvent` spec mutation.

`api.run` executes a plan as a batch: build a stepper, drain it, report.
`SimService` owns the same stepper but stays in the loop between records:

  * **checkpoint/resume** — `checkpoint()` snapshots the *complete* run
    state (stepper arrays + loop metadata, record history, accountant,
    sampler RNG, membership, the current — possibly mutated — spec)
    through `repro.checkpointing`; `SimService.resume(path)` rebuilds the
    service and continues bit-exactly: the resumed trajectory equals the
    uninterrupted one record for record.  Snapshots are only taken at
    record boundaries, where every span accumulator is exactly zero.
  * **traffic traces** — before each engine dispatch the service
    evaluates `SimSpec.traces` at the stepper's virtual time and installs
    the result: per-node rate scales on ``NetSim.rate_scale``,
    availability on the `DynamicSampler` it wraps around the population's
    sampler.  Traces are pure in virtual time, so they need no state in
    the checkpoint.
  * **spec mutation** — `SimSpec.events` fire between records: the
    service exports the stepper's state, applies the event to the spec
    (`api.apply_sim_event`), recompiles, rebuilds the stepper for the new
    plan, and restores the exported state into it.  Node join/leave
    events just edit the membership mask.  Attack onset/offset events
    rematerialize the population (malicious shards are spec-derived), so
    they require the default spec-materialized population.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

from ..api.plan import ExperimentPlan, compile_plan
from ..api.population import materialize
from ..api.report import RoundRecord, RunReport, detection_log
from ..api.run import _ObsSession, init_state, make_stepper
from ..api.spec import ExperimentSpec, SimSpec, apply_sim_event
from ..checkpointing import load_checkpoint, read_manifest, save_checkpoint
from ..core import async_update
from .traffic import DynamicSampler, modulation


def _record_to_json(r: RoundRecord) -> dict:
    """A RoundRecord as JSON-native scalars (numpy floats don't dump).
    json round-trips floats exactly (repr-based), so replayed histories
    stay bit-equal to the uninterrupted run's."""
    return {"t": float(r.t), "version": int(r.version),
            "accuracy": float(r.accuracy), "comm_bytes": float(r.comm_bytes),
            "comp_time": float(r.comp_time), "comm_time": float(r.comm_time),
            "n_rejected": int(r.n_rejected), "bytes_source": r.bytes_source}


class SimService:
    """Drive one experiment as a long-running, interruptible simulation.

    Args:
      plan_or_spec: a compiled `ExperimentPlan` or an `ExperimentSpec`
        (compiled here).  The spec's `SimSpec` (``spec.sim``) supplies the
        traces/events/checkpoint policy; a plan without one runs with an
        empty `SimSpec` — bit-identical to `api.run`.
      population: an explicit population (defaults to the spec-derived
        synthetic fleet).  Incompatible with attack events, which must
        rematerialize the population mid-run.
      sampler: overrides the population's participation model.
      checkpoint_dir / checkpoint_every: override the `SimSpec` policy.
    """

    def __init__(self, plan_or_spec, *, population=None, sampler=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: Optional[int] = None):
        plan = (plan_or_spec if isinstance(plan_or_spec, ExperimentPlan)
                else compile_plan(plan_or_spec))
        spec = plan.spec
        sim = spec.sim if spec.sim is not None else SimSpec()
        if population is not None and any(e.kind == "attack"
                                          for e in sim.events):
            raise ValueError(
                "SimService: attack SimEvents rematerialize the population "
                "(malicious shards are spec-derived) and so require the "
                "default spec-materialized population, not an external one")
        self.plan = plan
        self.spec = spec            # mutates as events apply
        self.base_spec = spec       # what the final report is labelled with
        self.checkpoint_dir = (checkpoint_dir if checkpoint_dir is not None
                               else sim.checkpoint_dir)
        self.checkpoint_every = (checkpoint_every if checkpoint_every
                                 is not None else sim.checkpoint_every)
        if self.checkpoint_every and self.checkpoint_dir is None:
            raise ValueError("SimService: checkpoint_every > 0 needs a "
                             "checkpoint_dir")
        self._external_pop = population is not None
        self._ext_sampler = sampler
        self.records_done = 0
        self.event_cursor = 0
        self.resumed_from: Optional[str] = None
        self.resume_round: Optional[int] = None
        self._finalized = False
        self._session_done = False
        self._final_report: Optional[RunReport] = None

        pop = population if population is not None else materialize(spec)
        if sampler is not None:
            pop = dataclasses.replace(pop, sampler=sampler)
        self.n_nodes = pop.n_nodes
        self.membership = np.ones(pop.n_nodes, bool)
        # availability indirection: traces and node join/leave flow through
        # this sampler; with no traces/events it reproduces the wrapped
        # sampler (or FullParticipation) exactly
        self.dyn = DynamicSampler(pop.n_nodes, inner=pop.sampler)
        pop = dataclasses.replace(pop, sampler=self.dyn)
        self.pop = pop
        self.state = init_state(plan, pop)
        self.session = _ObsSession(plan)
        streamed = self.session.history()
        if streamed is not None:
            self.state.history = streamed
        with self.session.scope():   # engines bind the tracer at build time
            self.stepper = make_stepper(plan, pop, self.state)
        self.stepper.pre_step = self._pre_dispatch

    # -- driving -------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.stepper.done

    def virtual_time(self) -> float:
        return self.stepper.virtual_time()

    def step(self) -> None:
        """Advance the run by exactly one `RoundRecord`: fire due events,
        dispatch, heartbeat, auto-checkpoint."""
        if self.stepper.done:
            raise RuntimeError("SimService.step: run already complete")
        self._apply_due_events()
        with self.session.scope():
            self.stepper.step()
        self.records_done += 1
        tr = self.session.tracer
        if tr is not None and tr.enabled:
            rec = self.state.history[-1]
            tr.metrics.counter("sim.records").inc()
            tr.instant("sim.heartbeat", round=self.records_done,
                       t=float(rec.t), accuracy=float(rec.accuracy))
        if (self.checkpoint_every
                and self.records_done % self.checkpoint_every == 0):
            self.checkpoint()

    def run(self, max_records: Optional[int] = None) -> RunReport:
        """Drain the run (or ``max_records`` more records) and report.
        A full drain finalizes and closes the obs session; a partial one
        returns an interim report and leaves the service live."""
        end = (None if max_records is None
               else self.records_done + max_records)
        try:
            while not self.stepper.done and (end is None
                                             or self.records_done < end):
                self.step()
        except BaseException:
            if not self._session_done:
                self._session_done = True
                self.session.finish(None)
            raise
        if self.stepper.done:
            return self.finish()
        return self.report()

    def finish(self) -> RunReport:
        """Finalize: hand engine state back, build the report, flush obs."""
        if self._final_report is None:
            if not self._finalized:
                self.stepper.finalize()
                self._finalized = True
            report = self.report()
            if not self._session_done:
                self._session_done = True
                self.session.finish(report)
            self._final_report = report
        return self._final_report

    def report(self) -> RunReport:
        """The run so far as a `RunReport` (the batch `api.run` schema,
        plus resume provenance)."""
        records = list(self.state.history)
        comm = sum(r.comm_time for r in records)
        comp = sum(r.comp_time for r in records)
        net = self.state.net
        if net is None and self.stepper.net is not None:
            net = self.stepper.net.summary()
        engine_name = ("fleet-mesh" if self.plan.mesh_devices is not None
                       else self.plan.engine)
        acct = self.state.accountant
        return RunReport(
            mode=self.plan.mode, engine=engine_name, records=records,
            kappa=async_update.communication_efficiency(comm, comp),
            epsilon_spent=(acct.epsilon(self.spec.privacy.delta)
                           if acct is not None else 0.0),
            final_accuracy=records[-1].accuracy if records else 0.0,
            detections=detection_log(records),
            spec=self.base_spec.to_dict(),
            net=net,
            resumed_from=self.resumed_from,
            resume_round=self.resume_round,
            final_params=self.state.params)

    # -- traffic modulation (pre-dispatch hook on the stepper) ---------------
    def _pre_dispatch(self, stepper) -> None:
        sim = self.spec.sim
        traces = sim.traces if sim is not None else ()
        up = self.membership
        scale = None
        if traces:
            scale, trace_up = modulation(traces, self.n_nodes,
                                         stepper.virtual_time())
            up = up & trace_up
        if not up.any():
            # a sync barrier round over zero nodes would average nothing
            # (and an async window would churn every slot): degrade to the
            # membership mask instead of starving the fleet entirely
            up = self.membership
            tr = self.session.tracer
            if tr is not None and tr.enabled:
                tr.metrics.counter("sim.forced_up").inc()
        self.dyn.up = up
        net = stepper.net
        if net is not None:
            net.rate_scale = scale
        # fleet-health probes ride the same between-records seam: they
        # read the session's streaming analytics and emit health.alert/
        # health.incident events (no-op without an ObsSpec.health axis)
        self.session.poll_health(stepper.virtual_time(), self.records_done)

    # -- SimEvent timeline ---------------------------------------------------
    def _apply_due_events(self) -> None:
        sim = self.spec.sim
        if sim is None:
            return
        events = sim.events
        while (self.event_cursor < len(events)
               and events[self.event_cursor].at_round <= self.records_done):
            ev = events[self.event_cursor]
            self.event_cursor += 1
            tr = self.session.tracer
            if tr is not None and tr.enabled:
                tr.instant("sim.event", kind=ev.kind,
                           at_round=int(ev.at_round), payload=dict(ev.payload))
                tr.metrics.counter("sim.events").inc()
            if ev.kind == "nodes":
                self._apply_membership(ev)
            else:
                self._rebuild(apply_sim_event(self.spec, ev))

    def _apply_membership(self, ev) -> None:
        for node in ev.payload.get("leave", ()):
            self.membership[int(node)] = False
        for node in ev.payload.get("join", ()):
            self.membership[int(node)] = True
        # keep the spec timeline consistent for checkpoints: the manifest
        # stores the mutated spec + the event cursor, so replayed events
        # are exactly the not-yet-applied suffix
        self.spec = apply_sim_event(self.spec, ev)

    def _rebuild(self, new_spec: ExperimentSpec) -> None:
        """Swap the stepper for one compiled from ``new_spec``, carrying
        the full run state across (`compile_plan` already validated every
        event's cumulative spec)."""
        arrays, smeta = self.stepper.export_state()
        plan = compile_plan(new_spec)
        if self._external_pop:
            pop = self.pop      # ctor forbids attack events for this case
        else:
            # rematerialize: attack events change which shards are poisoned.
            # The DynamicSampler (and its wrapped sampler's advanced RNG)
            # carries over — events cannot change the participation model.
            base = materialize(new_spec)
            if self._ext_sampler is not None:
                base = dataclasses.replace(base, sampler=self._ext_sampler)
            pop = dataclasses.replace(base, sampler=self.dyn)
        self.plan, self.spec, self.pop = plan, new_spec, pop
        with self.session.scope():
            self.stepper = make_stepper(plan, pop, self.state)
            self.stepper.restore_state(arrays, smeta)
        self.stepper.pre_step = self._pre_dispatch

    # -- checkpoint/resume ---------------------------------------------------
    def checkpoint(self, path: Optional[str] = None) -> str:
        """Snapshot the complete run state at the current record boundary.
        Returns the checkpoint base path (``<base>.npz`` + ``<base>.json``)."""
        if path is None:
            if self.checkpoint_dir is None:
                raise ValueError("SimService.checkpoint: no path given and "
                                 "no checkpoint_dir configured")
            path = os.path.join(self.checkpoint_dir,
                                f"ckpt_{self.records_done:06d}")
        arrays, smeta = self.stepper.export_state()
        tree = {"stepper": arrays,
                "membership": np.asarray(self.membership, bool)}
        extra = {
            "sim_checkpoint": 1,
            "spec": self.spec.to_dict(),
            "base_spec": self.base_spec.to_dict(),
            "records_done": int(self.records_done),
            "event_cursor": int(self.event_cursor),
            "stepper": smeta,
            "history": [_record_to_json(r) for r in self.state.history],
            "resumed_from": self.resumed_from,
            "resume_round": self.resume_round,
        }
        acct = self.state.accountant
        if acct is not None:
            # the RDP vector is accumulated by repeated adds — snapshot the
            # array itself, not steps*increment (bitwise != in general)
            tree["accountant_rdp"] = np.asarray(acct._rdp, np.float64)
            extra["accountant_steps"] = int(acct.steps)
        inner = self.dyn.inner
        if inner is not None and hasattr(inner, "rng"):
            extra["sampler_rng"] = inner.rng.bit_generator.state
        save_checkpoint(path, tree, step=self.records_done, extra=extra)
        tr = self.session.tracer
        if tr is not None and tr.enabled:
            tr.instant("sim.checkpoint", round=int(self.records_done),
                       path=path)
            tr.metrics.counter("sim.checkpoints").inc()
        return path

    @classmethod
    def resume(cls, path: str, *, population=None, sampler=None,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: Optional[int] = None) -> "SimService":
        """Rebuild a service from a `checkpoint()` snapshot and continue
        bit-exactly.  The manifest carries the spec as mutated by every
        event already applied, so the rebuilt plan matches the snapshot's
        shapes; the event cursor skips the applied prefix."""
        meta = read_manifest(path).get("extra", {})
        if not meta.get("sim_checkpoint"):
            raise ValueError(f"{path!r} is not a SimService checkpoint "
                             "(missing sim manifest metadata)")
        spec = ExperimentSpec.from_dict(meta["spec"])
        svc = cls(compile_plan(spec), population=population, sampler=sampler,
                  checkpoint_dir=checkpoint_dir,
                  checkpoint_every=checkpoint_every)
        # template tree from the freshly-built service (same spec => same
        # structure/shapes/dtypes), then overwrite from the snapshot
        like_arrays, _ = svc.stepper.export_state()
        like = {"stepper": like_arrays,
                "membership": np.asarray(svc.membership, bool)}
        if svc.state.accountant is not None:
            like["accountant_rdp"] = np.zeros_like(
                svc.state.accountant._rdp)
        tree, _step = load_checkpoint(path, like)
        svc.stepper.restore_state(tree["stepper"], meta["stepper"])
        svc.membership = np.asarray(tree["membership"], bool)
        if svc.state.accountant is not None and "accountant_rdp" in tree:
            svc.state.accountant._rdp = np.asarray(tree["accountant_rdp"],
                                                   np.float64)
            svc.state.accountant.steps = int(meta.get("accountant_steps", 0))
        # replay the record history through the (possibly streaming) list:
        # the obs records_jsonl stream is rebuilt record for record
        history: List[RoundRecord] = [RoundRecord(**r)
                                      for r in meta.get("history", [])]
        svc.state.history.clear()
        for rec in history:
            svc.state.history.append(rec)
        inner = svc.dyn.inner
        rng_state = meta.get("sampler_rng")
        if rng_state is not None and inner is not None \
                and hasattr(inner, "rng"):
            inner.rng.bit_generator.state = rng_state
        svc.records_done = int(meta["records_done"])
        svc.event_cursor = int(meta["event_cursor"])
        svc.base_spec = ExperimentSpec.from_dict(meta["base_spec"])
        svc.resumed_from = path
        svc.resume_round = svc.records_done
        tr = svc.session.tracer
        if tr is not None and tr.enabled:
            tr.instant("sim.resume", round=svc.records_done, path=path)
        return svc
