"""repro.sim — the always-on simulation service.

Checkpoint/resume, time-varying traffic traces, and mid-run spec
mutation over the record steppers `api.run` executes in batch.  Declare
the behaviour on ``ExperimentSpec.sim`` (an `api.SimSpec`) and `api.run`
routes through `SimService` automatically; or drive a service directly
for kill/resume control.
"""
from .service import SimService  # noqa: F401
from .traffic import DynamicSampler, modulation, region_mask  # noqa: F401
