"""Time-varying traffic: trace evaluation + the membership-aware sampler.

A `TrafficTrace` (declared on `api.SimSpec`) is a *pure function of
virtual time*: given the trace tuple and a time ``t``, `modulation`
returns the per-node link-rate scale and availability mask in effect.
Purity is the resume contract — a checkpoint restore recomputes the
identical modulation from the restored clocks, no trace state needs
saving.

The service feeds the results into two hooks:

  * the rate scale lands on ``NetSim.rate_scale`` (throttling the
    effective uplink bandwidth of every subsequent link draw);
  * the availability mask lands on a `DynamicSampler` wrapped around the
    population's declared sampler, so regional outages and `SimEvent`
    membership churn drop nodes from sync cohorts / discard their async
    arrivals through the exact same churn path `fleet.AvailabilityTrace`
    uses.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

from ..fleet import ClientSampler


def region_mask(n_nodes: int, node_frac: float,
                region_start: float) -> np.ndarray:
    """The contiguous (wrapping) regional node block a trace affects."""
    count = max(1, int(round(node_frac * n_nodes)))
    count = min(count, n_nodes)
    start = int(math.floor(region_start * n_nodes)) % n_nodes
    idx = (start + np.arange(count)) % n_nodes
    mask = np.zeros(n_nodes, bool)
    mask[idx] = True
    return mask


def _in_epoch(trace, t: float) -> bool:
    return trace.t_start <= t < trace.t_start + trace.duration_s


def modulation(traces: Sequence, n_nodes: int, t: float
               ) -> Tuple[Optional[np.ndarray], np.ndarray]:
    """(rate_scale, up) at virtual time ``t``.

    ``rate_scale`` is a per-node multiplier in (0, 1] — None when no
    bandwidth trace is active (the stationary fast path).  ``up`` is the
    per-node availability mask (False inside an outage epoch's region).
    Bandwidth traces compose multiplicatively; availability conjunctively.
    """
    scale: Optional[np.ndarray] = None
    up = np.ones(n_nodes, bool)
    for trc in traces:
        if trc.kind == "diurnal":
            phase = 2.0 * math.pi * (t - trc.phase_s) / trc.period_s
            s = 1.0 - trc.amplitude * (0.5 + 0.5 * math.sin(phase))
            if scale is None:
                scale = np.ones(n_nodes, np.float64)
            scale *= s
        elif trc.kind == "flash_crowd":
            if _in_epoch(trc, t):
                if scale is None:
                    scale = np.ones(n_nodes, np.float64)
                mask = region_mask(n_nodes, trc.node_frac, trc.region_start)
                scale[mask] *= (1.0 - trc.amplitude)
        elif trc.kind == "outage":
            if _in_epoch(trc, t):
                up &= ~region_mask(n_nodes, trc.node_frac, trc.region_start)
        else:   # compile_plan validates kinds; guard direct callers
            raise ValueError(f"unknown TrafficTrace kind {trc.kind!r}")
    return scale, up


class DynamicSampler(ClientSampler):
    """A `ClientSampler` whose availability is set from outside per
    round/window: the service intersects the wrapped sampler's cohort with
    the current trace/membership ``up`` mask.  With ``inner=None`` and a
    full mask this is exactly `FullParticipation` (same (idx, valid)
    arrays), so attaching the service to a plain spec changes nothing.
    """

    def __init__(self, n_nodes: int, inner: Optional[ClientSampler] = None):
        self.inner = inner
        self.up = np.ones(n_nodes, bool)

    def cohort(self, round_idx, n_nodes):
        if self.inner is None:
            idx = np.arange(n_nodes)
            valid = np.ones(n_nodes, bool)
        else:
            idx, valid = self.inner.cohort(round_idx, n_nodes)
        idx = np.asarray(idx)
        return idx, np.asarray(valid, bool) & self.up[idx]
