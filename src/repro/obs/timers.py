"""Host-side stage timers + the kernels profiling mode.

JAX dispatch is asynchronous: `time.perf_counter` around a jitted call
measures dispatch latency, not execution.  Everything here is
`block_until_ready`-fenced:

  * `timed_stage(tracer, name)` — a span context for one pipeline stage
    (select_window, the device program, net draw/commit, evaluation).
    The caller fences the stage's outputs via ``st.fence(out)`` before
    the context exits, so the span's wall duration covers the device
    work.  A disabled tracer yields a no-op context whose `fence` does
    nothing — untimed runs keep JAX's async pipelining (fencing an
    async dispatch chain would serialize it, which is itself a perf
    change; that is why timing is opt-in per run, never ambient).
  * `bench_kernel(name, fn, *args)` — the microbenchmark primitive
    `benchmarks/kernels_micro.py` consumes: warmup + fenced timing loop,
    µs/call, and a counter event + histogram sample into the tracer so a
    profiling run of the kernel suite lands in the same trace/metrics
    stream as everything else (the measurement harness the Pallas
    upload-pipeline megakernel work will argue from).

`fence` accepts any pytree (jax arrays, tuples, dicts) and tolerates
plain host values, so call sites don't special-case output shapes.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from .events import Tracer, get_tracer
from .metrics import SECONDS_EDGES


def fence(x: Any) -> Any:
    """Block until every jax array in ``x`` has materialized; host values
    pass through untouched."""
    import jax
    return jax.block_until_ready(x)


class _TimedStage:
    """Open stage timer: `fence` outputs inside, span emitted at exit."""
    __slots__ = ("_span", "_tracer", "_name")

    def __init__(self, tracer: Tracer, name: str, virt_t, tags):
        self._tracer = tracer
        self._name = name
        self._span = tracer.span(f"stage.{name}", virt_t=virt_t, **tags)

    def fence(self, x: Any) -> Any:
        return fence(x)

    def set(self, **tags) -> None:
        self._span.set(**tags)

    def __enter__(self):
        self._span.__enter__()
        return self

    def __exit__(self, *exc):
        out = self._span.__exit__(*exc)
        return out


class _NullStage:
    """Disabled-path stage: no clock reads, `fence` is identity (keeps
    JAX async pipelining untouched)."""
    __slots__ = ()

    def fence(self, x: Any) -> Any:
        return x

    def set(self, **tags) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_STAGE = _NullStage()


def timed_stage(tracer: Optional[Tracer], name: str,
                virt_t: Optional[float] = None, **tags):
    """Span context for one host-observed pipeline stage.

        with timed_stage(self.obs, "window.device", window=w) as st:
            out = self._window_fn(...)
            st.fence(out)           # block_until_ready before the clock stops
    """
    tracer = tracer if tracer is not None else get_tracer()
    if not (tracer.enabled and tracer.stage_timings):
        return _NULL_STAGE
    return _TimedStage(tracer, name, virt_t, tags)


# ---------------------------------------------------------------------------
# kernels profiling mode
# ---------------------------------------------------------------------------

def bench_kernel(name: str, fn, *args, iters: int = 3, warmup: int = 1,
                 tracer: Optional[Tracer] = None) -> float:
    """Fenced kernel microbenchmark: µs per call over ``iters`` timed
    iterations after ``warmup`` untimed ones (compilation + first-touch).

    When the (global or injected) tracer is enabled, each measurement
    lands in the stream as a ``kernel.<name>`` counter event (value =
    µs/call, tags carry iters) and a shared ``kernel.us_per_call``
    histogram sample — the kernels profiling mode
    `benchmarks/kernels_micro.py --profile` turns on.
    """
    tracer = tracer if tracer is not None else get_tracer()
    for _ in range(max(1, warmup)):
        fence(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    fence(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    if tracer.enabled:
        tracer.counter(f"kernel.{name}", us, iters=iters)
        tracer.metrics.histogram("kernel.us_per_call",
                                 [e * 1e6 for e in SECONDS_EDGES]).observe(us)
    return us
